"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.kernel  # interpret-mode kernel tests, in tier-1

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    return dict(rtol=3e-2, atol=3e-2) if dt == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("BH,T,S,d,dv,causal,bq,bk", [
    (2, 128, 128, 64, 64, True, 64, 64),
    (1, 96, 160, 32, 16, False, 64, 64),
    (3, 64, 64, 128, 128, True, 32, 32),
    (1, 17, 33, 16, 16, True, 8, 16),
])
def test_flash_attention_sweep(dtype, BH, T, S, d, dv, causal, bq, bk):
    from repro.kernels.flash_attention.kernel import flash_attention_bhsd
    from repro.kernels.flash_attention.ref import attention_bhsd_ref
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(BH, T, d)), dtype)
    k = jnp.asarray(rng.normal(size=(BH, S, d)), dtype)
    v = jnp.asarray(rng.normal(size=(BH, S, dv)), dtype)
    out = flash_attention_bhsd(q, k, v, causal=causal, block_q=bq, block_k=bk,
                               interpret=True)
    ref = attention_bhsd_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_gqa_layout():
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.models.attention import naive_attention
    rng = np.random.default_rng(1)
    B, T, KH, G, dh = 2, 64, 2, 3, 32
    q = jnp.asarray(rng.normal(size=(B, T, KH, G, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, KH, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, KH, dh)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32, interpret=True)
    ref = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n,v,bv", [(4, 1024, 256), (7, 3000, 512), (1, 128, 128)])
def test_accumulate_sweep(dtype, n, v, bv):
    from repro.kernels.accumulate.kernel import accumulate_blocked
    from repro.kernels.accumulate.ref import accumulate_ref
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(n, v)), dtype)
    out = accumulate_blocked(x, block_v=bv, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(accumulate_ref(x), np.float32), **_tol(dtype))


@pytest.mark.parametrize("v,k,bv", [(900, 4, 256), (2048, 16, 512), (100, 2, 64)])
def test_topk_compress_sweep(v, k, bv):
    from repro.kernels.topk_compress.kernel import topk_compress_blocked
    from repro.kernels.topk_compress.ref import topk_compress_ref
    from repro.kernels.sparse_update.ref import sparse_scatter_add_ref
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(v,)), jnp.float32)
    idx, vals = topk_compress_blocked(x, k_per_block=k, block_v=bv, interpret=True)
    ridx, rvals = topk_compress_ref(x, k_per_block=k, block_v=bv)
    np.testing.assert_allclose(
        np.asarray(sparse_scatter_add_ref(idx, vals, v)),
        np.asarray(sparse_scatter_add_ref(ridx, rvals, v)), rtol=1e-6)


@pytest.mark.parametrize("v,k,bv", [(900, 4, 256), (2048, 16, 512), (100, 2, 64),
                                    (1000, 200, 256), (4096, 256, 1024)])
def test_topk_bitonic_matches_argmax_elementwise(v, k, bv):
    """The bitonic partial sort must reproduce the argmax loop's pair stream
    *element for element* — same indices in the same slots (ties at equal
    magnitude break toward the lower index in both), not just the same sum."""
    from repro.kernels.topk_compress.kernel import topk_compress_blocked
    rng = np.random.default_rng(7)
    x = rng.normal(size=(v,)).astype(np.float32)
    x[rng.random(v) < 0.5] = 0.0      # magnitude ties at zero
    x = jnp.asarray(x)
    ia, va = topk_compress_blocked(x, k_per_block=k, block_v=bv,
                                   interpret=True, method="argmax")
    ib, vb = topk_compress_blocked(x, k_per_block=k, block_v=bv,
                                   interpret=True, method="bitonic")
    assert np.array_equal(np.asarray(ia), np.asarray(ib))
    assert np.array_equal(np.asarray(va), np.asarray(vb))


def test_topk_method_auto_selection():
    from repro.kernels.topk_compress.kernel import BITONIC_MIN_K
    from repro.kernels.topk_compress.ops import topk_compress
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(4096,)), jnp.float32)
    # method=None must agree with both explicit methods on either side of the
    # crossover (they are element-wise identical, so this pins the dispatch
    # without reaching into kernel internals)
    for k in (BITONIC_MIN_K - 1, BITONIC_MIN_K):
        auto = topk_compress(x, k_per_block=k, block_v=1024, interpret=True)
        for method in ("argmax", "bitonic"):
            explicit = topk_compress(x, k_per_block=k, block_v=1024,
                                     interpret=True, method=method)
            assert np.array_equal(np.asarray(auto[0]), np.asarray(explicit[0]))
    with pytest.raises(ValueError, match="argmax|bitonic"):
        topk_compress(x, k_per_block=4, block_v=1024, interpret=True,
                      method="quicksort")


@pytest.mark.parametrize("n,v,k,block", [
    (4, 16384, 512, 1024),     # the accumulator bench shape
    (8, 1000, 50, 256),        # ragged tail (1000 = 3×256 + 232)
    (1, 100, 10, 1024),        # single thread, one short block
    (3, 900, 900, 256),        # quota ≥ block: selection degenerates to all
    (2, 7, 3, 1024),           # tiny vector, non-pow2 block
])
def test_fused_scatter_bitexact_vs_unfused(n, v, k, block):
    """The fused sparsify→scatter-add must be *bit-exact* against the
    compress→densify→add path it replaces, for both impls, across densities
    (dense rounds, realistic sparse rounds, all-zero rounds)."""
    from repro.core.sparse import blocked_topk_accumulate
    rng = np.random.default_rng(9)
    for density in (0.0, 0.01, 0.3, 1.0):
        mat = rng.normal(size=(n, v)).astype(np.float32)
        mat[rng.random((n, v)) >= density] = 0.0
        mat = jnp.asarray(mat)
        ref = blocked_topk_accumulate(mat, k, block, fused=False)
        fused = blocked_topk_accumulate(mat, k, block, fused=True, impl="pallas")
        fused_jnp = blocked_topk_accumulate(mat, k, block, fused=True, impl="jnp")
        assert np.array_equal(np.asarray(ref), np.asarray(fused)), density
        assert np.array_equal(np.asarray(ref), np.asarray(fused_jnp)), density


def test_fused_scatter_kernel_validation():
    from repro.kernels.accumulate.fused_scatter import fused_topk_scatter
    with pytest.raises(ValueError, match=r"\(N, V\)"):
        fused_topk_scatter(jnp.zeros((8,)), per_block=2, block_eff=8)
    with pytest.raises(ValueError, match="per_block"):
        fused_topk_scatter(jnp.zeros((2, 8)), per_block=0, block_eff=8)


@pytest.mark.parametrize("m,v,bv", [(50, 700, 256), (200, 4096, 1024), (1, 64, 64)])
def test_scatter_add_sweep(m, v, bv):
    from repro.kernels.sparse_update.kernel import sparse_scatter_add
    from repro.kernels.sparse_update.ref import sparse_scatter_add_ref
    rng = np.random.default_rng(4)
    idx = jnp.asarray(rng.integers(0, v, size=(m,)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(m,)), jnp.float32)
    out = sparse_scatter_add(idx, vals, v, block_v=bv, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(sparse_scatter_add_ref(idx, vals, v)),
                               rtol=1e-5, atol=1e-6)


def test_scatter_add_duplicates():
    from repro.kernels.sparse_update.kernel import sparse_scatter_add
    idx = jnp.asarray([3, 3, 3, 0], jnp.int32)
    vals = jnp.asarray([1.0, 2.0, 3.0, 5.0], jnp.float32)
    out = sparse_scatter_add(idx, vals, 8, block_v=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out)[3], 6.0)
    np.testing.assert_allclose(np.asarray(out)[0], 5.0)


@pytest.mark.parametrize("n,k,d,bn", [(500, 11, 24, 128), (1000, 3, 8, 256)])
def test_kmeans_assign_sweep(n, k, d, bn):
    from repro.kernels.kmeans_assign.kernel import kmeans_assign_blocked
    from repro.kernels.kmeans_assign.ref import kmeans_assign_ref
    rng = np.random.default_rng(5)
    pts = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    ctr = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
    a, dist = kmeans_assign_blocked(pts, ctr, block_n=bn, interpret=True)
    ra, rd = kmeans_assign_ref(pts, ctr)
    assert np.array_equal(np.asarray(a), np.asarray(ra))
    np.testing.assert_allclose(np.asarray(dist), np.asarray(rd), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_ssd_scan_sweep(chunk):
    from repro.kernels.ssd_scan.ops import ssd
    from repro.kernels.ssd_scan.ref import ssd_sequential_ref
    rng = np.random.default_rng(6)
    b, T, H, P, G, N = 2, 64, 4, 8, 2, 16
    xs = jnp.asarray(rng.normal(size=(b, T, H, P)), jnp.float32) * 0.5
    dt = jnp.asarray(np.abs(rng.normal(size=(b, T, H))) * 0.5 + 0.1, jnp.float32)
    A_log = jnp.asarray(np.log(np.linspace(1.0, 4.0, H)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, T, G, N)), jnp.float32) * 0.3
    C = jnp.asarray(rng.normal(size=(b, T, G, N)), jnp.float32) * 0.3
    y, _ = ssd(xs, dt, A_log, B, C, chunk=chunk, interpret=True)
    ref = ssd_sequential_ref(xs, dt, A_log, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=3e-4, atol=3e-4)
