"""Per-arch smoke: reduced config, one forward/train step, shapes + no NaNs,
plus decode/prefill cache consistency (teacher-forcing equivalence)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models.build import build_model


def batch_for(cfg, B=2, T=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.family == "audio":
        b = {"frames": jnp.asarray(rng.normal(size=(B, T, cfg.frame_dim)), jnp.float32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, size=(B, T)), jnp.int32)}
    else:
        b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, size=(B, T)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, size=(B, T)), jnp.int32)}
    if cfg.family == "vlm":
        b["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_tokens, cfg.vision_dim)), jnp.float32)
    return b


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_train_step(name):
    cfg = smoke_config(ARCHS[name])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = batch_for(cfg)

    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(p, b)
        return loss, grads

    loss, grads = jax.jit(step)(params, batch)
    assert np.isfinite(float(loss)), f"{name}: non-finite loss"
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{name}: bad grads"
    logits = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("name", [n for n in sorted(ARCHS)
                                  if ARCHS[n].family != "audio"])
def test_decode_matches_forward(name):
    """Teacher-forced decode through the cache == full forward logits.

    MoE capacity is raised so no token drops: forward drops over-capacity
    tokens batch-wide while decode routes per step - a real (documented)
    behavioural difference, not an error.
    """
    cfg = smoke_config(ARCHS[name]).replace(attention_impl="naive",
                                            capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 16
    batch = batch_for(cfg, B=B, T=T)
    full_logits = np.asarray(jax.jit(model.forward)(params, batch), np.float32)

    cache = model.init_cache(B, T)
    decode = jax.jit(model.decode_step)
    outs = []
    for t in range(T):
        if cfg.family == "vlm":
            # cross K/V must be prefilled: emulate by projecting vision embeds
            pass
        logits, cache = decode(params, cache, batch["tokens"][:, t:t + 1], t)
        outs.append(np.asarray(logits[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    if cfg.family == "vlm":
        # vlm decode uses zero-initialised cross K/V (prefill not emulated here):
        # only check shapes/finiteness
        assert dec.shape == full_logits.shape and np.all(np.isfinite(dec))
    else:
        np.testing.assert_allclose(dec, full_logits, rtol=2e-3, atol=2e-3)


def test_int8_kv_cache_decode_close_to_bf16():
    """Quantized decode cache (serving lever): logits within int8 tolerance."""
    import jax
    import jax.numpy as jnp
    cfg = smoke_config(ARCHS["qwen2-72b"]).replace(attention_impl="naive")
    m = build_model(cfg)
    mq = build_model(cfg.replace(kv_cache_dtype="int8"))
    params = m.init(jax.random.PRNGKey(0))
    B, T = 2, 12
    batch = batch_for(cfg, B=B, T=T)
    c, cq = m.init_cache(B, T), mq.init_cache(B, T)
    dec, decq = jax.jit(m.decode_step), jax.jit(mq.decode_step)
    for t in range(T):
        lg, c = dec(params, c, batch["tokens"][:, t:t + 1], t)
        lq, cq = decq(params, cq, batch["tokens"][:, t:t + 1], t)
        assert float(jnp.max(jnp.abs(lg - lq))) < 0.15
    # the quantized cache is genuinely int8 under the hood
    leaf = jax.tree.leaves(cq)[0]
    assert any(l.dtype == jnp.int8 for l in jax.tree.leaves(cq))
