"""step.check — happens-before race detector, lock-order sanitizer, lint.

Tentpole contract: checking is a strict no-op by default (one-branch hot
paths, nothing armed globally); armed via ``Session(check=True)``, the
vector-clock race detector deterministically flags a seeded unsynchronized
RMW with both stack sites yet stays silent on all four analytics apps; the
lock sanitizer catches a node→shard inversion and wait-for cycles across
DBarrier/DSemaphore; and the spawn-time lint rejects structurally broken
programs (barrier arity, ragged accumulate, host sync under SPMD) with
``CheckError`` before any worker thread runs.
"""

import json
import os
import subprocess
import sys
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analytics import kmeans, logreg, nmf, pagerank
from repro.check import CheckError, Checker, Finding, NULL_CHECKER
from repro.check import checker as stepcheck
from repro.core import Session
from repro.ft import session_recovery


def _session(n_nodes=1, tpn=2, **kw):
    return Session(backend="host", n_nodes=n_nodes, threads_per_node=tpn,
                   check=True, **kw)


# -- no-op by default ---------------------------------------------------------


def test_noop_by_default():
    """A plain Session arms nothing: CHECKING stays False, the null checker
    is shared, and findings() answers (empty) against a disabled checker."""
    assert stepcheck.armed_count() == 0
    sess = Session(backend="host", n_nodes=1, threads_per_node=2)
    assert not sess.checker.enabled
    assert stepcheck.CHECKING is False
    assert stepcheck.armed_count() == 0
    ref = sess.def_global("g", jnp.float32(0))
    sess.run(lambda ctx: ref.set(ref.get() + 1))   # racy — but nobody looks
    assert sess.findings() == []


def test_arm_disarm_scoping():
    c1, c2 = Checker(enabled=True), Checker(enabled=True)
    try:
        assert stepcheck.CHECKING and stepcheck.armed_count() == 2
        c1.disable()
        assert stepcheck.CHECKING and stepcheck.armed_count() == 1
        c2.disable()
        assert not stepcheck.CHECKING and stepcheck.armed_count() == 0
    finally:
        stepcheck.reset()


def test_checker_context_manager():
    with Checker(enabled=True) as ck:
        assert ck.enabled and stepcheck.armed_count() == 1
    assert not ck.enabled and stepcheck.armed_count() == 0


# -- the acceptance race: seeded unsynchronized RMW ---------------------------


def _seeded_rmw_findings():
    sess = _session()
    counter = sess.def_global("counter", jnp.float32(0))

    def proc(ctx):
        for _ in range(4):
            v = counter.get()
            counter.set(v + jnp.float32(ctx.tid + 1))  # distinct per thread
        return None

    sess.run(proc)
    found = sess.findings()
    sess.checker.disable()
    return found


def test_seeded_rmw_race_detected_with_both_sites():
    found = _seeded_rmw_findings()
    kinds = {f.kind for f in found}
    assert "write-write" in kinds
    assert "read-write" in kinds
    for f in found:
        assert f.layer == "race" and f.severity == "error"
        assert f.name == "counter"
        assert len(f.tids) == 2          # both racing threads named
        assert f.sites and all(":" in s for s in f.sites)
        assert "test_check.py" in f.sites[0]
    # the read-write pair reports BOTH stack sites (read line != write line)
    rw = next(f for f in found if f.kind == "read-write")
    assert len(rw.sites) == 2


def test_race_detection_deterministic():
    """Same program, same findings — the detector keys on program structure
    (sites/kinds), not on which interleaving the scheduler happened to pick."""
    a = {(f.kind, f.name, f.sites) for f in _seeded_rmw_findings()}
    b = {(f.kind, f.name, f.sites) for f in _seeded_rmw_findings()}
    assert a == b and a


def test_ww_fixture_two_blind_writers():
    sess = _session()
    ref = sess.def_global("w", jnp.float32(0))

    def proc(ctx):
        ref.set(jnp.float32(ctx.tid + 1))   # differing values, no sync
        return None

    sess.run(proc)
    found = sess.findings()
    sess.checker.disable()
    assert [f.kind for f in found] == ["write-write"]
    assert found[0].tids == (0, 1)


def test_equal_value_writes_are_benign_replication():
    """The §4.5 bulk-synchronous idiom — every thread writes the identical
    reduced value — is unordered but benign; it is counted, not flagged."""
    sess = _session()
    ref = sess.def_global("r", jnp.float32(0))

    def proc(ctx):
        ref.set(jnp.float32(7.0))           # same value from both threads
        return None

    sess.run(proc)
    assert sess.findings() == []
    assert sess.checker.benign_replicated > 0
    sess.checker.disable()


def test_inc_inc_commutes():
    sess = _session()
    ref = sess.def_global("acc", jnp.float32(0))
    sess.run(lambda ctx: ref.inc(jnp.float32(ctx.tid + 1)))
    assert sess.findings() == []            # atomic incs commute by design
    sess.checker.disable()


def test_barrier_creates_happens_before_edge():
    """Writer → barrier → reader is ordered (clean); the identical program
    without the barrier is flagged — the sync edge is what's being tested."""

    def run(with_barrier):
        sess = _session()
        ref = sess.def_global("x", jnp.float32(0))
        bar = sess.barrier()

        def proc(ctx):
            if ctx.tid == 0:
                ref.set(jnp.float32(42.0))
            bar.enter() if with_barrier else None
            out = ref.get() if ctx.tid == 1 else None
            if not with_barrier:
                bar.enter()     # keep barrier arity identical for the lint
            return out

        sess.run(proc)
        found = sess.findings()
        sess.checker.disable()
        return found

    assert run(with_barrier=True) == []
    flagged = run(with_barrier=False)
    assert {f.kind for f in flagged} == {"read-write"}


def test_semaphore_handoff_creates_edge():
    sess = _session()
    ref = sess.def_global("h", jnp.float32(0))
    sem = sess.semaphore(0)                  # starts unavailable

    def proc(ctx):
        if ctx.tid == 0:
            ref.set(jnp.float32(1.0))
            sem.release()                    # hand-off publishes the write
        else:
            sem.acquire()
            ref.get()
        return None

    sess.run(proc)
    assert sess.findings() == []
    sess.checker.disable()


def test_accumulator_round_is_a_barrier_edge():
    sess = _session()
    partial = sess.new_array("p", (8,))
    out = sess.def_global("o", jnp.float32(0))

    def proc(ctx):
        tot = partial.accumulate(jnp.ones(8))
        if ctx.tid == 0:
            out.set(tot.sum())               # only one thread writes post-round
        return None

    sess.run(proc)
    assert sess.findings() == []
    sess.checker.disable()


# -- acceptance: zero findings on the four analytics apps ---------------------


@pytest.mark.parametrize("shards", [1, 8])
def test_apps_clean_under_armed_checker(shards):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 8)).astype(np.float32)
    y = (rng.random(64) > 0.5).astype(np.float32)
    pts = rng.normal(size=(60, 4)).astype(np.float32)
    r = np.abs(rng.normal(size=(24, 16))).astype(np.float32)
    edges = np.stack([rng.integers(0, 20, 60), rng.integers(0, 20, 60)],
                     axis=1).astype(np.int32)
    apps = [
        ("logreg", lambda s: logreg.fit(x, y, iters=3, session=s)),
        ("kmeans", lambda s: kmeans.fit(pts, 3, iters=3, session=s)),
        ("nmf", lambda s: nmf.fit(r, 4, iters=3, session=s)),
        ("pagerank", lambda s: pagerank.fit(edges, 20, iters=3, session=s)),
    ]
    for name, call in apps:
        sess = Session(backend="host", n_nodes=2, threads_per_node=2,
                       shards=shards, check=True)
        call(sess)
        found = sess.findings()
        sess.checker.disable()
        assert found == [], (f"{name} S={shards}: "
                             f"{[f.as_dict() for f in found]}")


# -- lock-order sanitizer -----------------------------------------------------


def test_inverted_node_shard_order_flagged():
    """Regression for the documented shard → node order: taking a shard lock
    while holding a node LRU lock is the inversion the cache layer must
    never perform (eviction cleanup defers for exactly this reason)."""
    ck = Checker(enabled=True)
    try:
        ck.bind_thread(0)
        ck.lock_acquired(("node", 0))
        ck.lock_acquired(("shard", 1))       # inverted!
        ck.lock_released(("shard", 1))
        ck.lock_released(("node", 0))
        kinds = [f.kind for f in ck.findings()]
        assert kinds == ["lock-order-inversion"]
        assert "shard → node" in ck.findings()[0].message
    finally:
        ck.disable()


def test_correct_shard_then_node_order_clean():
    ck = Checker(enabled=True)
    try:
        ck.bind_thread(0)
        ck.lock_acquired(("shard", 3))
        ck.lock_acquired(("node", 0))
        ck.lock_released(("node", 0))
        ck.lock_released(("shard", 3))
        assert ck.findings() == []
    finally:
        ck.disable()


def test_rebalance_shard_pairs_must_be_sorted():
    ck = Checker(enabled=True)
    try:
        ck.bind_thread(0)
        ck.rebalance_begin()
        ck.lock_acquired(("shard", 1))
        ck.lock_acquired(("shard", 2))       # ascending: fine
        ck.lock_released(("shard", 2))
        ck.lock_released(("shard", 1))
        assert ck.findings() == []
        ck.lock_acquired(("shard", 5))
        ck.lock_acquired(("shard", 4))       # descending: deadlock-prone
        ck.lock_released(("shard", 4))
        ck.lock_released(("shard", 5))
        ck.rebalance_end()
        assert [f.kind for f in ck.findings()] == ["rebalance-unsorted"]
    finally:
        ck.disable()


def test_shard_nesting_outside_rebalance_flagged():
    ck = Checker(enabled=True)
    try:
        ck.bind_thread(0)
        ck.lock_acquired(("shard", 0))
        ck.lock_acquired(("shard", 1))       # not in a rebalance
        assert [f.kind for f in ck.findings()] == ["shard-shard-nesting"]
    finally:
        ck.disable()


def test_live_rebalance_passes_sanitizer():
    """A real add_shard migration takes its sorted shard-pair locks under
    the rebalance exemption — armed, it must produce zero lock findings."""
    sess = _session(n_nodes=2, tpn=1, shards=2)
    for i in range(16):
        sess.def_global(f"k{i}", float(i))
    sess.store.add_shard(7)
    assert [f for f in sess.findings() if f.layer == "lock"] == []
    sess.checker.disable()


def test_wait_cycle_semaphore_barrier_deadlock():
    """t0 holds the semaphore and parks on a 2-arrival barrier; t1 parks on
    the semaphore — a cross-primitive wait-for cycle.  Timeouts let both
    threads exit; the checker must have reported the cycle meanwhile."""
    sess = _session()
    sem = sess.semaphore(1)
    bar = sess.barrier(2)

    def proc(ctx):
        if ctx.tid == 0:
            sem.acquire()
            bar.enter(timeout=2.0)           # t1 never arrives
            sem.release()
        else:
            time.sleep(0.2)
            if sem.acquire(timeout=2.0):
                sem.release()
            bar.enter(timeout=2.0)
        return None

    sess.run(proc)
    kinds = {f.kind for f in sess.findings()}
    assert "wait-cycle" in kinds
    cycle = next(f for f in sess.findings() if f.kind == "wait-cycle")
    assert "thread 0" in cycle.message and "thread 1" in cycle.message
    sess.checker.disable()


# -- spawn-time lint ----------------------------------------------------------


def test_lint_rejects_barrier_arity_before_threads_run():
    sess = _session()
    bar = sess.barrier(3)                    # 3 arrivals, only 2 threads
    seen_threads = []

    def proc(ctx):
        seen_threads.append(threading.current_thread())
        bar.enter()
        return None

    with pytest.raises(CheckError, match="arity"):
        sess.run(proc)
    # the only executions were the lint dry-runs on the driver thread:
    # no worker thread ever started, nothing ever parked on the barrier
    assert seen_threads and all(t is threading.main_thread()
                                for t in seen_threads)
    assert [f.kind for f in sess.findings()] == ["barrier-arity"]
    sess.checker.disable()


def test_lint_rejects_ragged_accumulate():
    sess = _session()
    g = sess.new_array("g", (4,))

    def proc(ctx):
        g.accumulate(jnp.ones(4))
        if ctx.tid == 0:
            g.accumulate(jnp.ones(4))        # one thread runs an extra round
        return None

    with pytest.raises(CheckError, match="diverge"):
        sess.run(proc)
    assert [f.kind for f in sess.findings()] == ["ragged-accumulate"]
    sess.checker.disable()


def test_lint_counts_fori_trips():
    """ctx.iterate multiplies reach counts: N rounds in a loop body is a
    matched program, a tid-dependent trip count is ragged."""
    sess = _session()
    g = sess.new_array("g", (4,))

    def ok(ctx):
        return ctx.iterate(lambda c: c + g.accumulate(jnp.ones(4)).sum(),
                           jnp.float32(0), 3)

    sess.run(ok)                             # lints clean, then really runs
    assert sess.findings() == []

    def ragged(ctx):
        return ctx.iterate(lambda c: c + g.accumulate(jnp.ones(4)).sum(),
                           jnp.float32(0), 3 + ctx.tid)

    with pytest.raises(CheckError, match="diverge"):
        sess.run(ragged)
    sess.checker.disable()


def test_lint_rejects_host_sync_under_spmd():
    sess = Session(backend="spmd", check=True)
    bar = sess.barrier()

    def proc(ctx, xs):
        bar.enter()                          # host-only primitive
        return xs.sum()

    with pytest.raises(CheckError, match="SPMD"):
        sess.run(proc, data=(jnp.ones((4, 2)),))
    assert [f.kind for f in sess.findings()] == ["spmd-host-sync"]
    sess.checker.disable()


def test_lint_sparse_budget_warning():
    sess = _session()
    sess.new_array("sp", (16,), sparse_k=100)   # k > pair_capacity(16)
    found = sess.findings()
    assert [f.kind for f in found] == ["sparse-overbudget"]
    assert found[0].severity == "warning"       # advisory, nothing raised
    sess.checker.disable()


def test_delete_with_live_replicas_warns():
    sess = _session(n_nodes=2, tpn=1)
    ref = sess.new_array("d", (4,))
    def proc(ctx):
        ref.get()                               # both nodes cache a replica
        return None

    sess.run(proc)
    sess.delete("d")
    found = [f for f in sess.findings() if f.kind == "delete-live-replicas"]
    assert len(found) == 1 and found[0].severity == "warning"
    assert "node(s) [0, 1]" in found[0].message
    assert "d" not in sess.names()              # the delete still happened
    sess.checker.disable()


def test_strict_false_records_without_raising():
    ck = Checker(enabled=True, strict=False)
    try:
        sess = Session(backend="host", n_nodes=1, threads_per_node=2,
                       check=ck)
        bar = sess.barrier(3)

        def proc(ctx):
            bar.enter(timeout=0.1)           # arity-broken but non-strict
            return None

        sess.run(proc)                       # no CheckError
        kinds = [f.kind for f in sess.findings()]
        assert "barrier-arity" in kinds      # the lint still records it
        # non-strict means the broken program really ran, so the dynamic
        # layer reports the starvation the lint predicted
        assert "starved-barrier" in kinds
    finally:
        ck.disable()


# -- findings model / export --------------------------------------------------


def test_findings_dedupe_and_export_roundtrip(tmp_path):
    found = _seeded_rmw_findings()
    # 4 RMW rounds/thread but structurally-identical findings dedupe by key
    assert len(found) == len({f.key() for f in found})
    ck = Checker(enabled=True)
    try:
        for f in found:
            ck.record(f)
            ck.record(f)                     # duplicate — dropped
        assert len(ck.findings()) == len(found)
        path = ck.export(str(tmp_path / "check.json"))
        with open(path) as fh:
            report = json.load(fh)
        assert report["count"] == len(found)
        assert set(report["by_layer"]) == {"race"}
        assert report["by_severity"]["error"] == len(found)
        for row in report["findings"]:
            assert {"layer", "kind", "severity", "message"} <= set(row)
    finally:
        ck.disable()


def test_finding_cap_counts_drops():
    ck = Checker(enabled=True, max_findings=2)
    try:
        for i in range(5):
            ck.record(Finding("race", "write-write", "error", f"m{i}",
                              name=f"n{i}"))
        assert len(ck.findings()) == 2 and ck.dropped == 3
    finally:
        ck.disable()


def test_null_checker_is_inert():
    assert not NULL_CHECKER.enabled
    NULL_CHECKER.on_access("x", "write", 1.0)   # all hooks are safe no-ops
    assert NULL_CHECKER.findings() == []


# -- integration: FT recovery keeps the armed checker -------------------------


def test_recovery_rearms_checker():
    sess = _session(n_nodes=2, tpn=1, shards=2)
    ref = sess.new_array("w", (8,))

    def proc(ctx):
        ref.accumulate(jnp.ones(8))
        return None

    sess.run(proc)
    plan, new_sess = session_recovery(sess, [1])
    assert new_sess.checker is sess.checker and new_sess.checker.enabled
    ref2 = new_sess.ref("w")

    def proc2(ctx):
        ref2.accumulate(jnp.ones(8))
        return None

    new_sess.run(proc2)
    assert new_sess.findings() == []
    sess.checker.disable()


# -- the example is the documented repro ------------------------------------


def test_race_demo_smoke():
    """examples/race_demo.py runs green: flags the seeded race with both
    sites, stays silent on the synchronized variant."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable,
         os.path.join(os.path.dirname(__file__), "..", "examples",
                      "race_demo.py")],
        env=env, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr
    assert "race_demo.py:31" in proc.stdout   # read site
    assert "race_demo.py:32" in proc.stdout   # write site
    assert "synchronized program: 0 finding(s)" in proc.stdout
