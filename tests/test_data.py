"""Data pipeline: restart exactness + partition properties."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.data import LMDataPipeline, lm_batch, partition_rows


def test_stateless_stream():
    b1 = lm_batch(5, 4, 16, 100, seed=7)
    b2 = lm_batch(5, 4, 16, 100, seed=7)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    b3 = lm_batch(6, 4, 16, 100, seed=7)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_pipeline_restart_exact():
    p = LMDataPipeline(4, 8, 100, prefetch=True)
    batches = [p.next() for _ in range(3)]
    p.close()
    p2 = LMDataPipeline(4, 8, 100, prefetch=False, start_step=1)
    s, b = p2.next()
    assert s == 1
    assert np.array_equal(np.asarray(b["tokens"]), np.asarray(batches[1][1]["tokens"]))


def test_labels_are_shifted_tokens():
    b = lm_batch(0, 2, 8, 50, seed=0)
    # tokens/labels come from one (T+1)-stream: labels[t] == tokens[t+1]
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


@given(st.integers(1, 500), st.integers(1, 16))
def test_partition_rows_cover_disjoint(n_rows, n_threads):
    spans = [partition_rows(n_rows, t, n_threads) for t in range(n_threads)]
    covered = []
    for lo, hi in spans:
        assert 0 <= lo <= hi <= n_rows
        covered.extend(range(lo, hi))
    assert covered == list(range(n_rows))
