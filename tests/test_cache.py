"""Directory-based write-invalidate DSM cache (paper §5.1)."""

import jax.numpy as jnp
import numpy as np

from repro.core import DSMCache, GlobalStore


def make():
    store = GlobalStore()
    store.new_array("v", (8,))
    store.new_array("w", (4,))
    return store, DSMCache(store, n_nodes=4, capacity=2)


def test_hit_miss():
    store, cache = make()
    cache.read(0, "v")
    assert cache.stats.misses == 1
    cache.read(0, "v")
    assert cache.stats.hits == 1


def test_write_invalidate():
    store, cache = make()
    cache.read(0, "v")
    cache.read(1, "v")
    cache.write(2, "v", jnp.ones(8))
    # nodes 0 and 1 had replicas; both invalidated
    assert cache.stats.invalidations == 2
    np.testing.assert_allclose(cache.read(0, "v"), 1.0)
    assert cache.stats.misses == 3  # 0, 1 initial + 0 after invalidate


def test_writer_keeps_fresh_replica():
    store, cache = make()
    cache.write(1, "v", jnp.full(8, 2.0))
    before = cache.stats.hits
    np.testing.assert_allclose(cache.read(1, "v"), 2.0)
    assert cache.stats.hits == before + 1


def test_lru_eviction():
    store, cache = make()
    store.new_array("u", (2,))
    cache.read(0, "v")
    cache.read(0, "w")
    cache.read(0, "u")  # capacity 2: evicts v
    assert cache.stats.evictions == 1
    cache.read(0, "v")
    assert cache.stats.misses == 4


def test_epoch_staleness():
    store, cache = make()
    cache.read(0, "v")
    store.set("v", jnp.ones(8))      # direct store write bumps epoch
    np.testing.assert_allclose(cache.read(0, "v"), 1.0)  # stale replica refreshed
    assert cache.stats.misses == 2


def _holder_count(cache, node_id):
    return sum(1 for d in cache.directory
               for holders in d.values() if node_id in holders)


def test_eviction_cleans_directory():
    """LRU eviction must remove the node from the evicted name's watcher
    directory: directory size stays bounded by cache capacity per node, and
    invalidation fan-out is not overcounted for long-gone replicas."""
    store = GlobalStore()
    names = [f"n{i}" for i in range(6)]
    for n in names:
        store.new_array(n, (4,))
    cache = DSMCache(store, n_nodes=4, capacity=2)
    for n in names:
        cache.read(0, n)
    assert cache.stats.evictions == 4
    # pre-fix: node 0 stayed listed as holder of all 6 names
    assert _holder_count(cache, 0) == 2
    assert sum(len(d) for d in cache.directory) == 2

    # a write to an evicted name must not count an invalidation for node 0
    before = cache.stats.invalidations
    cache.write(1, names[0], jnp.ones(4))
    assert cache.stats.invalidations == before


def test_eviction_directory_bounded_under_churn():
    store = GlobalStore()
    names = [f"c{i}" for i in range(16)]
    for n in names:
        store.new_array(n, (2,))
    cache = DSMCache(store, n_nodes=2, capacity=3)
    for rep in range(3):
        for n in names:
            cache.read(rep % 2, n)
    for node in (0, 1):
        assert _holder_count(cache, node) <= 3


def test_delete_redeclare_store_path_is_fresh():
    """Store-level delete→redeclare: the new entry's epoch is strictly past
    the deleted era, so an old replica can never validate as fresh."""
    store = GlobalStore()
    store.def_global("v", jnp.full((4,), 5.0))
    cache = DSMCache(store, n_nodes=2, capacity=4)
    np.testing.assert_allclose(cache.read(0, "v"), 5.0)   # replica @ epoch 0
    store.delete("v")
    store.def_global("v", jnp.full((4,), 9.0))            # pre-fix: epoch 0 again
    np.testing.assert_allclose(cache.read(0, "v"), 9.0)   # not the stale 5.0
    assert store.epoch("v") > 0


def test_drop_purges_replicas_and_directory():
    store, cache = make()
    cache.read(0, "v")
    cache.read(1, "v")
    cache.drop("v")
    assert all("v" not in c.blocks for c in cache.caches)
    assert all("v" not in d for d in cache.directory)
    # a fresh read misses (no phantom replica) and re-registers cleanly
    cache.read(0, "v")
    assert cache.stats.misses == 3
