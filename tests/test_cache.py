"""Directory-based write-invalidate DSM cache (paper §5.1)."""

import jax.numpy as jnp
import numpy as np

from repro.core import DSMCache, GlobalStore


def make():
    store = GlobalStore()
    store.new_array("v", (8,))
    store.new_array("w", (4,))
    return store, DSMCache(store, n_nodes=4, capacity=2)


def test_hit_miss():
    store, cache = make()
    cache.read(0, "v")
    assert cache.stats.misses == 1
    cache.read(0, "v")
    assert cache.stats.hits == 1


def test_write_invalidate():
    store, cache = make()
    cache.read(0, "v")
    cache.read(1, "v")
    cache.write(2, "v", jnp.ones(8))
    # nodes 0 and 1 had replicas; both invalidated
    assert cache.stats.invalidations == 2
    np.testing.assert_allclose(cache.read(0, "v"), 1.0)
    assert cache.stats.misses == 3  # 0, 1 initial + 0 after invalidate


def test_writer_keeps_fresh_replica():
    store, cache = make()
    cache.write(1, "v", jnp.full(8, 2.0))
    before = cache.stats.hits
    np.testing.assert_allclose(cache.read(1, "v"), 2.0)
    assert cache.stats.hits == before + 1


def test_lru_eviction():
    store, cache = make()
    store.new_array("u", (2,))
    cache.read(0, "v")
    cache.read(0, "w")
    cache.read(0, "u")  # capacity 2: evicts v
    assert cache.stats.evictions == 1
    cache.read(0, "v")
    assert cache.stats.misses == 4


def test_epoch_staleness():
    store, cache = make()
    cache.read(0, "v")
    store.set("v", jnp.ones(8))      # direct store write bumps epoch
    np.testing.assert_allclose(cache.read(0, "v"), 1.0)  # stale replica refreshed
    assert cache.stats.misses == 2
