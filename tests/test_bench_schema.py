"""Every committed BENCH_*.json must carry {host, commit, config} provenance.

A benchmark number nobody can trace back to a machine, revision and
toolchain is a rumor — ``benchmarks.common.write_bench`` stamps the record
on every write, and this test keeps files produced by older code (or by
hand) from slipping back in without one.
"""

import glob
import json
import os

BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks")

PROVENANCE_KEYS = ("host", "commit", "config")


def _bench_files():
    files = sorted(glob.glob(os.path.join(BENCH_DIR, "BENCH_*.json")))
    assert files, "no BENCH_*.json files found (benchmarks/ moved?)"
    return files


def test_every_bench_file_carries_provenance():
    for path in _bench_files():
        with open(path) as f:
            data = json.load(f)
        assert isinstance(data, dict), f"{os.path.basename(path)}: not an object"
        prov = data.get("provenance")
        assert isinstance(prov, dict), \
            f"{os.path.basename(path)}: missing provenance record"
        for key in PROVENANCE_KEYS:
            assert key in prov, \
                f"{os.path.basename(path)}: provenance lacks {key!r}"
        assert isinstance(prov["host"], str) and prov["host"], \
            f"{os.path.basename(path)}: provenance host must be non-empty"
        assert isinstance(prov["commit"], str) and prov["commit"], \
            f"{os.path.basename(path)}: provenance commit must be non-empty"
        assert isinstance(prov["config"], dict), \
            f"{os.path.basename(path)}: provenance config must be a dict"


def test_write_bench_stamps_provenance(tmp_path):
    from benchmarks.common import provenance, write_bench

    out = write_bench(str(tmp_path / "BENCH_unit.json"), {"x": 1}, knob=7)
    data = json.load(open(out))
    assert data["x"] == 1
    assert set(PROVENANCE_KEYS) <= set(data["provenance"])
    assert data["provenance"]["config"]["knob"] == 7
    # python version always rides along in config
    assert "python" in data["provenance"]["config"]
    # provenance() itself never raises and always returns the full key set
    assert set(PROVENANCE_KEYS) <= set(provenance())
