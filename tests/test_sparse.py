"""Sparse dispatch layer (core/sparse.py) + host↔SPMD sparse/auto/inc parity.

The tentpole contract: `blocked_topk_sparsify` routes to the Pallas
`topk_compress` kernel (interpret mode off-TPU) with the jnp path kept as a
reference, both backends compress contributions with the *same* dispatch and
pair format, and `wire_traffic()` for a sparse round is derived from the
actual pair counts on both — so host and SPMD sessions agree on results
(lossless iff nnz fits the budget, identical top-k selection otherwise) and
on the sparse wire figure.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess_devices
from repro.core import AccumMode, Session
from repro.core.sparse import (
    SparsePairs,
    block_layout,
    blocked_topk_sparsify,
    default_auto_k,
    pair_capacity,
    sparse_beneficial,
)

pytestmark = pytest.mark.kernel  # exercises the Pallas kernel in interpret mode


# -- dispatch: Pallas kernel vs jnp reference ---------------------------------


@pytest.mark.parametrize("v,k,block", [
    (256, 16, 1024),    # single block, k < V
    (3000, 48, 1024),   # multi-block, ragged tail
    (100, 7, 64),       # small blocks
    (64, 64, 1024),     # k == V (fully lossless)
])
def test_pallas_and_jnp_paths_agree(v, k, block):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(v,)), jnp.float32)   # dense → lossy
    pk = blocked_topk_sparsify(x, k, block)               # pallas (interpret)
    pj = blocked_topk_sparsify(x, k, block, impl="jnp")
    assert isinstance(pk, SparsePairs) and isinstance(pj, SparsePairs)
    assert pk.num_pairs == pj.num_pairs == pair_capacity(v, k, block)
    np.testing.assert_allclose(np.asarray(pk.densify()),
                               np.asarray(pj.densify()), rtol=1e-6)


def test_pairs_format_and_tuple_compat():
    x = jnp.asarray(np.arange(10, dtype=np.float32))
    pairs = blocked_topk_sparsify(x, 4)
    idx, vals = pairs                         # legacy tuple-style unpacking
    assert idx.dtype == jnp.int32 and vals.dtype == x.dtype
    assert pairs.wire_elements == 2 * pairs.num_pairs
    assert int(jnp.max(idx)) < 10             # padded tail normalised in-range
    np.testing.assert_allclose(np.asarray(pairs.densify()),
                               [0, 0, 0, 0, 0, 0, 6, 7, 8, 9])


def test_lossless_iff_under_per_block_budget():
    v, k = 512, 8
    nblocks, block_eff, per_block = block_layout(v, k, 256)
    rng = np.random.default_rng(1)
    x = np.zeros(v, np.float32)
    pos = rng.choice(v, size=per_block, replace=False)    # worst-case packing
    x[pos] = rng.normal(size=per_block)
    got = blocked_topk_sparsify(jnp.asarray(x), k, 256).densify()
    np.testing.assert_allclose(np.asarray(got), x, rtol=1e-6)

    # one more nonzero in a single block than its quota → lossy
    y = np.zeros(v, np.float32)
    y[:per_block + 1] = np.arange(1, per_block + 2, dtype=np.float32)
    got_y = np.asarray(blocked_topk_sparsify(jnp.asarray(y), k, 256).densify())
    assert int(np.sum(got_y != 0)) == per_block           # smallest entry dropped
    assert got_y[0] == 0.0 and not bool(sparse_beneficial(jnp.asarray(y), k, 256))


def test_layout_and_capacity_invariants():
    for v, k, block in [(1, 1, 1024), (4096, 256, 1024), (3000, 750, 1024),
                        (10, 100, 1024), (1024, 1, 128)]:
        nblocks, block_eff, per_block = block_layout(v, k, block)
        assert 1 <= per_block <= block_eff
        assert nblocks * block_eff >= v
        assert pair_capacity(v, k, block) == nblocks * per_block
    assert 2 * pair_capacity(1024, default_auto_k(1024)) < 1024
    with pytest.raises(ValueError):
        block_layout(0, 4)
    with pytest.raises(ValueError):
        block_layout(16, 0)
    with pytest.raises(ValueError):
        blocked_topk_sparsify(jnp.ones(8), 2, impl="nope")


# -- host ↔ SPMD parity (the acceptance criterion) ----------------------------


def test_sparse_auto_backend_parity_single_device():
    """Same session code, 1 host thread vs a 1-device SPMD mesh: identical
    results and an identical pairs-derived sparse wire figure.  (Multi-way
    parity runs in the forced-device subprocess tests below.)"""
    V, k = 512, 8
    rows = np.zeros((1, V), np.float32)
    rows[0, 3:6] = 2.0
    rows = jnp.asarray(rows)

    def run(backend, mode):
        sess = Session(backend=backend, n_nodes=1, threads_per_node=1)
        out = sess.new_array("o", (V,), sparse_k=k)

        def proc(ctx, xs):
            return out.accumulate(xs[0], mode=mode)

        res = sess.run(proc, data=(rows,))
        return np.asarray(res[0]), sess.wire_traffic()

    for mode in ("sparse", "auto"):
        r_host, _ = run("host", mode)
        r_spmd, _ = run("spmd", mode)
        np.testing.assert_allclose(r_host, r_spmd, rtol=1e-6)
        np.testing.assert_allclose(r_host, np.asarray(rows)[0], rtol=1e-6)
    # wire parity is asserted for SPARSE (AUTO is accounted at its dense
    # upper bound at SPMD trace time — documented divergence)
    _, w_host = run("host", "sparse")
    _, w_spmd = run("spmd", "sparse")
    assert w_host == w_spmd == 2 * pair_capacity(V, k) + V


def test_sparse_auto_backend_parity_multidevice():
    """4 host threads vs a 4-device mesh: lossless and lossy sparse rounds,
    auto's crossover, and the pairs-derived wire figure all agree."""
    out = run_subprocess_devices("""
import jax.numpy as jnp, numpy as np
from repro.core import Session
from repro.core.sparse import pair_capacity

V, k, N = 1024, 8, 4
P = pair_capacity(V, k)

def run(backend, rows, mode):
    sess = Session(backend=backend, n_nodes=2, threads_per_node=2)
    out = sess.new_array("o", (V,), sparse_k=k)
    def proc(ctx, xs):
        return out.accumulate(xs[0], mode=mode)
    res = sess.run(proc, data=(rows,))
    return np.asarray(res[0]), sess.wire_traffic()

# lossless round: nnz per contribution <= per-block quota
rows = np.zeros((N, V), np.float32)
for t in range(N):
    rows[t, t * 3: t * 3 + 3] = float(t + 1)
rows = jnp.asarray(rows)
for mode in ("sparse", "auto"):
    r_h, w_h = run("host", rows, mode)
    r_s, w_s = run("spmd", rows, mode)
    np.testing.assert_allclose(r_h, r_s, rtol=1e-6)
    np.testing.assert_allclose(r_h, np.sum(np.asarray(rows), axis=0), rtol=1e-6)
r_h, w_h = run("host", rows, "sparse")
r_s, w_s = run("spmd", rows, "sparse")
assert w_h == w_s == N * 2 * P + V, (w_h, w_s, N * 2 * P + V)

# lossy round: dense contributions, nnz > capacity — identical top-k selection
rng = np.random.default_rng(1)
dense = jnp.asarray(np.round(rng.normal(size=(N, V)) * 8), jnp.float32)
r_h, w_h = run("host", dense, "sparse")
r_s, w_s = run("spmd", dense, "sparse")
np.testing.assert_allclose(r_h, r_s, rtol=1e-6)
assert int(np.sum(r_h != 0)) <= N * P
assert w_h == w_s == N * 2 * P + V

# auto crossover on dense data: both backends fall back to the dense sum
r_h, _ = run("host", dense, "auto")
r_s, _ = run("spmd", dense, "auto")
np.testing.assert_allclose(r_h, np.sum(np.asarray(dense), axis=0), rtol=1e-6)
np.testing.assert_allclose(r_s, np.sum(np.asarray(dense), axis=0), rtol=1e-5)
print("SPARSE_PARITY_OK")
""", n_devices=4)
    assert "SPARSE_PARITY_OK" in out


def test_sparse_parity_inside_iterate():
    """ctx.iterate: the sparse collective runs under lax.scan on SPMD; wire
    accounting multiplies by the trip count and still matches the host."""
    out = run_subprocess_devices("""
import jax.numpy as jnp, numpy as np
from repro.core import Session
from repro.core.sparse import pair_capacity

V, k, N, iters = 512, 8, 4, 3
rows = np.zeros((N, V), np.float32)
for t in range(N):
    rows[t, t * 5: t * 5 + 2] = float(t + 1)
rows = jnp.asarray(rows)

def run(backend):
    sess = Session(backend=backend, n_nodes=2, threads_per_node=2)
    out = sess.new_array("o", (V,), sparse_k=k)
    def proc(ctx, xs):
        def step(c):
            return c + out.accumulate(xs[0], mode="sparse")
        return ctx.iterate(step, jnp.zeros((V,)), iters)
    res = sess.run(proc, data=(rows,))
    return np.asarray(res[0]), sess.wire_traffic()

r_h, w_h = run("host")
r_s, w_s = run("spmd")
np.testing.assert_allclose(r_h, r_s, rtol=1e-6)
P = pair_capacity(V, k)
assert w_h == w_s == iters * (N * 2 * P + V), (w_h, w_s)
print("SPARSE_ITERATE_OK")
""", n_devices=4)
    assert "SPARSE_ITERATE_OK" in out


def test_fused_parity_modes_and_shards():
    """The fused sparsify→scatter-add reduce (the default host SPARSE/AUTO
    path) across AccumMode {SPARSE, AUTO} × shards {1, 8}, including inside
    ctx.iterate: results bit-exact everywhere, and the pairs-derived
    wire_traffic() figure identical host↔SPMD and across shard counts."""
    out = run_subprocess_devices("""
import jax.numpy as jnp, numpy as np
from repro.core import Session
from repro.core.sparse import pair_capacity

V, k, N, iters = 512, 8, 4, 3
P = pair_capacity(V, k)
# lossless rows so AUTO takes the sparse branch every round
rows = np.zeros((N, V), np.float32)
for t in range(N):
    rows[t, t * 5: t * 5 + 2] = float(t + 1)
rows = jnp.asarray(rows)

def run(backend, mode, shards):
    sess = Session(backend=backend, n_nodes=2, threads_per_node=2,
                   shards=shards)
    out = sess.new_array("o", (V,), sparse_k=k)
    def proc(ctx, xs):
        def step(c):
            return c + out.accumulate(xs[0], mode=mode)
        return ctx.iterate(step, jnp.zeros((V,)), iters)
    res = sess.run(proc, data=(rows,))
    return np.asarray(res[0]), sess.wire_traffic()

for mode in ("sparse", "auto"):
    results = {(b, s): run(b, mode, s)
               for b in ("host", "spmd") for s in (1, 8)}
    base_r, base_w = results[("host", 1)]
    for key, (r, w) in results.items():
        assert np.array_equal(base_r, r), (mode, key)     # bit-exact parity
        assert w == base_w == iters * (N * 2 * P + V), (mode, key, w)
print("FUSED_PARITY_OK")
""", n_devices=4)
    assert "FUSED_PARITY_OK" in out


def test_fused_kernel_path_and_owner_cache_counters():
    """Observability satellite: step.trace attributes the fused win — the
    reduce path lands in accum.kernel_path.{dense,sparse,fused} counters and
    memoised SharedRef owner handles in store.owner_cache_hit."""
    V, N = 256, 4
    rows = jnp.asarray(np.eye(N, V, dtype=np.float32))    # lossless under k=8
    sess = Session(backend="host", n_nodes=2, threads_per_node=2,
                   shards=2, trace=True)
    try:
        out = sess.new_array("o", (V,), sparse_k=8)

        def proc(ctx, xs):
            out.accumulate(xs[0], mode="sparse")
            out.accumulate(xs[0], mode="auto")            # resolves to sparse
            out.accumulate(xs[0], mode="reduce_scatter")
            return out.get()

        sess.run(proc, data=(rows,))
        counters = sess.metrics()["trace"]["counters"]
        assert counters["accum.kernel_path.fused"] == 2   # sparse + auto
        assert counters["accum.kernel_path.dense"] == 1
        assert "accum.kernel_path.sparse" not in counters  # unfused never ran
        # every out.get() after the first resolved its owner from the handle
        assert counters.get("store.owner_cache_hit", 0) > 0
    finally:
        sess.tracer.disable()

    # fused=False through the registry: the unfused path is attributed too
    sess2 = Session(backend="host", n_nodes=2, threads_per_node=2, trace=True)
    try:
        out2 = sess2.new_array("o2", (V,), sparse_k=8)
        sess2.backend.fused = False

        def proc2(ctx, xs):
            out2.accumulate(xs[0], mode="sparse")

        sess2.run(proc2, data=(rows,))
        counters2 = sess2.metrics()["trace"]["counters"]
        assert counters2["accum.kernel_path.sparse"] == 1
        assert "accum.kernel_path.fused" not in counters2
    finally:
        sess2.tracer.disable()


def test_inc_backend_parity():
    """N threads calling ref.inc(a) advance the value by N·a on BOTH backends
    (SPMD lowers to one psum of the per-thread amounts), inside and outside
    ctx.iterate."""
    out = run_subprocess_devices("""
import jax.numpy as jnp, numpy as np
from repro.core import Session

def run(backend):
    sess = Session(backend=backend, n_nodes=2, threads_per_node=2)
    c = sess.def_global("c", 0.0)
    def proc(ctx):
        c.inc(2.0)                      # outside the loop
        def step(_):
            c.inc(1.0)                  # inside: once per round per thread
            return _
        ctx.iterate(step, None, 3)
    sess.run(proc)
    return float(c.get())

h = run("host")
s = run("spmd")
assert h == s == 4 * 2.0 + 4 * 1.0 * 3, (h, s)
print("INC_PARITY_OK")
""", n_devices=4)
    assert "INC_PARITY_OK" in out


def test_logreg_sparse_gradients_parity():
    """The analytics opt-in: logreg with sparse/auto gradient accumulation
    converges identically across backends (auto) and across impls."""
    out = run_subprocess_devices("""
import numpy as np
from repro.analytics import logreg
from repro.data import logreg_dataset

x, y, _ = logreg_dataset(400, 24, seed=0)
ref = logreg.fit_reference(x, y, iters=8, lr=1e-3)
# auto is lossless by construction: must equal the dense reference
th_h, _ = logreg.fit(x, y, backend="host", n_nodes=2, threads_per_node=2,
                     iters=8, mode="auto", k=16)
th_s, _ = logreg.fit(x, y, backend="spmd", iters=8, mode="auto", k=16)
np.testing.assert_allclose(th_h, ref, rtol=1e-4, atol=1e-5)
np.testing.assert_allclose(th_s, ref, rtol=1e-4, atol=1e-5)
# sparse with a tight budget is lossy but must be lossy the SAME way
th_hs, _ = logreg.fit(x, y, backend="host", n_nodes=2, threads_per_node=2,
                      iters=8, mode="sparse", k=8)
th_ss, _ = logreg.fit(x, y, backend="spmd", iters=8, mode="sparse", k=8)
np.testing.assert_allclose(th_hs, th_ss, rtol=1e-4, atol=1e-6)
print("LOGREG_SPARSE_OK")
""", n_devices=4)
    assert "LOGREG_SPARSE_OK" in out
