"""AccumMode.AUTO traffic accounting — both ROADMAP open items.

SPMD: the ``lax.cond`` branch is a runtime decision invisible at trace time;
each auto call site now threads a device-side branch counter through the
program (and through the ``lax.scan`` carry under ``ctx.iterate``), and
``join`` settles the trace-time dense upper bound to the branch actually
taken — so ``wire_traffic()`` matches the host figure exactly.

Host: the round's per-contribution ``sparse_beneficial`` checks are batched
into ONE jitted call (one device sync per round instead of O(N)).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess_devices
from repro.core import AccumMode, Session, accumulate
from repro.core.sparse import pair_capacity


def _run(backend, rows, mode="auto", iters=None):
    V = rows.shape[1]
    sess = Session(backend=backend, n_nodes=1, threads_per_node=1)
    out = sess.new_array("o", (V,), sparse_k=8)
    if iters is None:
        def proc(ctx, xs):
            return out.accumulate(xs[0], mode=mode)
    else:
        def proc(ctx, xs):
            def step(c):
                return c + out.accumulate(xs[0], mode=mode)
            return ctx.iterate(step, jnp.zeros((V,)), iters)
    res = sess.run(proc, data=(rows,))
    return np.asarray(res[0]), sess.wire_traffic()


def test_auto_wire_parity_single_device():
    """1 host thread vs a 1-device SPMD mesh: AUTO's settled wire figure
    equals the host's actual-branch figure on both the sparse and the dense
    side of the crossover."""
    V, k = 512, 8
    sparse_rows = np.zeros((1, V), np.float32)
    sparse_rows[0, 3:6] = 2.0
    sparse_rows = jnp.asarray(sparse_rows)
    r_h, w_h = _run("host", sparse_rows)
    r_s, w_s = _run("spmd", sparse_rows)
    np.testing.assert_allclose(r_h, r_s, rtol=1e-6)
    assert w_h == w_s == 2 * pair_capacity(V, k) + V     # pairs branch

    rng = np.random.default_rng(0)
    dense_rows = jnp.asarray(rng.normal(size=(1, V)).astype(np.float32))
    _, w_h = _run("host", dense_rows)
    _, w_s = _run("spmd", dense_rows)
    assert w_h == w_s == 2 * V                           # dense (N+1)·V, N=1


def test_auto_wire_parity_multidevice_and_iterate():
    out = run_subprocess_devices("""
import jax.numpy as jnp, numpy as np
from repro.core import Session
from repro.core.sparse import pair_capacity

V, k, N = 512, 8, 4
P = pair_capacity(V, k)

def run(backend, rows, iters=None):
    sess = Session(backend=backend, n_nodes=2, threads_per_node=2)
    out = sess.new_array("o", (V,), sparse_k=k)
    if iters is None:
        def proc(ctx, xs):
            return out.accumulate(xs[0], mode="auto")
    else:
        def proc(ctx, xs):
            def step(c):
                return c + out.accumulate(xs[0], mode="auto")
            return ctx.iterate(step, jnp.zeros((V,)), iters)
    res = sess.run(proc, data=(rows,))
    return np.asarray(res[0]), sess.wire_traffic()

rows = np.zeros((N, V), np.float32)
for t in range(N):
    rows[t, t * 3: t * 3 + 3] = float(t + 1)
rows = jnp.asarray(rows)

# sparse side of the crossover: settled SPMD figure == host pairs figure
r_h, w_h = run("host", rows)
r_s, w_s = run("spmd", rows)
np.testing.assert_allclose(r_h, r_s, rtol=1e-6)
assert w_h == w_s == N * 2 * P + V, (w_h, w_s)

# dense side: both fall back to (N+1)·V
rng = np.random.default_rng(1)
dense = jnp.asarray(rng.normal(size=(N, V)).astype(np.float32))
r_h, w_h = run("host", dense)
r_s, w_s = run("spmd", dense)
np.testing.assert_allclose(r_h, r_s, rtol=1e-5)
assert w_h == w_s == (N + 1) * V, (w_h, w_s)

# under ctx.iterate the counter rides the scan carry: 3 sparse rounds
r_h, w_h = run("host", rows, iters=3)
r_s, w_s = run("spmd", rows, iters=3)
np.testing.assert_allclose(r_h, r_s, rtol=1e-6)
assert w_h == w_s == 3 * (N * 2 * P + V), (w_h, w_s)
print("AUTO_TRAFFIC_OK")
""", n_devices=4)
    assert "AUTO_TRAFFIC_OK" in out


def test_host_auto_decides_each_round_with_one_batched_call(monkeypatch):
    """Satellite: the host accumulator's AUTO rule is one jitted
    sparse_beneficial_batch call per round, not O(N) per-contribution
    device syncs."""
    import repro.core.accumulator as accu_mod

    calls = []
    real = accu_mod.sparse_beneficial_batch

    def counting(vectors, k, block):
        calls.append(len(list(vectors)))
        return real(vectors, k, block)

    monkeypatch.setattr(accu_mod, "sparse_beneficial_batch", counting)
    # the per-contribution path must not be hit at all from the host round
    monkeypatch.setattr(
        accu_mod, "sparse_beneficial",
        lambda *a, **kw: pytest.fail("per-contribution sparse_beneficial "
                                     "called from the host AUTO round"))

    sess = Session(backend="host", n_nodes=2, threads_per_node=2)
    out = sess.new_array("g", (256,), sparse_k=8)
    rounds = 3

    def proc(ctx):
        def step(_):
            out.accumulate(jnp.ones(256), mode="auto")
            return _
        ctx.iterate(step, None, rounds)

    sess.run(proc)
    assert calls == [4] * rounds    # one batched decision per round, N=4 vecs


def test_with_branch_rejected_outside_auto():
    # the mode check fires before any collective, so no mesh context needed
    with pytest.raises(ValueError, match="with_branch"):
        accumulate(jnp.ones(4), "data", AccumMode.SPARSE, k=2, with_branch=True)
    with pytest.raises(ValueError, match="with_branch"):
        accumulate(jnp.ones(4), "data", AccumMode.REDUCE_SCATTER,
                   with_branch=True)
