"""DAddAccumulator host layer: correctness + the paper's traffic formulas."""

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AccumMode, DAddAccumulator, GlobalStore
from repro.core.sparse import pair_capacity


def run_round(mode, vecs, n_nodes=2, k=None, fused=True):
    n = len(vecs)
    store = GlobalStore()
    store.new_array("out", (vecs[0].size,))
    acc = DAddAccumulator(store, "out", n, n_nodes, mode, k=k, fused=fused)
    ts = [threading.Thread(target=acc.accumulate, args=(v,)) for v in vecs]
    [t.start() for t in ts]
    [t.join(10) for t in ts]
    return np.asarray(store.get("out")), acc


def test_sum_correct_all_modes():
    vecs = [jnp.full((64,), float(i + 1)) for i in range(4)]
    expect = np.full(64, 1.0 + 2 + 3 + 4)
    for mode in AccumMode:
        # k=V keeps sparse lossless even for fully-dense contributions
        out, _ = run_round(mode, vecs, k=64)
        np.testing.assert_allclose(out, expect)


def test_traffic_formulas():
    """Paper §5.2: (2N+1)·V naive vs (N+1)·V accumulator."""
    V, N = 128, 4
    vecs = [jnp.ones((V,)) for _ in range(N)]
    _, naive = run_round(AccumMode.GATHER_ALL, vecs)
    _, rs = run_round(AccumMode.REDUCE_SCATTER, vecs)
    assert naive.bytes_transferred == (2 * N + 1) * V
    assert rs.bytes_transferred == (N + 1) * V
    assert rs.bytes_transferred < naive.bytes_transferred


def _sparse_vecs(V, N, nnz=3):
    vecs = []
    for i in range(N):
        v = np.zeros(V, np.float32)
        v[i * nnz: (i + 1) * nnz] = float(i + 1)
        vecs.append(jnp.asarray(v))
    return vecs


def test_sparse_traffic_from_actual_pairs():
    """Sparse traffic is Σ_threads 2·pairs + V, with pairs = the static
    capacity of the budget-k compression — never a dense-sum figure."""
    V, N, k = 1024, 4, 8
    vecs = _sparse_vecs(V, N)
    out, sp = run_round(AccumMode.SPARSE, vecs, k=k)
    P = pair_capacity(V, k)
    assert sp.last_pair_counts == [P] * N
    assert sp.bytes_transferred == N * 2 * P + V
    np.testing.assert_allclose(out, np.sum(np.stack(vecs), axis=0))  # lossless


def test_fused_reduce_matches_unfused_bitexact():
    """fused=True (one sparsify→scatter-add kernel launch) must be bit-exact
    with the historical compress→densify→add path, and carry identical pair
    counts + wire accounting — fusion is an implementation detail, never a
    semantics change."""
    V, N, k = 1024, 4, 8
    for vecs in (_sparse_vecs(V, N),                       # lossless round
                 [jnp.asarray(np.random.default_rng(i).normal(size=V)
                              .astype(np.float32)) for i in range(N)]):  # lossy
        out_f, acc_f = run_round(AccumMode.SPARSE, vecs, k=k, fused=True)
        out_u, acc_u = run_round(AccumMode.SPARSE, vecs, k=k, fused=False)
        assert np.array_equal(out_f, out_u)
        assert acc_f.last_pair_counts == acc_u.last_pair_counts
        assert acc_f.bytes_transferred == acc_u.bytes_transferred


def test_sparse_requires_budget():
    store = GlobalStore()
    store.new_array("out", (8,))
    with pytest.raises(ValueError, match="top-k budget"):
        DAddAccumulator(store, "out", 2, 2, AccumMode.SPARSE)


def test_sparse_is_lossy_beyond_budget():
    """nnz > capacity: the round keeps only the top-k pairs per thread —
    same lossy semantics as the SPMD collective, not a silent dense sum."""
    V, k, N = 256, 4, 2
    rng = np.random.default_rng(0)
    vec = jnp.asarray(rng.normal(size=(V,)), jnp.float32)   # fully dense
    out, acc = run_round(AccumMode.SPARSE, [vec, vec], k=k)
    P = pair_capacity(V, k)
    assert int(np.sum(out != 0)) <= P          # top-k survived, rest dropped
    assert acc.bytes_transferred == N * 2 * P + V
    # the kept entries are the k largest-|x|
    top = np.argsort(-np.abs(np.asarray(vec)))[:P]
    np.testing.assert_allclose(out[top], 2 * np.asarray(vec)[top], rtol=1e-6)


def test_auto_crossover_dense_vs_pairs():
    """AUTO takes the pairs path iff every contribution is losslessly
    compressible AND cheaper; accounting follows the branch actually taken."""
    V, N, k = 1024, 4, 8
    sparse_vecs = _sparse_vecs(V, N)
    out, auto = run_round(AccumMode.AUTO, sparse_vecs, k=k)
    assert auto.last_mode == AccumMode.SPARSE
    assert auto.bytes_transferred == N * 2 * pair_capacity(V, k) + V
    np.testing.assert_allclose(out, np.sum(np.stack(sparse_vecs), axis=0))

    dense_vecs = [jnp.ones((V,)) for _ in range(N)]
    out2, auto2 = run_round(AccumMode.AUTO, dense_vecs, k=k)
    assert auto2.last_mode == AccumMode.REDUCE_SCATTER
    assert auto2.bytes_transferred == (N + 1) * V
    np.testing.assert_allclose(out2, N)

    # one dense thread among sparse ones forces the dense branch (global AND)
    mixed = sparse_vecs[:-1] + [jnp.ones((V,))]
    _, auto3 = run_round(AccumMode.AUTO, mixed, k=k)
    assert auto3.last_mode == AccumMode.REDUCE_SCATTER


def test_auto_defaults_budget_when_unset():
    """AUTO without an explicit k resolves a ~V/4 default per round and still
    crosses over; results are unchanged (auto is lossless by construction)."""
    V, N = 1024, 4
    out, auto = run_round(AccumMode.AUTO, _sparse_vecs(V, N))   # k=None
    assert auto.last_mode == AccumMode.SPARSE
    np.testing.assert_allclose(out, np.sum(np.stack(_sparse_vecs(V, N)), axis=0))
    assert auto.bytes_transferred < (N + 1) * V                 # pairs won


def test_ragged_contribution_is_an_error():
    """All threads must contribute equal-length vectors; a ragged one aborts
    the round instead of mis-accounting vec_len from the last arrival."""
    store = GlobalStore()
    store.new_array("out", (8,))
    acc = DAddAccumulator(store, "out", 2, 2, AccumMode.REDUCE_SCATTER)
    peer_errors = []

    def peer():
        try:
            acc.accumulate(jnp.ones(8))
        except threading.BrokenBarrierError as e:
            peer_errors.append(e)

    t = threading.Thread(target=peer)
    t.start()
    deadline = time.time() + 10
    while acc._count == 0 and time.time() < deadline:
        time.sleep(0.005)           # peer's contribution opens the round
    with pytest.raises(ValueError, match="ragged"):
        acc.accumulate(jnp.ones(4))
    t.join(10)                      # barrier was aborted: peer released
    assert not t.is_alive() and len(peer_errors) == 1
    # the poisoned round was dropped: no partial state, nothing stored
    assert acc._count == 0 and acc._vecs == [] and acc._partial is None
    assert acc.rounds == 0
    np.testing.assert_allclose(np.asarray(store.get("out")), 0.0)
    # and the accumulator is poisoned: a retry must NOT publish to the store
    # against a barrier that stays broken
    with pytest.raises(RuntimeError, match="aborted"):
        acc.accumulate(jnp.ones(8))
    assert acc.rounds == 0
    np.testing.assert_allclose(np.asarray(store.get("out")), 0.0)


def test_same_size_different_shape_is_ragged():
    """(8, 1) vs (8,) has equal size but must not broadcast into a silently
    wrong (8, 8) total — the shape guard catches it."""
    store = GlobalStore()
    store.new_array("out", (8,))
    acc = DAddAccumulator(store, "out", 2, 2, AccumMode.REDUCE_SCATTER)
    peer_errors = []

    def peer():
        try:
            acc.accumulate(jnp.ones((8, 1)))
        except threading.BrokenBarrierError as e:
            peer_errors.append(e)

    t = threading.Thread(target=peer)
    t.start()
    deadline = time.time() + 10
    while acc._count == 0 and time.time() < deadline:
        time.sleep(0.005)
    with pytest.raises(ValueError, match="ragged"):
        acc.accumulate(jnp.ones(8))
    t.join(10)
    assert not t.is_alive() and len(peer_errors) == 1


def test_sparse_auto_scalar_and_matrix_contributions():
    """Scalars and rank>=2 contributions ride the sparse/auto path flattened
    (as the SPMD ctx normalises ranks), with the round shape restored."""
    store = GlobalStore()
    store.def_global("s", 0.0)
    acc = DAddAccumulator(store, "s", 2, 2, AccumMode.AUTO)
    ts = [threading.Thread(target=acc.accumulate, args=(jnp.asarray(v),))
          for v in (2.0, 3.0)]
    [t.start() for t in ts]
    [t.join(10) for t in ts]
    assert float(store.get("s")) == 5.0
    assert acc.last_mode == AccumMode.REDUCE_SCATTER   # 2·cap < 1 never holds

    store.new_array("m", (4, 8))
    accm = DAddAccumulator(store, "m", 2, 2, AccumMode.SPARSE, k=4)
    mat = np.zeros((4, 8), np.float32)
    mat[1, 2] = 5.0
    mat[3, 7] = -1.0
    ts = [threading.Thread(target=accm.accumulate, args=(jnp.asarray(mat),))
          for _ in range(2)]
    [t.start() for t in ts]
    [t.join(10) for t in ts]
    got = np.asarray(store.get("m"))
    assert got.shape == (4, 8)
    np.testing.assert_allclose(got, 2 * mat)           # nnz=2 <= k: lossless


def test_reduce_failure_releases_waiters():
    """An exception inside the round reduction (here: an invalid AUTO budget)
    must abort the barrier instead of stranding the other threads forever."""
    store = GlobalStore()
    store.new_array("out", (8,))
    acc = DAddAccumulator(store, "out", 2, 2, AccumMode.AUTO, k=0)
    peer_errors = []

    def peer():
        try:
            acc.accumulate(jnp.ones(8))
        except threading.BrokenBarrierError as e:
            peer_errors.append(e)

    t = threading.Thread(target=peer)
    t.start()
    deadline = time.time() + 10
    while acc._count == 0 and time.time() < deadline:
        time.sleep(0.005)
    with pytest.raises(ValueError, match="budget"):
        acc.accumulate(jnp.ones(8))   # last arrival runs the failing reduce
    t.join(10)
    assert not t.is_alive() and len(peer_errors) == 1


def test_multi_round():
    V, N = 32, 3
    store = GlobalStore()
    store.new_array("out", (V,))
    acc = DAddAccumulator(store, "out", N, 2, AccumMode.REDUCE_SCATTER)

    def worker():
        for _ in range(3):
            acc.accumulate(jnp.ones((V,)))

    ts = [threading.Thread(target=worker) for _ in range(N)]
    [t.start() for t in ts]
    [t.join(10) for t in ts]
    assert acc.rounds == 3
    np.testing.assert_allclose(np.asarray(store.get("out")), N)


def test_multi_round_sparse_accounting_resets():
    """Pair accounting is per-round: a sparse round followed by another must
    not reuse the previous round's pair list (the old _nnzs reuse bug)."""
    V, N, k = 512, 2, 4
    store = GlobalStore()
    store.new_array("out", (V,))
    acc = DAddAccumulator(store, "out", N, 2, AccumMode.SPARSE, k=k)
    v = np.zeros(V, np.float32)
    v[:2] = 1.0
    vec = jnp.asarray(v)

    def worker():
        for _ in range(3):
            acc.accumulate(vec)

    ts = [threading.Thread(target=worker) for _ in range(N)]
    [t.start() for t in ts]
    [t.join(10) for t in ts]
    P = pair_capacity(V, k)
    assert acc.rounds == 3
    assert acc.bytes_transferred == 3 * (N * 2 * P + V)
    assert acc.last_pair_counts == [P] * N
