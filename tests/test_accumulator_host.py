"""DAddAccumulator host layer: correctness + the paper's traffic formulas."""

import threading

import jax.numpy as jnp
import numpy as np

from repro.core import AccumMode, DAddAccumulator, GlobalStore


def run_round(mode, vecs, n_nodes=2):
    n = len(vecs)
    store = GlobalStore()
    store.new_array("out", (vecs[0].size,))
    acc = DAddAccumulator(store, "out", n, n_nodes, mode)
    ts = [threading.Thread(target=acc.accumulate, args=(v,)) for v in vecs]
    [t.start() for t in ts]
    [t.join(10) for t in ts]
    return np.asarray(store.get("out")), acc


def test_sum_correct_all_modes():
    vecs = [jnp.full((64,), float(i + 1)) for i in range(4)]
    expect = np.full(64, 1.0 + 2 + 3 + 4)
    for mode in AccumMode:
        out, _ = run_round(mode, vecs)
        np.testing.assert_allclose(out, expect)


def test_traffic_formulas():
    """Paper §5.2: (2N+1)·V naive vs (N+1)·V accumulator."""
    V, N = 128, 4
    vecs = [jnp.ones((V,)) for _ in range(N)]
    _, naive = run_round(AccumMode.GATHER_ALL, vecs)
    _, rs = run_round(AccumMode.REDUCE_SCATTER, vecs)
    assert naive.bytes_transferred == (2 * N + 1) * V
    assert rs.bytes_transferred == (N + 1) * V
    assert rs.bytes_transferred < naive.bytes_transferred


def test_sparse_and_auto_traffic():
    V, N = 1024, 4
    sparse_vecs = []
    for i in range(N):
        v = np.zeros(V, np.float32)
        v[i * 3: i * 3 + 3] = 1.0
        sparse_vecs.append(jnp.asarray(v))
    _, sp = run_round(AccumMode.SPARSE, sparse_vecs)
    assert sp.bytes_transferred == sum(2 * 3 for _ in range(N)) + V
    _, auto = run_round(AccumMode.AUTO, sparse_vecs)
    assert auto.bytes_transferred <= (N + 1) * V  # picks the cheaper path
    dense_vecs = [jnp.ones((V,)) for _ in range(N)]
    _, auto2 = run_round(AccumMode.AUTO, dense_vecs)
    assert auto2.bytes_transferred == (N + 1) * V


def test_multi_round():
    V, N = 32, 3
    store = GlobalStore()
    store.new_array("out", (V,))
    acc = DAddAccumulator(store, "out", N, 2, AccumMode.REDUCE_SCATTER)

    def worker():
        for _ in range(3):
            acc.accumulate(jnp.ones((V,)))

    ts = [threading.Thread(target=worker) for _ in range(N)]
    [t.start() for t in ts]
    [t.join(10) for t in ts]
    assert acc.rounds == 3
    np.testing.assert_allclose(np.asarray(store.get("out")), N)
