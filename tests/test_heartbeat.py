"""Heartbeat failure detector (paper §5.4)."""

import time

from repro.ft import HeartbeatMonitor


def test_detects_silent_node():
    dead = []
    mon = HeartbeatMonitor([0, 1], timeout=0.15, check_interval=0.02,
                           on_failure=lambda d: dead.extend(d))
    mon.start()
    t0 = time.monotonic()
    while time.monotonic() - t0 < 0.4:
        mon.beat(0)   # node 1 never beats
        time.sleep(0.02)
    mon.stop()
    assert dead == [1]
    assert mon.dead_nodes() == [1]


def test_pause_resume_virtual_barrier():
    mon = HeartbeatMonitor([0], timeout=10)
    assert not mon.should_pause()
    mon.pause()
    assert mon.should_pause()
    mon.resume()
    assert not mon.should_pause()


def test_declare_and_revive():
    dead = []
    mon = HeartbeatMonitor([0, 1], timeout=10, on_failure=lambda d: dead.extend(d))
    mon.declare_dead(0)
    assert dead == [0]
    mon.revive(0)
    assert mon.dead_nodes() == []
