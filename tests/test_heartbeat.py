"""Heartbeat failure detector (paper §5.4)."""

import time

from repro.ft import HeartbeatMonitor


def test_detects_silent_node():
    dead = []
    mon = HeartbeatMonitor([0, 1], timeout=0.15, check_interval=0.02,
                           on_failure=lambda d: dead.extend(d))
    mon.start()
    t0 = time.monotonic()
    while time.monotonic() - t0 < 0.4:
        mon.beat(0)   # node 1 never beats
        time.sleep(0.02)
    mon.stop()
    assert dead == [1]
    assert mon.dead_nodes() == [1]


def test_pause_resume_virtual_barrier():
    mon = HeartbeatMonitor([0], timeout=10)
    assert not mon.should_pause()
    mon.pause()
    assert mon.should_pause()
    mon.resume()
    assert not mon.should_pause()


def test_declare_and_revive():
    dead = []
    mon = HeartbeatMonitor([0, 1], timeout=10, on_failure=lambda d: dead.extend(d))
    mon.declare_dead(0)
    assert dead == [0]
    mon.revive(0)
    assert mon.dead_nodes() == []


def test_beat_carries_metrics_payload():
    """Heartbeats piggyback a metrics snapshot; the master reads the latest
    per node, and a dead node's payload stops updating."""
    import jax.numpy as jnp

    from repro.core import Session
    from repro.ft import metrics_payload

    mon = HeartbeatMonitor([0, 1], timeout=10)
    sess = Session(backend="host", n_nodes=2, threads_per_node=1, trace=True)
    try:
        ref = sess.new_array("v", (8,))
        sess.run(lambda ctx, xs: ref.accumulate(xs.sum(axis=0)),
                 data=(jnp.ones((2, 8)),))
        mon.beat(0, payload=metrics_payload(sess))
        mon.beat(1, payload={"custom": 1})
        p0 = mon.last_payload(0)
        assert p0["trace_enabled"] and p0["wire_traffic"] == sess.wire_traffic()
        assert p0["barrier_wait_us"]["count"] >= 2
        assert mon.payloads()[1] == {"custom": 1}
        # payloads are optional: a bare beat keeps the previous payload
        mon.beat(0)
        assert mon.last_payload(0) is p0
        # dead nodes stop updating
        mon.declare_dead(1)
        mon.beat(1, payload={"custom": 2})
        assert mon.last_payload(1) == {"custom": 1}
    finally:
        sess.tracer.disable()
