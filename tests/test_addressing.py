"""DSM address space (paper §5.1)."""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core.addressing import (
    OBJECT_ID_BITS, PACKAGE_WORDS, AddressAllocator, align_up, block_address,
    make_address, package_id, split_address, watcher_node,
)


@given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
def test_address_roundtrip(oid, fid):
    assert split_address(make_address(oid, fid)) == (oid, fid)


def test_address_layout():
    addr = make_address(3, 7)
    assert addr == (3 << 32) | 7
    with pytest.raises(ValueError):
        make_address(2**32, 0)


def test_coarse_allocation_is_package_aligned():
    alloc = AddressAllocator(coarse=True)
    oid = alloc.new_object()
    s1 = alloc.alloc_field(oid, 5)
    s2 = alloc.alloc_field(oid, 3)
    assert s1.field_id % PACKAGE_WORDS == 0
    assert s2.field_id % PACKAGE_WORDS == 0
    assert s2.field_id >= s1.field_id + 5


def test_fine_allocation_is_dense():
    alloc = AddressAllocator(coarse=False)
    oid = alloc.new_object()
    s1 = alloc.alloc_field(oid, 5)
    s2 = alloc.alloc_field(oid, 3)
    assert s2.field_id == s1.field_id + 5


@given(st.integers(0, 2**40), st.integers(1, 64))
def test_watcher_node_in_range(addr, n):
    assert 0 <= watcher_node(addr, n) < n
    assert block_address(addr) == addr >> 5


def test_align_up():
    assert align_up(0, 32) == 0
    assert align_up(1, 32) == 32
    assert align_up(32, 32) == 32
