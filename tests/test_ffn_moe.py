"""FFN + MoE: gather dispatch vs dense oracle, shared experts, aux loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ffn import MoEConfig, dense_ffn, init_dense_ffn, init_moe, moe_ffn


def test_dense_ffn_kinds():
    for kind, keys in (("swiglu", {"w_gate", "w_up", "w_down"}),
                       ("gelu", {"w_in", "w_out"})):
        p = init_dense_ffn(jax.random.PRNGKey(0), 16, 32, kind=kind)
        assert set(p) == keys
        out = dense_ffn(p, jnp.ones((2, 3, 16)), kind=kind)
        assert out.shape == (2, 3, 16)


def test_gelu_bias():
    p = init_dense_ffn(jax.random.PRNGKey(0), 16, 32, kind="gelu", bias=True)
    assert {"b_in", "b_out"} <= set(p)


@pytest.mark.parametrize("groups", [1, 2])
def test_moe_gather_matches_dense(groups):
    """With capacity high enough that nothing drops, gather == dense oracle."""
    cfg_g = MoEConfig(d_model=16, n_experts=4, top_k=2, d_ff_expert=8,
                      capacity_factor=8.0, impl="gather", data_groups=groups)
    cfg_d = cfg_g._replace(impl="dense")
    p = init_moe(jax.random.PRNGKey(0), cfg_g)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    yg, aux_g = moe_ffn(p, x, cfg_g)
    yd, aux_d = moe_ffn(p, x, cfg_d)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yd), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_g), float(aux_d), rtol=1e-5)


def test_moe_capacity_drops_tokens():
    cfg = MoEConfig(d_model=8, n_experts=2, top_k=1, d_ff_expert=8,
                    capacity_factor=0.1, impl="gather")  # capacity 1 per expert
    p = init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.ones((1, 16, 8))
    y, _ = moe_ffn(p, x, cfg)
    assert y.shape == (1, 16, 8)  # dropped tokens contribute 0, no crash


def test_shared_expert_adds():
    cfg0 = MoEConfig(d_model=8, n_experts=2, top_k=1, d_ff_expert=8,
                     capacity_factor=4.0, impl="dense", n_shared=0)
    cfg1 = cfg0._replace(n_shared=1)
    p = init_moe(jax.random.PRNGKey(2), cfg1)
    x = jnp.ones((1, 4, 8))
    y0, _ = moe_ffn({k: v for k, v in p.items() if k != "shared"}, x, cfg0)
    y1, _ = moe_ffn(p, x, cfg1)
    shared_out = dense_ffn(p["shared"], x.reshape(4, 8), kind="swiglu").reshape(1, 4, 8)
    np.testing.assert_allclose(np.asarray(y1 - y0), np.asarray(shared_out),
                               rtol=1e-4, atol=1e-5)


def test_aux_loss_uniform_router_is_one_weighted():
    """Perfectly balanced routing gives aux ≈ weight·E·Σ(1/E·1/E)·E = weight."""
    cfg = MoEConfig(d_model=8, n_experts=4, top_k=1, d_ff_expert=8,
                    impl="dense", aux_loss_weight=1.0)
    p = init_moe(jax.random.PRNGKey(3), cfg)
    p = dict(p, router=jnp.zeros((8, 4)))   # uniform probs
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, 64, 8)), jnp.float32)
    _, aux = moe_ffn(p, x, cfg)
    np.testing.assert_allclose(float(aux), 1.0, rtol=0.15)
