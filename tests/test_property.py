"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import pack_spec, pack_tree, unpack_tree
from repro.core.sparse import blocked_topk_sparsify, densify, sparse_beneficial
from repro.launch.shardings import sanitize_spec
from jax.sharding import PartitionSpec as P


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 8), st.integers(1, 8)), min_size=1, max_size=5),
       st.integers(0, 5))
def test_pack_unpack_roundtrip_2d(shapes, seed):
    rng = np.random.default_rng(seed)
    tree = {f"l{i}": jnp.asarray(rng.normal(size=s), jnp.float32)
            for i, s in enumerate(shapes)}
    spec = pack_spec(tree)
    back = unpack_tree(pack_tree(tree, spec), spec)
    for k in tree:
        np.testing.assert_allclose(np.asarray(back[k]), np.asarray(tree[k]), rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 12), st.integers(0, 5))
def test_sparse_lossless_when_under_budget(nnz, seed):
    """densify(topk(x)) == x whenever nnz(x) <= k (the auto-mode guarantee)."""
    rng = np.random.default_rng(seed)
    v = np.zeros(256, np.float32)
    pos = rng.choice(256, size=nnz, replace=False)
    v[pos] = rng.normal(size=nnz)
    x = jnp.asarray(v)
    k = 16
    idx, vals = blocked_topk_sparsify(x, k)
    np.testing.assert_allclose(np.asarray(densify(idx, vals, 256)), v, rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 64), st.integers(1, 8))
def test_sanitize_spec_always_divides(dim, axis_size):
    class FakeMesh:
        shape = {"data": axis_size}
        axis_names = ("data",)
    spec = sanitize_spec(P("data"), (dim,), FakeMesh())
    if spec[0] is not None:
        assert dim % axis_size == 0


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 5))
def test_accumulator_linearity(seed):
    """accumulate is a linear operator: sum of parts == part of sums."""
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(64,)).astype(np.float32)
    b = rng.normal(size=(64,)).astype(np.float32)
    from repro.kernels.accumulate.ref import accumulate_ref
    lhs = accumulate_ref(jnp.stack([jnp.asarray(a + b)]))
    rhs = accumulate_ref(jnp.stack([jnp.asarray(a)])) + accumulate_ref(jnp.stack([jnp.asarray(b)]))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.lists(st.text(alphabet="abcdefgh", min_size=1, max_size=8),
                min_size=1, max_size=20, unique=True),
       st.integers(0, 25), st.integers(0, 10 ** 6))
def test_crash_mid_migration_loses_and_duplicates_nothing(keys, steps, seed):
    """step.tiers satellite: kill a session inside an open migration window
    at an arbitrary drain point — session_recovery must complete the handoff
    with every key present exactly once and every value intact."""
    from repro.core import Session
    from repro.ft import session_recovery

    sess = Session(backend="host", n_nodes=2, threads_per_node=1, shards=2)
    vals = {f"hz_{k}": float((seed + i) % 977)
            for i, k in enumerate(keys)}
    for k, v in vals.items():
        sess.store.def_global(k, jnp.full((4,), v))
    sess.store.add_shard(9, drain=False)             # window opens
    sess.store.migrate_step(steps)                   # partial drain
    plan, new_sess = session_recovery(sess, [1])     # crash strikes here
    assert new_sess.store.migration_window is None
    assert sorted(new_sess.store.names()) == sorted(vals)
    for k, v in vals.items():
        np.testing.assert_allclose(np.asarray(new_sess.store.get(k)), v)
