"""DBarrier / DSemaphore / SSP clock (paper §4.3/§5.3)."""

import threading
import time

from repro.core import DBarrier, DSemaphore, SSPClock


def test_barrier_releases_all():
    b = DBarrier(4)
    done = []

    def worker(i):
        assert b.Enter()
        done.append(i)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    [t.start() for t in ts]
    [t.join(5) for t in ts]
    assert sorted(done) == [0, 1, 2, 3]
    assert b.entries == 4


def test_barrier_timeout():
    b = DBarrier(2)
    assert b.enter(timeout=0.05) is False  # nobody else arrives


def test_semaphore_counts():
    s = DSemaphore(2)
    assert s.Acquire() and s.Acquire()
    assert s.Acquire(timeout=0.05) is False
    s.Release()
    assert s.Acquire(timeout=1)


def test_semaphore_fifo_wakeup():
    s = DSemaphore(0)
    order = []

    def worker(i):
        s.acquire()
        order.append(i)

    ts = []
    for i in range(3):
        t = threading.Thread(target=worker, args=(i,))
        t.start()
        ts.append(t)
        time.sleep(0.05)  # enforce queue order
    for _ in range(3):
        s.release()
        time.sleep(0.05)
    [t.join(5) for t in ts]
    assert order == [0, 1, 2]


def test_ssp_bounded_staleness():
    c = SSPClock(2, staleness=1)
    c.tick(0); c.tick(0)
    # worker 0 is 2 ahead of worker 1: must block
    assert c.wait(0, timeout=0.05) is False
    c.tick(1)
    assert c.wait(0, timeout=1)


def test_ssp_drop_worker_unblocks():
    c = SSPClock(2, staleness=0)
    c.tick(0)
    assert c.wait(0, timeout=0.05) is False
    c.drop_worker(1)
    assert c.wait(0, timeout=1)
