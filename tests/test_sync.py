"""DBarrier / DSemaphore / SSP clock (paper §4.3/§5.3)."""

import threading
import time

from repro.core import DBarrier, DSemaphore, SSPClock


def test_barrier_releases_all():
    b = DBarrier(4)
    done = []

    def worker(i):
        assert b.Enter()
        done.append(i)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    [t.start() for t in ts]
    [t.join(5) for t in ts]
    assert sorted(done) == [0, 1, 2, 3]
    assert b.entries == 4


def test_barrier_timeout():
    b = DBarrier(2)
    assert b.enter(timeout=0.05) is False  # nobody else arrives


def test_semaphore_counts():
    s = DSemaphore(2)
    assert s.Acquire() and s.Acquire()
    assert s.Acquire(timeout=0.05) is False
    s.Release()
    assert s.Acquire(timeout=1)


def test_semaphore_fifo_wakeup():
    s = DSemaphore(0)
    order = []

    def worker(i):
        s.acquire()
        order.append(i)

    ts = []
    for i in range(3):
        t = threading.Thread(target=worker, args=(i,))
        t.start()
        ts.append(t)
        time.sleep(0.05)  # enforce queue order
    for _ in range(3):
        s.release()
        time.sleep(0.05)
    [t.join(5) for t in ts]
    assert order == [0, 1, 2]


def test_ssp_bounded_staleness():
    c = SSPClock(2, staleness=1)
    c.tick(0); c.tick(0)
    # worker 0 is 2 ahead of worker 1: must block
    assert c.wait(0, timeout=0.05) is False
    c.tick(1)
    assert c.wait(0, timeout=1)


def test_ssp_drop_worker_unblocks():
    c = SSPClock(2, staleness=0)
    c.tick(0)
    assert c.wait(0, timeout=0.05) is False
    c.drop_worker(1)
    assert c.wait(0, timeout=1)


def test_ssp_drop_worker_releases_concurrent_waiters():
    """FT: several waiters blocked on one straggler must ALL release when the
    straggler's node is declared dead (no survivor left hanging)."""
    c = SSPClock(4, staleness=0)
    released = []

    def fast(tid):
        c.tick(tid)
        assert c.wait(tid, timeout=5)
        released.append(tid)

    ts = [threading.Thread(target=fast, args=(i,)) for i in (0, 1, 2)]
    [t.start() for t in ts]
    time.sleep(0.1)
    assert released == []            # everyone blocked on worker 3
    c.drop_worker(3)                 # heartbeat declares it dead
    [t.join(5) for t in ts]
    assert sorted(released) == [0, 1, 2]
    assert c.min_clock() == 1


def test_ssp_add_worker_rejoins_at_min_clock_and_is_waited_on():
    """FT: a replacement worker enters at the min clock and immediately
    participates in the staleness bound — survivors block on it again."""
    c = SSPClock(2, staleness=0)
    c.tick(0)
    c.drop_worker(1)
    assert c.wait(0, timeout=1)      # alone, nothing to wait for
    c.add_worker(2)                  # replacement thread (new tid)
    assert c._clocks[2] == c.min_clock() == 1

    blocked = threading.Event()
    done = threading.Event()

    def ahead():
        c.tick(0)
        blocked.set()
        assert c.wait(0, timeout=5)
        done.set()

    t = threading.Thread(target=ahead)
    t.start()
    blocked.wait(5)
    time.sleep(0.05)
    assert not done.is_set()         # blocked on the rejoined worker
    c.tick(2)
    t.join(5)
    assert done.is_set()


def test_semaphore_timeout_removes_fifo_ticket():
    """FT: a waiter that times out must leave the FIFO queue, otherwise its
    stale head ticket starves every later waiter."""
    s = DSemaphore(0)
    got = {}

    def short():
        got["short"] = s.acquire(timeout=0.1)

    def long():
        got["long"] = s.acquire(timeout=5)

    t1 = threading.Thread(target=short)
    t1.start()
    time.sleep(0.03)                 # short is queued first (FIFO head)
    t2 = threading.Thread(target=long)
    t2.start()
    t1.join(5)
    assert got["short"] is False     # timed out, ticket withdrawn
    s.release()                      # must wake `long`, not the dead head
    t2.join(5)
    assert got["long"] is True
    assert len(s._queue) == 0


def test_semaphore_timeout_mid_queue_preserves_fifo_order():
    s = DSemaphore(0)
    order = []

    def waiter(name, timeout):
        if s.acquire(timeout=timeout):
            order.append(name)

    threads = []
    for name, timeout in (("a", 5), ("dead", 0.1), ("b", 5)):
        t = threading.Thread(target=waiter, args=(name, timeout))
        t.start()
        threads.append(t)
        time.sleep(0.03)             # enforce queue order a < dead < b
    time.sleep(0.15)                 # "dead" times out mid-queue
    s.release()
    s.release()
    [t.join(5) for t in threads]
    assert order == ["a", "b"]


# -- negative timeout == block forever (paper-cased Enter/Acquire default) ----


def test_barrier_enter_negative_timeout_blocks_until_release():
    """``Enter(timeout=-1)`` (the paper's default) must block indefinitely —
    not raise, not return False after 0 seconds — and release normally once
    the arity is met."""
    b = DBarrier(2)
    state = {}

    def waiter():
        state["ok"] = b.Enter(-1)

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.2)
    assert t.is_alive()              # still parked: -1 never timed out
    assert b.Enter(-1) is True       # second arrival releases both
    t.join(5)
    assert not t.is_alive() and state["ok"] is True
    # the snake-cased API treats any negative the same way
    b2 = DBarrier(1)
    assert b2.enter(timeout=-3.5) is True


def test_semaphore_acquire_negative_timeout_blocks_until_release():
    s = DSemaphore(0)
    state = {}

    def waiter():
        state["ok"] = s.Acquire(-1)

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.2)
    assert t.is_alive()              # parked forever, not timed out
    s.release()
    t.join(5)
    assert not t.is_alive() and state["ok"] is True
    assert s._count == 0             # the hand-off consumed the permit
