"""step.obs: flight recorder, watchdog anomalies, OpenMetrics export.

Covers the PR's acceptance demos — a seeded stalled migration window and a
seeded slow-barrier straggler each detected within their deadline, with a
non-empty flight-recorder dump that round-trips ``json`` — plus the
satellites: the Hist reservoir late-outlier regression, pinned heartbeat
payload keys, and metrics read concurrently with an open migration window.
"""

import importlib.util
import json
import os
import threading
import time

import jax.numpy as jnp
import pytest

from repro.core import telemetry
from repro.core.session import Session
from repro.core.telemetry import Hist, RingSink, Tracer
from repro.obs import (ANOMALY_KINDS, SEVERITIES, Anomaly, FlightRecorder,
                       Watchdog, as_recorder, openmetrics)


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------


def test_recorder_arms_record_only_and_close_disarms():
    trc = Tracer(enabled=False)
    rec = FlightRecorder(capacity=64)
    rec.attach(trc)
    assert trc.enabled and trc.record_only and rec.armed
    assert trc.ring is not None and trc.ring.capacity == 64
    assert telemetry.armed_count() == 1
    rec.close()
    assert not trc.enabled and not trc.record_only and not rec.armed
    assert telemetry.armed_count() == 0


def test_recorder_leaves_user_enabled_tracer_running():
    trc = Tracer(enabled=True)
    try:
        rec = FlightRecorder()
        rec.attach(trc)
        assert not trc.record_only          # full tracing continues
        assert rec.armed                    # but the ring is hung off it
        rec.close()
        assert trc.enabled                  # close only undoes what it did
    finally:
        trc.disable()


def test_ring_sink_bounded_overwrite_oldest():
    ring = RingSink(capacity=4)
    for i in range(6):
        ring.append({"i": i})
    assert len(ring) == 4 and ring.total == 6
    assert [e["i"] for e in ring.snapshot()] == [2, 3, 4, 5]
    with pytest.raises(ValueError):
        RingSink(capacity=0)


def test_record_only_fast_ops_leave_no_events():
    trc = Tracer(enabled=False)
    rec = FlightRecorder()
    rec.attach(trc)
    try:
        t0 = trc.now()
        trc.store_op("get", 0, t0)          # microseconds: under slow_us
        snap = trc.snapshot()
        assert snap["events"] == 0          # unbounded list never grows
        assert snap["ops"]["store.get"]["count"] == 1  # hist still fed
        trc.mark("migration", "window.open", pending=3)
        names = [e["name"] for e in rec.events()]
        assert "window.open" in names       # marks always reach the ring
    finally:
        rec.close()


def test_record_only_slow_span_reaches_ring():
    trc = Tracer(enabled=False)
    rec = FlightRecorder(slow_us=10.0)      # 10µs threshold for the test
    rec.attach(trc)
    try:
        t0 = trc.now()
        time.sleep(0.005)
        trc.add_span("store-op", "store.get", t0, trc.now())
        assert any(e["name"] == "store.get" for e in rec.events())
        assert trc.snapshot()["events"] == 0
    finally:
        rec.close()


def test_dump_round_trips_json():
    trc = Tracer(enabled=False)
    rec = FlightRecorder()
    rec.attach(trc)
    try:
        trc.mark("lifecycle", "hello", n=1)
        dump = rec.dump(reason="unit")
        blob = json.dumps(dump)
        back = json.loads(blob)
        assert back["reason"] == "unit"
        assert back["ring"]["held"] >= 1
        assert any(e["name"] == "hello" for e in back["events"])
    finally:
        rec.close()


def test_recorder_export_writes_json(tmp_path):
    trc = Tracer(enabled=False)
    rec = FlightRecorder()
    rec.attach(trc)
    try:
        trc.mark("anomaly", "synthetic")
        path = rec.export(str(tmp_path / "dump.json"), reason="export-test")
        data = json.load(open(path))
        assert data["reason"] == "export-test"
        assert data["events"]
    finally:
        rec.close()


def test_as_recorder_resolution():
    assert as_recorder(True).enabled
    assert not as_recorder(False).enabled
    assert not as_recorder(None).enabled
    rec = FlightRecorder(capacity=8)
    assert as_recorder(rec) is rec


def test_session_record_true_end_to_end():
    sess = Session(backend="host", shards=2, record=True)
    try:
        ref = sess.new_array("obs_x", (32,))
        ref.set(jnp.ones(32))
        ref.get()
        m = sess.metrics()
        assert m["trace"]["record_only"]
        assert m["trace"]["ring"] is not None
        assert m["trace"]["ops"]["store.set"]["count"] >= 1
    finally:
        sess.recorder.close()
    assert telemetry.armed_count() == 0


# ---------------------------------------------------------------------------
# Hist reservoir (satellite: late-run outliers must still move p99)
# ---------------------------------------------------------------------------


def test_hist_reservoir_late_outliers_move_p99():
    h = Hist()
    for _ in range(100_000):
        h.add(100.0)
    snap = h.snapshot()
    assert snap["p99"] == 100.0
    # 5k outliers arriving AFTER the 4096-sample reservoir filled: under the
    # old keep-first-N cap these were invisible; Algorithm R keeps ~4.8% of
    # the stream as outliers, so p99 (the top 1%) must move
    for _ in range(5_000):
        h.add(10_000.0)
    snap = h.snapshot()
    assert snap["p99"] == 10_000.0
    assert snap["p50"] == 100.0             # the median must NOT move
    assert snap["count"] == 105_000
    assert snap["max"] == 10_000.0


def test_hist_reservoir_deterministic():
    a, b = Hist(), Hist()
    vals = [float((i * 37) % 1013) for i in range(20_000)]
    for v in vals:
        a.add(v)
        b.add(v)
    assert a.snapshot() == b.snapshot()     # seeded xorshift: no run jitter


# ---------------------------------------------------------------------------
# watchdog: the two acceptance demos
# ---------------------------------------------------------------------------


def test_watchdog_detects_stalled_migration_window():
    sess = Session(backend="host", shards=2, record=True)
    try:
        for i in range(48):
            sess.new_array(f"mig{i}", (16,))
        mig = sess.store.add_shard(drain=False)     # seed the stall
        assert mig is None or sess.store.migration_window is not None
        win = sess.store.migration_window
        assert win is not None and win.remaining > 0
        wd = sess.watchdog(migration_deadline_s=0.15)
        assert wd.poll_once() == []                 # first poll: baseline
        deadline = time.monotonic() + 5.0
        fired = []
        while not fired and time.monotonic() < deadline:
            time.sleep(0.05)
            fired = wd.poll_once()
        assert fired, "stalled window not detected within deadline"
        a = fired[0]
        assert a.kind == "stalled-migration" and a.severity == "error"
        assert a.details["remaining"] > 0
        # the dump is the acceptance artifact: non-empty, json-round-trips
        assert a.dump is not None and a.dump["events"]
        assert any(e["name"] == "window.open" for e in a.dump["events"])
        back = json.loads(json.dumps(a.as_dict()))
        assert back["kind"] == "stalled-migration"
        # progress resets the stall clock: drain and verify no re-fire
        sess.store.drain_window()
        wd._seen.clear()
        assert wd.poll_once() == []
    finally:
        sess.store.drain_window()
        sess.recorder.close()


def test_watchdog_detects_slow_barrier_straggler():
    sess = Session(backend="host", record=True)
    try:
        bar = sess.barrier(2)                       # seeded straggler: one
        done = threading.Event()                    # enter, partner never comes

        def straggler():
            bar.enter(timeout=10.0)
            done.set()

        t = threading.Thread(target=straggler, daemon=True)
        t.start()
        wd = sess.watchdog(min_barrier_slo_us=20_000.0)  # 20ms SLO
        deadline = time.monotonic() + 5.0
        fired = []
        while not fired and time.monotonic() < deadline:
            time.sleep(0.05)
            fired = wd.poll_once()
        assert fired, "straggler not detected within deadline"
        a = fired[0]
        assert a.kind == "slow-barrier"
        assert a.details["wait_us"] >= 20_000.0
        assert a.details["waiters"] == 1
        assert a.dump is not None and a.dump["events"]  # anomaly mark at least
        json.dumps(a.as_dict())
        bar.enter(timeout=1.0)                      # release the straggler
        assert done.wait(2.0)
        t.join(timeout=2.0)
        assert bar.oldest_wait_start() is None
    finally:
        sess.recorder.close()


def test_watchdog_slow_semaphore():
    sess = Session(backend="host", record=True)
    try:
        sem = sess.semaphore(1)
        sem.acquire()
        blocked = threading.Thread(
            target=lambda: (sem.acquire(timeout=10.0), sem.release()),
            daemon=True)
        blocked.start()
        wd = sess.watchdog(min_semaphore_slo_us=20_000.0)
        deadline = time.monotonic() + 5.0
        fired = []
        while not fired and time.monotonic() < deadline:
            time.sleep(0.05)
            fired = wd.poll_once()
        assert fired and fired[0].kind == "slow-semaphore"
        sem.release()
        blocked.join(timeout=2.0)
    finally:
        sess.recorder.close()


# ---------------------------------------------------------------------------
# watchdog: remaining detectors (duck-typed sessions keep these deterministic)
# ---------------------------------------------------------------------------


class _FakeStore:
    def __init__(self):
        self.migration_window = None
        self._tiers = {"promotions": 0, "demotions": 0}

    def tier_stats(self):
        return dict(self._tiers)


class _FakeSession:
    def __init__(self):
        self.store = _FakeStore()
        self.tracer = Tracer(enabled=False)
        self.recorder = None
        self._watch_prims = set()


def test_watchdog_tier_thrash():
    sess = _FakeSession()
    wd = Watchdog(sess, thrash_min_moves=16, cooldown_s=0.0)
    assert wd.poll_once() == []                     # baseline window
    sess.store._tiers = {"promotions": 40, "demotions": 38}
    fired = wd.poll_once()
    assert [a.kind for a in fired] == ["tier-thrash"]
    assert fired[0].details["promotions"] == 40
    # one-sided movement (a legitimate spill) is NOT thrash
    sess.store._tiers = {"promotions": 40, "demotions": 138}
    assert wd.poll_once() == []


def test_watchdog_lock_wait_outlier():
    sess = _FakeSession()
    trc = sess.tracer
    for sid in range(3):                            # three quiet shards
        for _ in range(50):
            trc.observe("store.lock_wait", 10.0, shard=sid)
    for _ in range(50):                             # one hot shard
        trc.observe("store.lock_wait", 90_000.0, shard=3)
    wd = Watchdog(sess, min_lock_wait_us=1_000.0, lock_wait_factor=8.0)
    fired = wd.poll_once()
    assert [a.kind for a in fired] == ["lock-wait-outlier"]
    assert fired[0].details["shard"] == 3
    assert fired[0].details["p99_us"] >= 90_000.0


def test_watchdog_cooldown_dedups_repeat_fires():
    sess = _FakeSession()
    wd = Watchdog(sess, thrash_min_moves=16, cooldown_s=60.0)
    wd.poll_once()
    sess.store._tiers = {"promotions": 40, "demotions": 38}
    assert len(wd.poll_once()) == 1
    sess.store._tiers = {"promotions": 80, "demotions": 76}
    assert wd.poll_once() == []                     # same incident, cooled down


def test_watchdog_dump_dir_writes_anomaly_files(tmp_path):
    sess = Session(backend="host", record=True)
    try:
        for i in range(48):
            sess.new_array(f"dd{i}", (8,))
        sess.store.add_shard(drain=False)
        wd = sess.watchdog(migration_deadline_s=0.05,
                           dump_dir=str(tmp_path))
        wd.poll_once()
        time.sleep(0.1)
        fired = wd.poll_once()
        assert fired
        path = fired[0].details["dump_path"]
        assert os.path.exists(path)
        data = json.load(open(path))                # acceptance: json.load
        assert data["kind"] == "stalled-migration"
        assert data["dump"]["events"]
    finally:
        sess.store.drain_window()
        sess.recorder.close()


def test_watchdog_daemon_thread_lifecycle():
    sess = _FakeSession()
    with Watchdog(sess, interval_s=0.01) as wd:
        time.sleep(0.05)
        assert wd._thread is not None and wd._thread.is_alive()
    assert wd._thread is None


def test_anomaly_catalogue_is_stable():
    assert ANOMALY_KINDS == ("stalled-migration", "slow-barrier",
                             "slow-semaphore", "tier-thrash",
                             "lock-wait-outlier", "dead-heartbeat")
    assert SEVERITIES == ("warning", "error", "critical")
    a = Anomaly(kind="tier-thrash", severity="warning", message="m",
                detected_at=0.0)
    assert a.as_dict()["dump"] is None


# ---------------------------------------------------------------------------
# ft integration: heartbeat escalation + recovery black box
# ---------------------------------------------------------------------------


def test_watchdog_dead_heartbeat_escalation():
    from repro.ft import HeartbeatMonitor, metrics_payload

    sess = Session(backend="host", record=True)
    try:
        recovered = []
        mon = HeartbeatMonitor([0, 1], timeout=10.0,
                               on_failure=recovered.append)
        wd = sess.watchdog()
        wd.watch_heartbeats(mon)
        mon.beat(1, metrics_payload(sess))
        mon.declare_dead(1)
        assert recovered == [[1]]                   # original callback ran
        assert [a.kind for a in wd.anomalies] == ["dead-heartbeat"]
        a = wd.anomalies[0]
        assert a.severity == "critical"
        assert a.details["node"] == 1
        assert a.details["last_payload"]["record_armed"] is True
        assert a.dump is not None
    finally:
        sess.recorder.close()


def test_session_recovery_attaches_flight_dump():
    from repro.ft import session_recovery

    sess = Session(backend="host", n_nodes=2, threads_per_node=1, record=True)
    new_sess = None
    try:
        sess.new_array("theta", (16,)).set(jnp.zeros(16))
        plan, new_sess = session_recovery(sess, [1])
        assert plan.flight_dump is not None
        assert plan.flight_dump["reason"] == "session-recovery"
        # the recovery mark is the dump's last breadcrumb
        assert any(e["name"] == "session_recovery"
                   for e in plan.flight_dump["events"])
        json.dumps(plan.flight_dump)
        # the replacement session adopts the same armed recorder
        assert new_sess.recorder is sess.recorder
        assert new_sess.recorder.armed
    finally:
        (new_sess or sess).recorder.close()
    assert telemetry.armed_count() == 0


def test_session_recovery_without_recorder_has_no_dump():
    from repro.ft import session_recovery

    sess = Session(backend="host", n_nodes=2, threads_per_node=1)
    plan, new_sess = session_recovery(sess, [1])
    assert plan.flight_dump is None
    assert not new_sess.recorder.armed


def test_metrics_payload_keys_pinned():
    from repro.ft import PAYLOAD_KEYS, REBALANCE_KEYS, metrics_payload

    sess = Session(backend="host", shards=2)
    payload = metrics_payload(sess)
    assert tuple(payload.keys()) == PAYLOAD_KEYS
    assert tuple(payload["rebalance"].keys()) == REBALANCE_KEYS
    assert payload["trace_enabled"] is False
    assert payload["record_armed"] is False
    # a store that never migrated still emits the full zeroed record
    assert payload["rebalance"]["windows"] == 0
    assert payload["rebalance"]["open"] is False


def test_metrics_payload_rebalance_keys_without_migration_support():
    from repro.ft import REBALANCE_KEYS, metrics_payload

    class _BareStore:                      # no migration_totals at all
        pass

    class _BareSession:
        store = _BareStore()
        tracer = Tracer(enabled=False)
        recorder = None

        def wire_traffic(self):
            return 0

    payload = metrics_payload(_BareSession())
    assert tuple(payload["rebalance"].keys()) == REBALANCE_KEYS
    assert payload["rebalance"]["pending"] == 0


# ---------------------------------------------------------------------------
# metrics under a live migration window (satellite)
# ---------------------------------------------------------------------------


def test_metrics_concurrent_with_open_migration_window():
    sess = Session(backend="host", shards=4, trace=True)
    try:
        for i in range(64):
            sess.new_array(f"cw{i}", (32,))
        sess.store.add_shard(drain=False)
        assert sess.store.migration_window is not None

        moved_seq, errors = [], []

        def poller():
            try:
                for _ in range(200):
                    m = sess.metrics()
                    mig = m["tiers"]["migration"]
                    moved_seq.append((mig["entries_moved"], mig["pulled"]))
                    assert isinstance(m["shards"], dict)
            except Exception as e:  # pragma: no cover - the failure signal
                errors.append(e)

        t = threading.Thread(target=poller)
        t.start()
        while sess.store.migration_window is not None:
            sess.store.migrate_step(2)              # drain concurrently
        t.join(timeout=30)
        assert not errors, f"metrics raced the window: {errors[0]!r}"
        # counters must be monotonic across the drain
        assert moved_seq == sorted(moved_seq)
        m = sess.metrics()
        assert m["tiers"]["migration"]["open"] is False
        assert m["tiers"]["migration"]["entries_moved"] >= 1
    finally:
        sess.tracer.disable()


def test_metrics_tiers_section_with_cold_tier():
    # the hot budget is per shard: 1KiB holds exactly one 256-float entry,
    # so any shard owning two or more names must have spilled
    sess = Session(backend="host", shards=2, cold_tier="host",
                   cold_budget=1 << 10)
    for i in range(8):
        sess.new_array(f"tz{i}", (256,)).set(jnp.ones(256))
    tiers = sess.metrics()["tiers"]
    assert tiers["kind"] == "host"
    assert tiers["demotions"] >= 1                  # budget forced spills
    assert tiers["cold_entries"] >= 1
    assert tiers["hot"]["bytes"] <= 2 * (1 << 10)


# ---------------------------------------------------------------------------
# OpenMetrics exporter
# ---------------------------------------------------------------------------


def test_openmetrics_from_live_session():
    sess = Session(backend="host", shards=2, record=True)
    try:
        ref = sess.new_array("om", (64,))
        ref.set(jnp.ones(64))
        ref.get()
        text = sess.openmetrics()
        assert text.endswith("# EOF\n")
        assert "# TYPE step_store_gets counter" in text
        assert "step_store_gets_total " in text
        assert 'step_shard_store_gets_total{shard="0"}' in text
        assert "step_trace_record_only 1" in text
        assert "step_recorder_ring_capacity" in text
        assert 'step_op_latency_us{op="store.set",quantile="0.99"}' in text
        # TYPE/HELP emitted once per family even with per-shard samples
        assert text.count("# TYPE step_shard_store_gets counter") == 1
    finally:
        sess.recorder.close()


def test_openmetrics_defensive_on_empty_metrics():
    text = openmetrics({})
    assert text.endswith("# EOF\n")
    assert "step_store_gets_total 0" in text
    assert "step_migration_open 0" in text


def test_openmetrics_anomaly_counter_and_escaping():
    text = openmetrics({}, anomalies=[
        Anomaly(kind="tier-thrash", severity="warning", message="m",
                detected_at=0.0),
        {"kind": 'we"ird\nkind'},
        {"kind": "tier-thrash"},
    ])
    assert 'step_anomalies_total{kind="tier-thrash"} 2' in text
    assert r'step_anomalies_total{kind="we\"ird\nkind"} 1' in text


def test_openmetrics_custom_prefix():
    text = openmetrics({}, prefix="acme")
    assert "# TYPE acme_info gauge" in text
    assert "step_" not in text


# ---------------------------------------------------------------------------
# step_top renderer (pure function of snapshots)
# ---------------------------------------------------------------------------


def _load_step_top():
    path = os.path.join(os.path.dirname(__file__), "..", "scripts",
                        "step_top.py")
    spec = importlib.util.spec_from_file_location("step_top", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_step_top_render_is_pure():
    st = _load_step_top()
    cur = {
        "backend": "host", "wire_traffic": 5,
        "trace": {"enabled": True, "record_only": True,
                  "ring": {"held": 7, "capacity": 64, "total": 7},
                  "ops": {"store.get": {"count": 300, "p50": 10.0,
                                        "p99": 50.0, "max": 80.0,
                                        "rate_per_s": 10.0}},
                  "ops_by_shard": {"store.lock_wait": {
                      0: {"count": 5, "p50": 1.0, "p99": 2.0}}}},
        "tiers": {"hot": {"entries": 3, "bytes": 2048.0}, "cold": {"bytes": 0},
                  "cold_entries": 0, "promotions": 1, "demotions": 2,
                  "migration": {"open": True, "pending": 4, "windows": 1,
                                "entries_moved": 9, "bytes_moved": 100,
                                "pulled": 2}},
    }
    prev = json.loads(json.dumps(cur))
    prev["trace"]["ops"]["store.get"]["count"] = 100
    frame = st.render(cur, prev, dt=2.0,
                      anomalies=[{"kind": "tier-thrash", "message": "churn"}])
    assert "obs=record ring=7/64" in frame
    assert "store.get" in frame and "100.0" in frame   # (300-100)/2 ops/s
    assert "OPEN pending=4" in frame
    assert "[tier-thrash] churn" in frame
    # rendering must not mutate its inputs
    assert cur["trace"]["ops"]["store.get"]["count"] == 300


def test_step_top_render_empty_metrics():
    st = _load_step_top()
    frame = st.render({})
    assert "step_top" in frame and "obs=off" in frame


def test_step_top_rate_falls_back_to_lifetime():
    st = _load_step_top()
    cur = {"trace": {"ops": {"store.get": {"count": 10, "p50": 1.0,
                                           "p99": 2.0, "max": 3.0,
                                           "rate_per_s": 42.0}}}}
    assert st._rate(cur, None, "store.get", 1.0) == 42.0
    prev = {"trace": {"ops": {"store.get": {"count": 4}}}}
    assert st._rate(cur, prev, "store.get", 2.0) == 3.0
