"""Attention math: blocked == naive, MLA, RoPE properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.attention import (
    GQAConfig, MLAConfig, blocked_attention, gqa_attend, init_gqa, init_mla,
    init_mla_cache, mla_attend, mla_decode, naive_attention,
)
from repro.models.common import apply_rope


@settings(max_examples=15, deadline=None)
@given(
    st.integers(1, 2), st.integers(1, 3),   # B, KH
    st.sampled_from([1, 2, 4]),             # G
    st.sampled_from([8, 16]),               # dh
    st.sampled_from([17, 32, 64]),          # T
    st.booleans(),                          # causal
)
def test_blocked_equals_naive(B, KH, G, dh, T, causal):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, T, KH, G, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, KH, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, KH, dh)), jnp.float32)
    blk = blocked_attention(q, k, v, causal=causal, block_k=16)
    ref = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_blocked_q_offset():
    rng = np.random.default_rng(1)
    B, T, S, KH, G, dh = 1, 4, 32, 2, 1, 8
    q = jnp.asarray(rng.normal(size=(B, T, KH, G, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KH, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KH, dh)), jnp.float32)
    blk = blocked_attention(q, k, v, causal=True, q_offset=10, block_k=8)
    ref = naive_attention(q, k, v, causal=True, q_offset=10)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_rope_preserves_norm_and_relativity():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    r = apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(r), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    def dot_at(i, j):
        qi = apply_rope(q, jnp.full((1, 1), i))
        kj = apply_rope(k, jnp.full((1, 1), j))
        return float(jnp.sum(qi * kj))
    np.testing.assert_allclose(dot_at(3, 1), dot_at(7, 5), rtol=1e-4)


def test_mla_decode_matches_prefill_last_token():
    """Absorbed-matrix decode == expand-everything attention, token by token."""
    cfg = MLAConfig(d_model=32, n_heads=2, q_lora_rank=16, kv_lora_rank=8,
                    qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8,
                    attention_impl="naive")
    p = init_mla(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    B, T = 2, 6
    x = jnp.asarray(rng.normal(size=(B, T, 32)), jnp.float32)
    full = np.asarray(mla_attend(p, x, cfg))
    cache = init_mla_cache(cfg, B, T, jnp.float32)
    for t in range(T):
        cache, out = mla_decode(p, cache, x[:, t:t + 1], cfg, t)
        np.testing.assert_allclose(np.asarray(out[:, 0]), full[:, t],
                                   rtol=2e-4, atol=2e-4)


def test_gqa_bias_and_qknorm_paths():
    cfg = GQAConfig(d_model=16, n_heads=4, n_kv_heads=2, head_dim=8,
                    qk_norm=True, qkv_bias=True, attention_impl="naive")
    p = init_gqa(jax.random.PRNGKey(0), cfg)
    assert {"bq", "bk", "bv", "q_norm", "k_norm"} <= set(p)
    x = jnp.ones((1, 4, 16))
    out = gqa_attend(p, x, cfg)
    assert out.shape == (1, 4, 16)
    assert np.all(np.isfinite(np.asarray(out)))
