"""Mamba2 SSD: chunked == sequential recurrence; decode == prefill."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ssd_scan.ref import ssd_sequential_ref
from repro.models.mamba import (
    SSMConfig, init_mamba2, init_mamba_cache, mamba2_decode, mamba2_forward,
    ssd_chunked,
)


def _inputs(b=2, T=32, H=4, P=8, G=2, N=16, seed=0):
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(b, T, H, P)), jnp.float32) * 0.5
    dt = jnp.asarray(np.abs(rng.normal(size=(b, T, H))) * 0.5 + 0.1, jnp.float32)
    A_log = jnp.asarray(np.log(np.linspace(1.0, 4.0, H)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, T, G, N)), jnp.float32) * 0.3
    C = jnp.asarray(rng.normal(size=(b, T, G, N)), jnp.float32) * 0.3
    return xs, dt, A_log, B, C


def test_chunked_matches_sequential_multiple_chunk_sizes():
    xs, dt, A_log, B, C = _inputs()
    y_seq = ssd_sequential_ref(xs, dt, A_log, B, C)
    for chunk in (4, 8, 16, 32):
        y, _ = ssd_chunked(xs, dt, A_log, B, C, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_seq),
                                   rtol=3e-4, atol=3e-4)


def test_block_decode_matches_forward():
    cfg = SSMConfig(d_model=16, d_state=8, head_dim=8, expand=2, chunk=8)
    p = init_mamba2(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    B, T = 2, 16
    x = jnp.asarray(rng.normal(size=(B, T, 16)), jnp.float32)
    full = np.asarray(mamba2_forward(p, x, cfg))
    cache = init_mamba_cache(cfg, B)
    for t in range(T):
        cache, y = mamba2_decode(p, cache, x[:, t:t + 1], cfg)
        np.testing.assert_allclose(np.asarray(y[:, 0]), full[:, t],
                                   rtol=3e-4, atol=3e-4)


def test_final_state_consistency():
    xs, dt, A_log, B, C = _inputs(T=16)
    _, h8 = ssd_chunked(xs, dt, A_log, B, C, chunk=8)
    _, h4 = ssd_chunked(xs, dt, A_log, B, C, chunk=4)
    np.testing.assert_allclose(np.asarray(h8), np.asarray(h4), rtol=3e-4, atol=3e-4)
