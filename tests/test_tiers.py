"""step.tiers — tiered shard storage + epoch-aware promotion + live
incremental rebalancing.

The tentpole contract: a ``ShardedStore`` with a cold tier spills
least-recently-used entries past the per-shard hot-byte budget and promotes
them back (epoch-preserving, so cache replicas stay valid) on access; a ring
join/leave runs as an *incremental* migration window — the new ring is
published immediately, each moved key crosses under exactly the two involved
shard locks, readers/writers keep flowing, and no operation ever observes a
stale value.  With ``cold_tier=None`` (the default) every path stays
single-tier at one extra branch per op.
"""

import hashlib
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DiskTier,
    DSMCache,
    GlobalStore,
    HostMemTier,
    Session,
    ShardedStore,
)
from repro.core.tiers import resolve_cold_tier
from repro.ft import metrics_payload, rebalance_shards, session_recovery

ONE_KB = (256,)  # float32 (256,) == 1024 bytes


def _fill(store, names, base=0.0, shape=ONE_KB):
    for i, n in enumerate(names):
        store.def_global(n, jnp.full(shape, base + i))


# -- cold tiers ---------------------------------------------------------------


def test_resolve_cold_tier_contract():
    assert resolve_cold_tier(None) is None
    assert isinstance(resolve_cold_tier("host"), HostMemTier)
    disk = resolve_cold_tier("disk")
    assert isinstance(disk, DiskTier)
    disk.close()
    tier = HostMemTier()
    assert resolve_cold_tier(tier) is tier
    with pytest.raises(ValueError, match="cold_tier"):
        resolve_cold_tier("tape")
    with pytest.raises(TypeError, match="ColdTier"):
        resolve_cold_tier(object())


def test_budget_demotes_lru_first_and_counts():
    store = ShardedStore(shards=1, cold_tier="host", cold_budget=2 * 1024)
    _fill(store, [f"d{i}" for i in range(4)])        # 4 KB hot demand
    ts = store.tier_stats()
    assert ts["kind"] == "host" and ts["budget_bytes"] == 2 * 1024
    assert ts["hot"]["entries"] == 2 and ts["hot"]["bytes"] == 2 * 1024
    assert ts["cold_entries"] == 2 == ts["demotions"]
    assert ts["cold"] == {"puts": 2, "gets": 0, "deletes": 0,
                          "entries": 2, "bytes": 2 * 1024}
    # insertion order is LRU order: the two oldest entries were spilled
    shard = store._shards[0]
    assert sorted(shard.cold) == ["d0", "d1"]
    # a read touches (MRU-bumps) a hot entry; the next demand spills the
    # other hot entry, not the one just used
    np.testing.assert_allclose(np.asarray(store.get("d2")), 2.0)
    store.def_global("d4", jnp.full(ONE_KB, 4.0))
    assert "d3" in store._shards[0].cold and "d2" in store._shards[0].entries


def test_promotion_preserves_epoch_and_value():
    store = ShardedStore(shards=1, cold_tier="host", cold_budget=1024)
    store.def_global("p", jnp.full(ONE_KB, 1.0))
    store.set("p", jnp.full(ONE_KB, 2.0))
    epoch = store._shards[0].entries["p"].epoch
    _fill(store, ["f0", "f1"], base=10.0)            # push "p" cold
    cold_entry = store._shards[0].cold["p"]
    assert cold_entry.value is None and cold_entry.epoch == epoch
    np.testing.assert_allclose(np.asarray(store.get("p")), 2.0)  # promote
    assert store._shards[0].entries["p"].epoch == epoch          # unchanged
    ts = store.tier_stats()
    assert ts["promotions"] >= 1 and ts["cold_hits"] >= 1


def test_epoch_validated_cache_replica_survives_demote_promote_cycle():
    store = GlobalStore(shards=1, cold_tier="host", cold_budget=1024)
    cache = DSMCache(store, n_nodes=2)
    store.def_global("m", jnp.full(ONE_KB, 3.0))
    np.testing.assert_allclose(cache.read(0, "m"), 3.0)          # replica
    _fill(store, ["g0", "g1"], base=5.0)                         # demote "m"
    assert "m" in store._shards[0].cold
    # the replica's epoch still matches the (cold) entry — a cached read is
    # a hit and never forces a promotion
    hits, promos = cache.stats.hits, store.tier_stats()["promotions"]
    np.testing.assert_allclose(cache.read(0, "m"), 3.0)
    assert cache.stats.hits == hits + 1
    assert store.tier_stats()["promotions"] == promos
    # a write promotes (slot reclaim, no payload load), bumps the epoch, and
    # invalidates the replica exactly as in the single-tier store
    cache.write(1, "m", jnp.full(ONE_KB, 4.0))
    np.testing.assert_allclose(cache.read(0, "m"), 4.0)


def test_set_and_inc_operate_on_cold_entries():
    store = ShardedStore(shards=1, cold_tier="host", cold_budget=1024)
    store.def_global("s", jnp.full(ONE_KB, 1.0))
    store.def_global("i", jnp.full(ONE_KB, 1.0))
    store.def_global("hot", jnp.full(ONE_KB, 0.0))   # spills s and i
    shard = store._shards[0]
    assert {"s", "i"} <= set(shard.cold)
    store.set("s", jnp.full(ONE_KB, 9.0))            # overwrite: no load
    store.inc("i", 1.0)                              # rmw: loads then incs
    np.testing.assert_allclose(np.asarray(store.get("s")), 9.0)
    np.testing.assert_allclose(np.asarray(store.get("i")), 2.0)


def test_delete_reclaims_cold_payload():
    tier = HostMemTier()
    store = ShardedStore(shards=1, cold_tier=tier, cold_budget=1024)
    _fill(store, ["a", "b"])                         # "a" goes cold
    assert tier.stats()["entries"] == 1
    store.delete("a")
    assert tier.stats()["entries"] == 0
    assert "a" not in store._shards[0].cold
    with pytest.raises(KeyError):
        store.get("a")


def test_disk_tier_roundtrip_and_close_removes_spill_dir():
    import os
    store = ShardedStore(shards=1, cold_tier="disk", cold_budget=1024)
    _fill(store, ["x0", "x1", "x2"])
    tier = store.cold_tier
    root = tier.root
    assert os.path.isdir(root) and tier.stats()["entries"] == 2
    np.testing.assert_allclose(np.asarray(store.get("x0")), 0.0)
    np.testing.assert_allclose(np.asarray(store.get("x1")), 1.0)
    tier.close()
    assert not os.path.exists(root)                  # owned tempdir removed


def test_object_entries_round_trip_through_cold_tier():
    store = ShardedStore(shards=1, cold_tier="host", cold_budget=1024)
    store.new_object("obj", {"w": jnp.full(ONE_KB, 1.5), "b": jnp.zeros(4)})
    store.def_global("pad", jnp.full(ONE_KB, 0.0))
    assert "obj" in store._shards[0].cold
    got = store.get("obj")
    np.testing.assert_allclose(np.asarray(got["w"]), 1.5)
    np.testing.assert_allclose(np.asarray(got["b"]), 0.0)


def test_default_path_keeps_single_tier_shape():
    store = ShardedStore(shards=2)
    _fill(store, [f"n{i}" for i in range(4)])
    ts = store.tier_stats()
    assert ts["kind"] is None and ts["budget_bytes"] is None
    assert ts["cold_entries"] == 0 == ts["demotions"] == ts["promotions"]
    assert ts["hot"]["bytes"] == 0                   # untracked when untiered
    assert store.cold_tier is None
    for shard in store._shards.values():
        assert shard.cold == {}


def test_session_plumbs_cold_tier_and_reports_tiers_metric():
    sess = Session(backend="host", n_nodes=1, threads_per_node=2,
                   shards=2, cold_tier="host", cold_budget=4 * 1024)
    refs = [sess.new_array(f"t{i}", ONE_KB) for i in range(12)]
    for i, r in enumerate(refs):
        r.set(jnp.full(ONE_KB, float(i)))
    m = sess.metrics()
    assert m["tiers"]["kind"] == "host"
    assert m["tiers"]["demotions"] > 0
    assert m["tiers"]["migration"] == sess.store.migration_totals()
    for i, r in enumerate(refs):                     # everything still exact
        np.testing.assert_allclose(np.asarray(r.get()), float(i))


# -- review regressions -------------------------------------------------------


def _pin_hot_abstract(shard, names, shape=ONE_KB):
    """Turn the named hot entries abstract (trace-mode ShapeDtypeStructs):
    they keep counting toward hot_bytes but _demotable rejects them, so the
    demotion pass can only ever pick a concrete entry."""
    for n in names:
        shard.entries[n].value = jax.ShapeDtypeStruct(shape, jnp.float32)


def test_get_returns_promoted_value_even_when_demoted_right_back():
    """Review regression: when every older hot entry is non-demotable, the
    demotion pass after a promote picks the just-promoted entry as its only
    victim — get() must still return the stored value, not None."""
    store = ShardedStore(shards=1, cold_tier="host", cold_budget=2 * 1024)
    _fill(store, ["victim", "pad0", "pad1"], base=6.0)
    shard = store._shards[0]
    assert "victim" in shard.cold                    # LRU spill past budget
    _pin_hot_abstract(shard, ["pad0", "pad1"])
    for _ in range(2):                               # stable across cycles
        np.testing.assert_allclose(np.asarray(store.get("victim")), 6.0)
        assert "victim" in shard.cold                # demoted back each time
    assert shard.stats["demotions"] >= 3


def test_inc_returns_new_value_even_when_demoted_right_back():
    """Review regression: inc() promotes, computes, then re-budgets via
    _note_resize; if that demotes the entry being served, the freshly
    computed value must still be returned (and must round-trip)."""
    store = ShardedStore(shards=1, cold_tier="host", cold_budget=2 * 1024)
    _fill(store, ["ctr", "pad0", "pad1"], base=1.0)
    shard = store._shards[0]
    assert "ctr" in shard.cold
    _pin_hot_abstract(shard, ["pad0", "pad1"])
    out = store.inc("ctr", 2.0)
    assert out is not None
    np.testing.assert_allclose(np.asarray(out), 3.0)
    assert "ctr" in shard.cold                       # demoted after serving
    np.testing.assert_allclose(np.asarray(store.get("ctr")), 3.0)


def test_settle_serves_in_place_under_the_new_owners_lock():
    """Review regression: during the brief unsealed window phase the ring
    comparison still reports a move for a name that has already crossed.  A
    re-entrant op holding the NEW owner's lock (cache.write composes
    store.set that way) must be served in place — re-entering the
    pair-locked pull would take the source lock second, a lock-order
    inversion that can deadlock against a concurrent puller."""
    from repro.core.shards import MigrationWindow, Shard

    store = ShardedStore(shards=2)
    names = [f"u{i}" for i in range(16)]
    _fill(store, names)
    old_ring = store._ring
    store._shards[9] = Shard(9)
    new_ring = old_ring.added(9)
    name = next(n for n in names if new_ring.owner(n) == 9)
    win = MigrationWindow(old_ring, new_ring)        # unsealed on purpose
    store._ring = new_ring
    store._window = win
    src, dst = store._shards[old_ring.owner(name)], store._shards[9]
    dst.entries[name] = src.entries.pop(name)        # already crossed
    orig = store._migrate_one

    def boom(*a, **k):  # pragma: no cover - only fires on regression
        raise AssertionError("re-entrant settle re-entered the pair pull")

    store._migrate_one = boom
    store._lock_shard(dst)                           # the re-entrant posture
    try:
        assert store._settle(win, name) == 9
        np.testing.assert_allclose(np.asarray(store.get(name)),
                                   float(names.index(name)))
    finally:
        store._unlock_shard(dst)
        store._migrate_one = orig
        store._window = None
    np.testing.assert_allclose(np.asarray(store.get(name)),
                               float(names.index(name)))


def test_name_listings_and_stats_survive_concurrent_topology_changes():
    """Review regression: names()/stats/tier_stats()/_entries iterate the
    shard table while add_shard/remove_shard insert into it — they must
    iterate a snapshot, never raising 'dictionary changed size'."""
    store = ShardedStore(shards=2)
    names = [f"n{i}" for i in range(64)]
    _fill(store, names, shape=(8,))
    stop = threading.Event()
    errors = []

    def reader():
        try:
            while not stop.is_set():
                assert set(store.names()) >= set()   # exercise the walk
                store.stats
                store.tier_stats()
                store._entries
        except Exception as exc:  # pragma: no cover - the regression itself
            errors.append(repr(exc))

    th = threading.Thread(target=reader)
    th.start()
    try:
        for sid in range(50, 58):
            store.add_shard(sid)
            store.remove_shard(sid)
    finally:
        stop.set()
        th.join()
    assert not errors, errors[:3]
    assert sorted(store.names()) == sorted(names)


def test_disk_tier_spill_files_keyed_by_full_name_digest():
    """Review regression: spill files must be keyed by a long digest of the
    full DSM name — a 64-bit ring-hash key lets two distinct live names
    share one file, silently serving one name the other's payload."""
    tier = DiskTier()
    try:
        path = tier._path("a")
        assert path != tier._path("b")
        want = hashlib.blake2b(b"a", digest_size=20).hexdigest() + ".pkl"
        assert os.path.basename(path) == want
        tier.put("a", np.full(4, 1.0))
        tier.put("b", np.full(4, 2.0))
        np.testing.assert_allclose(tier.get("a"), 1.0)
        np.testing.assert_allclose(tier.get("b"), 2.0)
    finally:
        tier.close()


# -- incremental migration windows --------------------------------------------


def test_add_shard_drains_inline_by_default_and_records_cost():
    store = ShardedStore(shards=2)
    names = [f"k{i}" for i in range(32)]
    _fill(store, names)
    mig = store.add_shard(7)
    assert store.migration_window is None            # drained before return
    assert mig.added == (7,) and len(mig.moved) > 0
    assert mig.bytes_moved == 1024 * len(mig.moved)
    assert mig.window_s > 0.0 and mig.pulled == 0
    for i, n in enumerate(names):
        np.testing.assert_allclose(np.asarray(store.get(n)), float(i))
    totals = store.migration_totals()
    assert totals["windows"] == 1 and totals["open"] is False
    assert totals["bytes_moved"] == mig.bytes_moved


def test_open_window_settles_reads_writes_then_closes():
    store = ShardedStore(shards=2)
    names = [f"w{i}" for i in range(32)]
    _fill(store, names)
    store.add_shard(9, drain=False)
    win = store.migration_window
    assert win is not None and win.remaining > 0
    before = win.remaining
    # every op settles its own key first — reads are never stale, and each
    # access shrinks the pending set by at most that one key
    for i, n in enumerate(names):
        np.testing.assert_allclose(np.asarray(store.get(n)), float(i))
    assert store.migration_window is None or store.migration_window.remaining < before
    left = store.migrate_step(10 ** 6)
    assert left == 0 and store.migration_window is None
    totals = store.migration_totals()
    assert totals["pulled"] > 0                      # reads did real handoffs
    assert totals["entries_moved"] == before


def test_remove_shard_window_serves_unpulled_keys_from_retired_shard():
    store = ShardedStore(shards=3)
    names = [f"r{i}" for i in range(30)]
    _fill(store, names)
    victim = store.shard_of(names[0])
    mig = store.remove_shard(victim, drain=False)
    assert mig.removed == (victim,)
    assert victim not in store.shard_ids()           # ring updated at once
    # un-pulled keys still readable (served off the retired shard) and the
    # global name listing stays complete mid-window
    assert set(names) <= set(store.names())
    for i, n in enumerate(names):
        np.testing.assert_allclose(np.asarray(store.get(n)), float(i))
    store.drain_window()
    assert len(store._shards[victim].entries) == 0
    assert set(names) <= set(store.names())


def test_cold_entries_migrate_as_index_records_without_payload_io():
    tier = HostMemTier()
    store = ShardedStore(shards=2, cold_tier=tier, cold_budget=0)
    names = [f"c{i}" for i in range(16)]
    _fill(store, names)                              # budget 0: all cold
    io_before = tier.stats()["gets"] + tier.stats()["puts"]
    mig = store.add_shard(5)
    assert len(mig.moved) > 0
    # the payload is keyed by name in the shared tier — moving a cold entry
    # moves only its index record, no tier round trip
    assert tier.stats()["gets"] + tier.stats()["puts"] == io_before
    assert mig.bytes_moved == 1024 * len(mig.moved)  # accounted at cold size
    for i, n in enumerate(names):
        np.testing.assert_allclose(np.asarray(store.get(n)), float(i))


def test_back_to_back_topology_changes_serialize_windows():
    store = ShardedStore(shards=2)
    _fill(store, [f"b{i}" for i in range(24)])
    store.add_shard(4, drain=False)
    assert store.migration_totals()["open"] is True
    store.add_shard(5, drain=False)                  # drains window 1 first
    store.drain_window()
    totals = store.migration_totals()
    assert totals["windows"] == 2 and totals["open"] is False
    for i in range(24):
        np.testing.assert_allclose(np.asarray(store.get(f"b{i}")), float(i))


def test_legacy_stop_the_world_path_still_works_and_reports_cost():
    store = ShardedStore(shards=2)
    _fill(store, [f"l{i}" for i in range(16)])
    mig = store.add_shard(3, incremental=False)
    assert store.migration_window is None
    assert mig.bytes_moved == 1024 * len(mig.moved) and mig.pulled == 0
    assert mig.window_s > 0.0
    for i in range(16):
        np.testing.assert_allclose(np.asarray(store.get(f"l{i}")), float(i))


def test_incremental_rebalance_bounds_reader_pause_and_never_goes_stale():
    """The acceptance stress: concurrent read/write traffic across an
    add_shard window with an injected per-entry migration delay.  No thread
    may ever observe a stale or torn value, and the worst single-op pause
    must be bounded by ~one entry migration — far below the whole window
    (which is what the stop-the-world path would charge one reader)."""
    store = ShardedStore(shards=2)
    names = [f"s{i}" for i in range(64)]
    _fill(store, names, shape=(64,))
    pause = 0.015
    store._migrate_entry_hook = lambda name: time.sleep(pause)
    stop = threading.Event()
    errors, op_times = [], []

    def worker(t):
        mine = names[t::4]                           # single writer per name
        latest = {n: float(names.index(n)) for n in mine}
        k = 0
        try:
            while not stop.is_set():
                n = mine[k % len(mine)]
                k += 1
                t0 = time.perf_counter()
                if k % 2:
                    latest[n] += 1.0
                    store.set(n, jnp.full((64,), latest[n]))
                got = np.asarray(store.get(n))
                op_times.append(time.perf_counter() - t0)
                if not np.all(got == got[0]):
                    errors.append(f"torn read of {n}")
                elif got[0] != latest[n]:
                    errors.append(f"stale read of {n}: {got[0]} != {latest[n]}")
        except Exception as exc:  # pragma: no cover - surfaced via errors
            errors.append(f"worker {t}: {exc!r}")

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for th in threads:
        th.start()
    time.sleep(0.05)
    mig = store.add_shard(7, drain=False)
    store.drain_window()
    time.sleep(0.05)
    stop.set()
    for th in threads:
        th.join()
    store._migrate_entry_hook = None
    assert not errors, errors[:5]
    moved = len(mig.moved)
    assert moved >= 8                                # the window did real work
    window_s = store.migration_totals()["window_s"]
    assert window_s >= moved * pause * 0.9
    # bounded pause: one entry handoff (possibly queued behind one more),
    # never the full window a stop-the-world rebalance would charge
    assert max(op_times) < 0.5 * window_s
    assert max(op_times) < 6 * pause + 0.1


def test_incremental_handoff_is_checker_clean():
    """step.check must accept the pair-locked handoff (its own exemption)
    while still rejecting everything the old rules rejected: a live window
    with concurrent disjoint traffic produces zero findings."""
    sess = Session(backend="host", n_nodes=4, threads_per_node=1,
                   shards=4, check=True)
    refs = [sess.new_array(f"h{i}", (16,)) for i in range(16)]
    started = threading.Event()

    def rebalancer():
        started.wait()
        sess.store.add_shard(11, drain=False)        # workers pull on access
        time.sleep(0.01)
        sess.store.drain_window()

    def proc(ctx):
        started.set()
        for rnd in range(40):
            r = refs[ctx.tid * 4 + rnd % 4]          # disjoint per thread
            r.set(jnp.full((16,), float(rnd)))
            assert float(np.asarray(r.get())[0]) == float(rnd)
        return True

    mover = threading.Thread(target=rebalancer)
    mover.start()
    try:
        assert sess.run(proc) == [True] * 4
        mover.join()
        assert sess.store.migration_window is None
        assert sess.findings() == []
    finally:
        sess.checker.disable()


# -- crash mid-migration + FT plumbing ----------------------------------------


@pytest.mark.parametrize("seed", range(4))
def test_recovery_mid_window_loses_and_duplicates_nothing(seed):
    """Kill the session inside an open migration window at a random drain
    point: session_recovery must complete the handoff — every key present
    exactly once, every value intact, window closed."""
    rng = np.random.default_rng(seed)
    sess = Session(backend="host", n_nodes=3, threads_per_node=1, shards=3)
    vals = {f"c{seed}_{i}": float(rng.integers(0, 1000))
            for i in range(int(rng.integers(5, 40)))}
    for k, v in vals.items():
        sess.store.def_global(k, jnp.full((8,), v))
    sess.store.add_shard(10 + seed, drain=False)
    sess.store.migrate_step(int(rng.integers(0, len(vals) + 1)))
    plan, new_sess = session_recovery(sess, [2])     # crash strikes now
    assert new_sess.store is sess.store
    assert new_sess.store.migration_window is None
    listed = sorted(new_sess.store.names())
    assert listed == sorted(vals)                    # nothing lost, no dupes
    for k, v in vals.items():
        np.testing.assert_allclose(np.asarray(new_sess.store.get(k)), v)


@pytest.mark.slow
def test_migration_stress_repeated_topology_changes_under_load():
    """Soak: back-to-back add/remove topology changes under sustained 6-way
    read/write traffic.  Every read must return the writer's latest value
    (single writer per name), never torn, across every window.  Scaled up in
    its own CI job via ``STEP_STRESS_SCALE``."""
    scale = int(os.environ.get("STEP_STRESS_SCALE", "1"))
    store = ShardedStore(shards=2)
    names = [f"z{i}" for i in range(96)]
    _fill(store, names, shape=(64,))
    stop = threading.Event()
    errors = []

    def worker(t):
        mine = names[t::6]                           # single writer per name
        latest = {n: float(names.index(n)) for n in mine}
        k = 0
        try:
            while not stop.is_set():
                n = mine[k % len(mine)]
                k += 1
                if k % 3 == 0:
                    latest[n] += 1.0
                    store.set(n, jnp.full((64,), latest[n]))
                got = np.asarray(store.get(n))
                if not np.all(got == got[0]):
                    errors.append(f"torn read of {n}")
                elif got[0] != latest[n]:
                    errors.append(f"stale read of {n}: {got[0]} != {latest[n]}")
        except Exception as exc:  # pragma: no cover - surfaced via errors
            errors.append(f"worker {t}: {exc!r}")

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(6)]
    for th in threads:
        th.start()
    sids = iter(range(100, 100 + 3 * scale))
    try:
        for _ in range(3 * scale):
            store.add_shard(next(sids), drain=False)
            store.migrate_step(5)                    # partial manual drain
            store.drain_window()
            victim = min(store.shard_ids())
            store.remove_shard(victim, drain=False)
            store.drain_window()
            time.sleep(0.01)
    finally:
        stop.set()
        for th in threads:
            th.join()
    assert not errors, errors[:5]
    totals = store.migration_totals()
    assert totals["windows"] == 6 * scale and totals["open"] is False
    assert sorted(store.names()) == sorted(names)    # nothing lost, no dupes
    assert len(store.shard_ids()) == 2               # net topology unchanged


def test_rebalance_plan_and_heartbeat_report_migration_cost():
    """Satellite: ft.rebalance_shards' merged plan carries bytes_moved and
    window duration, and ft.metrics_payload exposes the store's lifetime
    rebalance totals."""
    sess = Session(backend="host", n_nodes=2, threads_per_node=1, shards=2)
    for i in range(24):
        sess.store.def_global(f"fb{i}", jnp.full(ONE_KB, float(i)))
    mig = rebalance_shards(sess.store, join=[6], leave=[0])
    assert mig is not None
    assert mig.bytes_moved >= 1024 * len(mig.moved) > 0
    assert mig.bytes_moved % 1024 == 0
    assert mig.window_s > 0.0
    payload = metrics_payload(sess)
    assert payload["rebalance"]["windows"] == 2
    assert payload["rebalance"]["bytes_moved"] == mig.bytes_moved
    assert payload["rebalance"]["open"] is False
    for i in range(24):
        np.testing.assert_allclose(np.asarray(sess.store.get(f"fb{i}")),
                                   float(i))
