"""Optimizers, schedules, compression (error feedback identity)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.sparse import blocked_topk_sparsify, densify
from repro.optim import (
    adam, adamw, apply_updates, clip_by_global_norm, ef_init, global_norm,
    sgd, warmup_cosine,
)


def test_sgd_matches_manual():
    params = {"w": jnp.asarray([1.0, 2.0])}
    grads = {"w": jnp.asarray([0.5, -0.5])}
    opt = sgd(lr=0.1)
    upd, _ = opt.update(grads, opt.init(params))
    new = apply_updates(params, upd)
    np.testing.assert_allclose(new["w"], [0.95, 2.05])


def test_momentum():
    opt = sgd(lr=1.0, momentum=0.9)
    p = {"w": jnp.zeros(1)}
    st_ = opt.init(p)
    g = {"w": jnp.ones(1)}
    upd1, st_ = opt.update(g, st_, p, 0)
    upd2, st_ = opt.update(g, st_, p, 1)
    np.testing.assert_allclose(upd1["w"], -1.0)
    np.testing.assert_allclose(upd2["w"], -1.9)


def test_adam_first_step_is_lr_sized():
    opt = adam(lr=1e-3)
    p = {"w": jnp.asarray([1.0])}
    g = {"w": jnp.asarray([123.0])}
    upd, _ = opt.update(g, opt.init(p), p, 0)
    np.testing.assert_allclose(upd["w"], -1e-3, rtol=1e-4)


def test_adamw_decay():
    opt_w = adamw(lr=1e-2, weight_decay=0.1)
    opt_0 = adamw(lr=1e-2, weight_decay=0.0)
    p = {"w": jnp.asarray([10.0])}
    g = {"w": jnp.asarray([1.0])}
    uw, _ = opt_w.update(g, opt_w.init(p), p, 0)
    u0, _ = opt_0.update(g, opt_0.init(p), p, 0)
    np.testing.assert_allclose(uw["w"] - u0["w"], -1e-2 * 0.1 * 10.0, rtol=1e-5)


def test_clip_and_norm():
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    np.testing.assert_allclose(global_norm(g), 5.0)
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(global_norm(clipped), 1.0, rtol=1e-5)


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, 10, 100)
    assert float(sched(0)) == 0.0
    np.testing.assert_allclose(float(sched(10)), 1.0, rtol=1e-5)
    assert float(sched(100)) < 0.2


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 6))
def test_error_feedback_identity(seed):
    """sent + residual == corrected gradient, exactly (lossless bookkeeping)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    ef = ef_init(256)
    corrected = g + ef.residual
    idx, vals = blocked_topk_sparsify(corrected, 16)
    sent = densify(idx, vals, 256)
    residual = corrected - sent
    np.testing.assert_allclose(np.asarray(sent + residual), np.asarray(corrected),
                               rtol=1e-6, atol=1e-7)
