"""ctx.iterate / ctx.fori: one logical loop, two lowerings.

Host backend: plain Python loop with a ``ctx.guard()`` checkpoint per round.
SPMD backend: one ``lax.scan`` with the shared-value dict threaded through the
carry — lowered program size and compile time O(1) in ``iters``, traffic
accounting multiplied by the trip count.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess_devices
from repro.core import AccumMode, Session
from repro.core.session import SpmdTraffic


# -- semantics (host backend) -------------------------------------------------


def test_iterate_host_matches_manual_loop():
    sess = Session(backend="host", n_nodes=1, threads_per_node=2)
    out = sess.new_array("out", (4,))

    def proc(ctx):
        def step(theta):
            total = out.accumulate(jnp.ones(4))
            return theta + 0.5 * total
        return ctx.iterate(step, jnp.zeros(4), 3)

    results = sess.run(proc)
    # 2 threads x ones(4) -> total 2.0 per round; 3 rounds x 0.5 * 2.0 = 3.0
    for r in results:
        np.testing.assert_allclose(np.asarray(r), 3.0)


def test_fori_passes_running_index():
    sess = Session(backend="host", n_nodes=1, threads_per_node=2)

    def proc(ctx):
        return ctx.fori(lambda i, c: c + i, 0, 5)

    assert sess.run(proc) == [0 + 1 + 2 + 3 + 4] * 2


def test_iterate_zero_rounds_returns_carry():
    for backend in ("host", "spmd"):
        sess = Session(backend=backend, n_nodes=1, threads_per_node=1)

        def proc(ctx):
            return ctx.iterate(lambda c: c + 1.0, jnp.float32(7.0), 0)

        assert [float(r) for r in sess.run(proc)] == [7.0]


# -- backend parity on the scan path ------------------------------------------


def _ran_program(backend):
    """Shared get/set + accumulate + local carry, all inside ctx.iterate."""
    sess = Session(backend=backend, n_nodes=1, threads_per_node=1)
    w = sess.def_global("w", jnp.arange(4.0))
    acc = sess.new_array("acc", (4,))

    def proc(ctx, xs):
        def step(theta):
            total = acc.accumulate(xs.sum(0) * w.get())
            w.set(w.get() * 0.5)
            return theta + total
        return ctx.iterate(step, jnp.zeros(4), 4)

    res = sess.run(proc, data=(jnp.ones((2, 4)),))
    return np.asarray(res[0]), np.asarray(w.get())


def test_iterate_parity_host_vs_spmd_single_device():
    th, wh = _ran_program("host")
    ts, ws = _ran_program("spmd")
    np.testing.assert_allclose(ts, th, rtol=1e-6)
    np.testing.assert_allclose(ws, wh, rtol=1e-6)


def test_iterate_multidevice_scan_parity_and_ragged_warning():
    """4-device scan path == host results; ragged rows warn before trimming."""
    out = run_subprocess_devices("""
import warnings
import jax.numpy as jnp, numpy as np
from repro.core import Session

def program(backend, rows):
    sess = Session(backend=backend, n_nodes=2, threads_per_node=2)
    w = sess.def_global("w", jnp.ones(8))
    acc = sess.new_array("acc", (8,))
    def proc(ctx, xs):
        def step(theta):
            total = acc.accumulate(xs.sum(0) + w.get())
            w.set(total / ctx.n_threads)
            return theta + total
        return ctx.iterate(step, jnp.zeros(8), 5)
    res = sess.run(proc, data=(jnp.ones((rows, 8)),))
    return np.asarray(res[0]), np.asarray(w.get()), sess

th, wh, _ = program("host", 16)
ts, ws, ss = program("spmd", 16)
assert ss.backend.n_threads == 4
np.testing.assert_allclose(ts, th, rtol=1e-5)
np.testing.assert_allclose(ws, wh, rtol=1e-5)
assert ss.backend.stats.rounds == 5

with warnings.catch_warnings(record=True) as rec:
    warnings.simplefilter("always")
    program("spmd", 18)    # 18 % 4 == 2 ragged rows
msgs = [str(r.message) for r in rec if r.category is UserWarning]
assert any("2 ragged row" in m for m in msgs), msgs
print("ITERATE_MULTIDEVICE_OK")
""", n_devices=4)
    assert "ITERATE_MULTIDEVICE_OK" in out


# -- compile cost: O(1) in iters (the acceptance criterion) -------------------


def _lowered_lines(iters: int) -> int:
    sess = Session(backend="spmd")
    grad = sess.new_array("grad", (8,))

    def proc(ctx, xs):
        def step(theta):
            return theta + grad.accumulate(xs.sum(0))
        return ctx.iterate(step, jnp.zeros(8), iters)

    return len(sess.lower(proc, data=(jnp.ones((4, 8)),)).as_text().splitlines())


def test_spmd_iterate_program_size_constant_in_iters():
    sizes = {iters: _lowered_lines(iters) for iters in (2, 32, 256)}
    assert len(set(sizes.values())) == 1, f"lowered size varies with iters: {sizes}"


def test_session_lower_rejects_host_backend():
    sess = Session(backend="host")
    with pytest.raises(RuntimeError, match="SPMD"):
        sess.lower(lambda ctx: None)


# -- traffic accounting under the scan ----------------------------------------


def test_spmd_traffic_multiplied_by_trip_count():
    sess = Session(backend="spmd")
    n = sess.backend.n_threads
    out = sess.new_array("out", (16,))

    def proc(ctx):
        return ctx.iterate(lambda c: c + out.accumulate(jnp.ones(16))[0], 0.0, 7)

    sess.run(proc)
    assert sess.backend.stats.rounds == 7
    assert sess.wire_traffic() == (n + 1) * 16 * 7


def test_spmd_traffic_scalar_accumulate_does_not_crash():
    # regression: account() used local.shape[0], which raised on 0-d values
    stats = SpmdTraffic()
    stats.account(AccumMode.REDUCE_SCATTER, 4, 1, None)
    assert stats.bytes_transferred == 5 and stats.rounds == 1


def test_scalar_accumulate_both_backends():
    for backend, n in (("host", 4), ("spmd", None)):
        sess = (Session(backend="host", n_nodes=2, threads_per_node=2)
                if backend == "host" else Session(backend="spmd"))
        n = n or sess.backend.n_threads
        c = sess.new_array("c", ())

        def proc(ctx):
            return ctx.iterate(lambda t: t + c.accumulate(jnp.float32(2.0)),
                               jnp.float32(0.0), 3)

        res = sess.run(proc)
        assert [float(r) for r in res] == [2.0 * n * 3] * len(res)
        assert float(c.get()) == 2.0 * n
        assert sess.wire_traffic() == (n + 1) * 1 * 3
