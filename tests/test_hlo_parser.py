"""Collective-traffic HLO parser."""

from repro.utils.hlo import collective_bytes_from_hlo


HLO = """
HloModule test
%all-reduce.216 = f32[4,512,2048]{2,1,0} all-reduce(%fusion.5), channel_id=1, replica_groups=[8,8]<=[64], use_global_device_ids=true, to_apply=%add
%ag = bf16[64,128]{1,0} all-gather(%p0), channel_id=2, replica_groups=[4,4]<=[16], dimensions={0}
%rs = f32[16,128]{1,0} reduce-scatter(%p1), channel_id=3, replica_groups=[2,8]<=[16], to_apply=%add
%cp = f32[32]{0} collective-permute(%p2), source_target_pairs={{0,1},{1,0}}
%ard = f32[4]{0} all-reduce-done(%h)
%tuple_ar = (f32[128]{0}, f32[128]{0}) all-reduce(%a, %b), replica_groups=[1,4]<=[4], to_apply=%add
"""


def test_parses_ops_and_bytes():
    s = collective_bytes_from_hlo(HLO)
    # all-reduce: 4*512*2048*4 + tuple 2*128*4; -done excluded
    ar = 4 * 512 * 2048 * 4 + 2 * 128 * 4
    assert s.bytes_by_op["all-reduce"] == ar
    assert s.count_by_op["all-reduce"] == 2
    # all-gather operand = output / group(4)
    assert s.bytes_by_op["all-gather"] == 64 * 128 * 2 / 4
    # reduce-scatter operand = output * group(8)
    assert s.bytes_by_op["reduce-scatter"] == 16 * 128 * 4 * 8
    assert s.bytes_by_op["collective-permute"] == 32 * 4
    assert "all-reduce-done" not in " ".join(s.bytes_by_op)


def test_wire_model_is_ring():
    s = collective_bytes_from_hlo(HLO)
    # all-gather wire = (g-1)/g * full
    assert abs(s.wire_bytes_by_op["all-gather"] - 64 * 128 * 2 * 3 / 4) < 1e-6


def test_replica_group_list_form():
    s = collective_bytes_from_hlo(
        "%x = f32[8]{0} all-gather(%p), replica_groups={{0,1,2,3}}, dimensions={0}")
    assert s.bytes_by_op["all-gather"] == 8 * 4 / 4
