"""Architecture registry: exact assigned configs + cell skip logic."""

import pytest

from repro.configs import ARCHS, SHAPES, cell_runnable, get_arch, smoke_config


def test_all_ten_archs_registered():
    assert sorted(ARCHS) == sorted([
        "deepseek-v3-671b", "moonshot-v1-16b-a3b", "starcoder2-3b", "qwen3-4b",
        "qwen2-72b", "qwen3-1.7b", "llama-3.2-vision-90b", "zamba2-2.7b",
        "hubert-xlarge", "mamba2-2.7b",
    ])


@pytest.mark.parametrize("name,nl,dm,nh,kv,dff,vocab", [
    ("deepseek-v3-671b", 61, 7168, 128, 128, 2048, 129280),
    ("moonshot-v1-16b-a3b", 48, 2048, 16, 16, 1408, 163840),
    ("starcoder2-3b", 30, 3072, 24, 2, 12288, 49152),
    ("qwen3-4b", 36, 2560, 32, 8, 9728, 151936),
    ("qwen2-72b", 80, 8192, 64, 8, 29568, 152064),
    ("qwen3-1.7b", 28, 2048, 16, 8, 6144, 151936),
    ("llama-3.2-vision-90b", 100, 8192, 64, 8, 28672, 128256),
    ("zamba2-2.7b", 54, 2560, 32, 32, 10240, 32000),
    ("hubert-xlarge", 48, 1280, 16, 16, 5120, 504),
    ("mamba2-2.7b", 64, 2560, 1, 1, 0, 50280),
])
def test_assigned_numbers_exact(name, nl, dm, nh, kv, dff, vocab):
    c = get_arch(name)
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
        (nl, dm, nh, kv, dff, vocab)


def test_family_features():
    ds = get_arch("deepseek-v3-671b")
    assert ds.attn_kind == "mla" and ds.n_experts == 256 and ds.top_k == 8 \
        and ds.n_shared_experts == 1 and ds.mtp
    assert get_arch("moonshot-v1-16b-a3b").top_k == 6
    assert get_arch("qwen3-4b").qk_norm and get_arch("qwen2-72b").qkv_bias
    assert get_arch("zamba2-2.7b").ssm_state == 64
    assert get_arch("mamba2-2.7b").ssm_state == 128
    assert get_arch("hubert-xlarge").causal is False


def test_cell_skip_matrix():
    """40 cells: 31 runnable, 9 skipped per the assignment rules."""
    runnable = skipped = 0
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            ok, reason = cell_runnable(arch, shape)
            if ok:
                runnable += 1
            else:
                skipped += 1
                assert reason
    assert runnable == 31 and skipped == 9
    # the specific rules
    assert not cell_runnable(get_arch("qwen2-72b"), SHAPES["long_500k"])[0]
    assert cell_runnable(get_arch("mamba2-2.7b"), SHAPES["long_500k"])[0]
    assert cell_runnable(get_arch("zamba2-2.7b"), SHAPES["long_500k"])[0]
    assert not cell_runnable(get_arch("hubert-xlarge"), SHAPES["decode_32k"])[0]


def test_smoke_configs_are_small():
    for cfg in ARCHS.values():
        s = smoke_config(cfg)
        assert s.d_model <= 128 and s.n_layers <= 4 and s.vocab <= 512
        assert s.family == cfg.family and s.attn_kind == cfg.attn_kind
