"""step.shards — consistent-hash sharded store: ring, per-shard locking,
shard-local directories, elastic rebalancing, and S-sweep app parity.

The tentpole contract: ``ShardedStore(shards=1)`` is behaviour-identical to
the seed's flat ``GlobalStore``; with S>1, operations on names owned by
different shards never contend on a shared lock; a ring join/leave migrates
only the keys whose owner changed, with epochs (and delete-era generations)
preserved so no stale cache replica survives a migration; and the four
analytics apps agree host↔SPMD at S ∈ {1, 2, 8}.
"""

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DSMCache, GlobalStore, HashRing, Session, ShardedStore
from repro.ft import rebalance_shards, session_recovery


def _names_per_shard(store, per_shard: int = 1, prefix: str = "k"):
    """Find (and declare) `per_shard` names on each active shard."""
    got = {sid: [] for sid in store.shard_ids()}
    i = 0
    while any(len(v) < per_shard for v in got.values()):
        name = f"{prefix}{i}"
        i += 1
        sid = store.shard_of(name)
        if len(got[sid]) < per_shard:
            store.def_global(name, jnp.zeros(4))
            got[sid].append(name)
    return got


# -- the ring -----------------------------------------------------------------


def test_ring_deterministic_and_total():
    r1 = HashRing([0, 1, 2, 3])
    r2 = HashRing([0, 1, 2, 3])
    keys = [f"name{i}" for i in range(200)]
    assert [r1.owner(k) for k in keys] == [r2.owner(k) for k in keys]
    assert set(r1.owner(k) for k in keys) <= {0, 1, 2, 3}
    # every shard owns a non-trivial arc
    from collections import Counter
    counts = Counter(r1.owner(k) for k in keys)
    assert len(counts) == 4 and min(counts.values()) >= 10


def test_ring_change_moves_only_affected_arcs():
    old = HashRing(range(4))
    grown = old.added(4)
    keys = [f"name{i}" for i in range(500)]
    moved = [k for k in keys if old.owner(k) != grown.owner(k)]
    # only keys that the NEW shard claimed may change owner
    assert all(grown.owner(k) == 4 for k in moved)
    assert 0 < len(moved) < len(keys) // 2          # ~1/5 expected
    shrunk = old.removed(2)
    moved = [k for k in keys if old.owner(k) != shrunk.owner(k)]
    assert all(old.owner(k) == 2 for k in moved)    # only the dead shard's keys


def test_ring_validation():
    with pytest.raises(ValueError):
        HashRing([0], vnodes=0)
    store = GlobalStore(shards=2)
    with pytest.raises(ValueError):
        store.add_shard(1)          # already on the ring
    with pytest.raises(KeyError):
        store.remove_shard(9)
    store.remove_shard(1)
    with pytest.raises(ValueError):
        store.remove_shard(0)       # never remove the last shard


def test_empty_ring_owner_raises_value_error():
    # an empty ring is a legal value object (removed() of the last shard),
    # but resolving an owner on it must be a clear ValueError — it used to
    # escape as a bare ZeroDivisionError from the modulo
    ring = HashRing([])
    assert len(ring) == 0
    with pytest.raises(ValueError, match="empty hash ring"):
        ring.owner("anything")
    emptied = HashRing([3]).removed(3)
    assert emptied.ids == ()
    with pytest.raises(ValueError, match="empty hash ring"):
        emptied.owner("x")


def test_empty_ring_store_ops_raise_value_error():
    # satellite: the mutating ops surface the ring's clear ValueError too —
    # set/mget/inc on a store whose ring emptied must match owner()'s
    # contract, not escape as a KeyError or ZeroDivisionError
    store = ShardedStore(shards=1)
    store.def_global("a", jnp.zeros(4))
    store._ring = HashRing([])            # simulate the last arc vanishing
    with pytest.raises(ValueError, match="empty hash ring"):
        store.set("a", jnp.ones(4))
    with pytest.raises(ValueError, match="empty hash ring"):
        store.mget(["a"])
    with pytest.raises(ValueError, match="empty hash ring"):
        store.inc("a", 1.0)
    with pytest.raises(ValueError, match="empty hash ring"):
        store.get("a")


def test_ring_version_bumps_on_topology_change():
    ring = HashRing([0, 1])
    assert ring.version == 0
    grown = ring.added(2)
    assert grown.version == 1
    assert grown.removed(2).version == 2
    assert ring.version == 0                      # immutable: original untouched

    store = GlobalStore(shards=2)
    assert store.ring_version == 0
    store.add_shard()
    assert store.ring_version == 1
    store.remove_shard(2)
    assert store.ring_version == 2


def test_stale_owner_handle_across_rebalance():
    """A memoised OwnerHandle must keep every op correct across add_shard/
    remove_shard: a stale handle is ignored (the op re-hashes), a current
    one routes straight to the shard."""
    store = GlobalStore(shards=2)
    names = [f"h{i}" for i in range(64)]
    for i, n in enumerate(names):
        store.def_global(n, jnp.float32(i))
    handles = {n: store.owner_handle(n) for n in names}
    for n, h in handles.items():
        assert h.version == 0 and h.shard == store.shard_of(n)
        assert float(store.get(n, owner=h)) == float(store.get(n))

    mig = store.add_shard()                 # every handle is now stale
    assert store.ring_version == 1
    assert mig.moved                        # some names actually migrated
    for i, n in enumerate(names):
        # stale handles (wrong shard for moved names) must still resolve
        assert float(store.get(n, owner=handles[n])) == float(i)
        store.set(n, jnp.float32(i * 2), owner=handles[n])
        assert float(store.inc(n, 1, owner=handles[n])) == float(i * 2 + 1)
    vals = store.mget(names, owners=[handles[n] for n in names])
    assert [float(v) for v in vals] == [float(i * 2 + 1) for i in range(len(names))]

    # refreshed handles route correctly under the new topology too
    fresh = {n: store.owner_handle(n) for n in names}
    store.remove_shard(2)
    assert store.ring_version == 2
    for i, n in enumerate(names):           # stale again, still correct
        assert float(store.get(n, owner=fresh[n])) == float(i * 2 + 1)


def test_owner_handles_in_mget_must_align():
    store = GlobalStore(shards=2)
    store.def_global("a", 1.0)
    store.def_global("b", 2.0)
    with pytest.raises(ValueError, match="align"):
        store.mget(["a", "b"], owners=[store.owner_handle("a")])


# -- S=1 flat-store equivalence ----------------------------------------------


def test_single_shard_matches_flat_store_semantics():
    s = GlobalStore(shards=1)
    assert s.n_shards == 1 and s.shard_ids() == [0]
    s.def_global("x", jnp.arange(4.0))
    s.new_array("a", (8,), jnp.int32)
    s.new_object("o", {"w": jnp.ones((2, 2)), "b": jnp.zeros(2)})
    assert s.shard_of("x") == 0
    np.testing.assert_allclose(s.get("x"), [0, 1, 2, 3])
    s.set("x", jnp.ones(4))
    assert s.epoch("x") == 1
    va, vo = s.mget(["x", "o"])
    np.testing.assert_allclose(va, 1.0)
    assert set(vo) == {"w", "b"}
    assert int(s.inc("x", 5)[0]) == 6
    # delete→redeclare starts strictly past the deleted era (generation fix)
    s.delete("x")
    s.def_global("x", jnp.zeros(4))
    assert s.epoch("x") > 1
    assert sorted(s.names()) == ["a", "o", "x"]
    assert s.stats["set"] >= 1 and s.stats["inc"] == 1


def test_mget_one_round_trip_per_shard_touched():
    s = GlobalStore(shards=4)
    per = _names_per_shard(s, per_shard=2)
    names = [n for group in per.values() for n in group]
    base = s.stats["get"]
    base_tr = s.stats["transfers"]
    s.mget(names)
    shards_touched = len({s.shard_of(n) for n in names})
    assert shards_touched == 4
    assert s.stats["get"] - base == shards_touched
    assert s.stats["transfers"] - base_tr == shards_touched


# -- per-shard locking: the concurrency acceptance criterion -------------------


def test_ops_on_other_shards_run_while_one_shard_lock_is_held():
    """Hold shard A's lock; reads/writes/incs on shard-B names (through the
    cache, exactly the worker path) must complete — pre-shards, the single
    Session._cache_lock serialised them behind the holder."""
    store = GlobalStore(shards=8)
    cache = DSMCache(store, n_nodes=4)
    per = _names_per_shard(store)
    (sid_a, (name_a,)), (sid_b, (name_b,)) = [
        (sid, tuple(v)) for sid, v in list(per.items())[:2]]
    assert sid_a != sid_b

    other_done = threading.Event()
    blocked_done = threading.Event()

    def touch_other_shard():
        cache.write(0, name_b, jnp.ones(4))
        cache.read(1, name_b)
        store.inc(name_b, 1.0)
        other_done.set()

    def touch_held_shard():
        store.get(name_a)
        blocked_done.set()

    lock_a = store.shard_for(name_a).lock
    lock_a.acquire()
    try:
        t1 = threading.Thread(target=touch_other_shard, daemon=True)
        t1.start()
        assert other_done.wait(10.0), \
            "ops on a different shard blocked behind a held shard lock"
        t2 = threading.Thread(target=touch_held_shard, daemon=True)
        t2.start()
        time.sleep(0.2)
        assert not blocked_done.is_set(), \
            "an op on the held shard must wait for its lock"
    finally:
        lock_a.release()
    assert blocked_done.wait(10.0)
    t1.join(5)
    t2.join(5)


def test_concurrent_cached_rw_mix_across_shards_is_coherent():
    """Stress: 4 worker nodes hammer a read/write/inc mix over names spread
    across 8 shards; every read must observe a value some writer published
    (epoch coherence holds with per-shard locks, no global serialisation)."""
    sess = Session(backend="host", n_nodes=4, threads_per_node=1, shards=8)
    refs = [sess.new_array(f"v{i}", (4,)) for i in range(16)]
    counter = sess.def_global("hits", 0.0)

    def proc(ctx):
        for round_ in range(30):
            r = refs[(ctx.tid * 7 + round_) % len(refs)]
            if round_ % 3 == ctx.tid % 3:
                r.set(jnp.full((4,), float(round_)))
            v = np.asarray(r.get())
            assert v.shape == (4,) and np.all(v == v[0])  # never torn
            counter.inc(1.0)
        return True

    assert sess.run(proc) == [True] * 4
    assert float(counter.get()) == 4 * 30
    with pytest.warns(DeprecationWarning, match="Session.shard_stats"):
        stats = sess.shard_stats()
    assert set(stats) == set(sess.store.shard_ids())
    # the namespace genuinely spread: several shards saw traffic
    busy = [sid for sid, row in stats.items() if row["store"]["get"] > 0]
    assert len(busy) >= 2


# -- elastic rebalancing -------------------------------------------------------


def test_rebalance_moves_only_changed_owners_epochs_survive():
    store = GlobalStore(shards=4)
    names = [f"n{i}" for i in range(120)]
    for i, n in enumerate(names):
        store.def_global(n, float(i))
        store.set(n, float(i) + 1.0)        # every epoch distinct from fresh
    owners = {n: store.shard_of(n) for n in names}
    epochs = {n: store.epoch(n) for n in names}

    mig = store.add_shard()                  # join: shard 4
    assert mig.added == (4,) and not mig.removed
    for n, (src, dst) in mig.moved.items():
        assert owners[n] == src and dst == 4
    for n in names:
        if n not in mig.moved:               # unmoved keys keep their owner
            assert store.shard_of(n) == owners[n]
        assert store.epoch(n) == epochs[n] == mig.epochs.get(n, epochs[n])
        np.testing.assert_allclose(np.asarray(store.get(n)),
                                   float(names.index(n)) + 1.0)
    assert 0 < mig.moved_fraction < 0.5      # ~1/5 of the namespace

    owners2 = {n: store.shard_of(n) for n in names}
    mig2 = store.remove_shard(1)             # leave: shard 1 hands off its arc
    assert set(mig2.moved) == {n for n in names if owners2[n] == 1}
    for n in names:
        assert store.epoch(n) == epochs[n]
    assert store.shard_ids() == [0, 2, 3, 4]


def test_rebalance_preserves_delete_generations():
    """A name deleted before the migration must still redeclare strictly past
    its retired epoch on its NEW owner shard."""
    store = GlobalStore(shards=2)
    store.def_global("victim", jnp.ones(4))
    store.set("victim", jnp.zeros(4))
    retired_epoch = store.epoch("victim")
    store.delete("victim")
    # force the arc to move: grow the ring until the owner changes
    old_owner = store.shard_of("victim")
    while store.shard_of("victim") == old_owner:
        store.add_shard()
    store.def_global("victim", jnp.full((4,), 9.0))
    assert store.epoch("victim") > retired_epoch


def test_no_stale_replica_survives_migration():
    """Cache replicas validated by epoch stay exact across a migration, and
    the migrated directory record still drives invalidation on the new
    owner shard."""
    store = GlobalStore(shards=2)
    cache = DSMCache(store, n_nodes=2)
    store.def_global("m", jnp.full((4,), 1.0))
    np.testing.assert_allclose(cache.read(0, "m"), 1.0)   # node 0 replica
    old_owner = store.shard_of("m")
    while store.shard_of("m") == old_owner:
        store.add_shard()
    # directory record migrated with the entry: a write by node 1 must still
    # invalidate node 0's replica
    cache.write(1, "m", jnp.full((4,), 2.0))
    assert cache.stats.invalidations == 1
    np.testing.assert_allclose(cache.read(0, "m"), 2.0)   # fresh, not stale
    # and the epoch-validated fast path still hits after refresh
    hits = cache.stats.hits
    np.testing.assert_allclose(cache.read(0, "m"), 2.0)
    assert cache.stats.hits == hits + 1


def test_store_side_delete_hook_kills_phantom_holders():
    """Satellite: GlobalStore.delete called DIRECTLY (not via Session.delete)
    must tear down cache replicas and directory holders — pre-hook, phantom
    holders persisted until eviction."""
    store = GlobalStore(shards=2)
    cache = DSMCache(store, n_nodes=3)
    store.def_global("p", jnp.full((4,), 5.0))
    for node in range(3):
        cache.read(node, "p")
    assert any("p" in d for d in cache.directory)
    store.delete("p")                         # direct store-level delete
    assert all("p" not in c.blocks for c in cache.caches)
    assert all("p" not in d for d in cache.directory)
    store.def_global("p", jnp.full((4,), 7.0))
    misses = cache.stats.misses
    np.testing.assert_allclose(cache.read(0, "p"), 7.0)   # miss, not phantom
    assert cache.stats.misses == misses + 1


def test_session_recovery_rebalances_ring_under_drill_scenario():
    """The fault_tolerance_drill scenario on a sharded store: node 2 dies,
    session_recovery removes its shard — only its keys migrate (epochs
    preserved) and the recovered session keeps computing correctly."""
    from repro.analytics import kmeans
    from repro.data import kmeans_dataset

    x, _, _ = kmeans_dataset(400, 8, 4, seed=0)
    sess = Session(backend="host", n_nodes=4, threads_per_node=2, shards=4)
    kmeans.fit(x, 4, iters=2, seed=0, session=sess)
    names = sess.names()
    owners = {n: sess.store.shard_of(n) for n in names}
    epochs = {n: sess.store.epoch(n) for n in names}

    sess.kill_node(2)
    plan, recovered = session_recovery(sess, [2], mode="multi")
    assert plan.migration is not None and plan.migration.removed == (2,)
    assert set(plan.migration.moved) == {n for n in names if owners[n] == 2}
    assert recovered.store is sess.store
    assert recovered.store.shard_ids() == [0, 1, 3]
    for n in names:
        assert recovered.store.epoch(n) == epochs[n]
        if owners[n] != 2:
            assert recovered.store.shard_of(n) == owners[n]
    centers, _ = kmeans.fit(x, 4, iters=2, seed=0, session=recovered)
    ref = kmeans.fit_reference(x, 4, iters=2, seed=0)

    # compare the clustering objective, not raw coordinates: host accumulator
    # rounds sum in thread-arrival order, and a boundary point flipping
    # cluster under fp non-associativity may shift a center slightly
    def inertia(c):
        d = np.linalg.norm(np.asarray(x)[:, None, :] - np.asarray(c)[None],
                           axis=-1)
        return float(np.mean(np.min(d, axis=1) ** 2))

    assert abs(inertia(centers) - inertia(ref)) <= 0.05 * inertia(ref)


def test_session_recovery_keeps_ring_when_shards_dont_follow_nodes():
    """A failed NODE id must not evict a coincidentally-matching SHARD id:
    with shards != n_nodes the ids are unrelated and the ring stays put."""
    sess = Session(backend="host", n_nodes=4, threads_per_node=1, shards=8)
    plan, _ = session_recovery(sess, [2], mode="multi")
    assert plan.migration is None
    assert sess.store.shard_ids() == list(range(8))
    # explicit opt-in still forces the removal
    plan, _ = session_recovery(sess, [2], mode="multi", rebalance=True)
    assert plan.migration is not None and plan.migration.removed == (2,)
    assert sess.store.shard_ids() == [0, 1, 3, 4, 5, 6, 7]


def test_recovered_smaller_world_tolerates_stale_holder_records():
    """The shard directory's holder ids are session-relative, but the store
    outlives sessions: after FT recovery shrinks the world, a record left by
    the dead session's highest node must not be used to index the smaller
    session's replica list (was an IndexError whenever the old last writer
    was a node beyond the new world — ~1/4 of recovery-drill runs)."""
    store = GlobalStore(shards=2)
    store.def_global("w", jnp.zeros(4))
    old = Session(backend="host", n_nodes=4, threads_per_node=1, store=store)
    old.cache.write(3, "w", jnp.ones(4))      # node 3 is now the sole holder
    new = Session(backend="host", n_nodes=2, threads_per_node=1, store=store)
    new.cache.write(0, "w", jnp.full(4, 2.0))  # must drop the stale record
    with store.locked_owner("w") as shard:
        assert shard.directory["w"] == {0}
    assert float(np.asarray(new.cache.read(1, "w"))[0]) == 2.0


def test_delete_hooks_do_not_pin_dead_session_caches():
    """FT recovery rolls new sessions over a surviving store; each session's
    cache registers a delete hook.  The hooks must be weak: a collected
    session's cache drops off the hook list instead of leaking forever."""
    import gc

    store = GlobalStore(shards=2)
    store.def_global("h", jnp.ones(4))
    for _ in range(5):
        sess = Session(backend="host", n_nodes=2, threads_per_node=1,
                       store=store)
        sess.run(lambda ctx: float(np.asarray(sess.ref("h").get())[0]))
        del sess
    gc.collect()
    store.delete("h")     # fires hooks: dead ones must have been pruned
    assert len(store._delete_hooks) <= 1   # at most the GC-pending newest


def test_rebalance_shards_merges_join_and_leave():
    store = GlobalStore(shards=2)
    for i in range(40):
        store.def_global(f"j{i}", float(i))
    mig = rebalance_shards(store, join=[2, 3], leave=[0])
    assert mig.added == (2, 3) and mig.removed == (0,)
    assert store.shard_ids() == [1, 2, 3]
    assert all(store.shard_of(n) != 0 for n in store.names())
    # no-op topology changes report None
    assert rebalance_shards(store, join=[2], leave=[9]) is None


# -- app parity across shard counts (the acceptance criterion) -----------------


@pytest.mark.parametrize("shards", [1, 2, 8])
def test_apps_host_spmd_parity_across_shard_counts(shards):
    """All four analytics apps: host and SPMD sessions over an S-shard store
    produce the flat-store reference results — sharding is invisible to the
    programming model at every S."""
    from repro.analytics import kmeans, logreg, nmf, pagerank
    from repro.data import kmeans_dataset, logreg_dataset, nmf_dataset, powerlaw_graph

    def sessions():
        return (Session(backend="host", n_nodes=2, threads_per_node=2,
                        shards=shards),
                Session(backend="spmd", shards=shards))

    x, y, _ = logreg_dataset(200, 16, seed=0)
    ref = logreg.fit(x, y, iters=4,
                     session=Session(backend="host", n_nodes=2,
                                     threads_per_node=2))[0]
    h, s = sessions()
    np.testing.assert_allclose(logreg.fit(x, y, iters=4, session=h)[0], ref,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(logreg.fit(x, y, iters=4, session=s)[0], ref,
                               rtol=1e-4, atol=1e-5)

    xk, _, _ = kmeans_dataset(240, 8, 4, seed=1)
    refc = kmeans.fit(xk, 4, iters=3, seed=1,
                      session=Session(backend="host", n_nodes=2,
                                      threads_per_node=2))[0]
    h, s = sessions()
    np.testing.assert_allclose(kmeans.fit(xk, 4, iters=3, seed=1,
                                          session=h)[0], refc,
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(kmeans.fit(xk, 4, iters=3, seed=1,
                                          session=s)[0], refc,
                               rtol=1e-3, atol=1e-3)

    r, _, _ = nmf_dataset(60, 16, 3, seed=2)
    h, s = sessions()
    p_h, q_h, _ = nmf.fit(r, 3, iters=4, seed=2, session=h)
    p_s, q_s, _ = nmf.fit(r, 3, iters=4, seed=2, session=s)
    np.testing.assert_allclose(nmf.frob_loss(r, p_s, q_s),
                               nmf.frob_loss(r, p_h, q_h), rtol=1e-2)

    edges = powerlaw_graph(120, 4, seed=3)
    refr = pagerank.fit(edges, 120, iters=4,
                        session=Session(backend="host", n_nodes=2,
                                        threads_per_node=2))[0]
    h, s = sessions()
    np.testing.assert_allclose(pagerank.fit(edges, 120, iters=4,
                                            session=h)[0], refr,
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(pagerank.fit(edges, 120, iters=4,
                                            session=s)[0], refr,
                               rtol=1e-4, atol=1e-6)


def test_shard_stats_attributes_wire_traffic_to_output_shard():
    sess = Session(backend="host", n_nodes=2, threads_per_node=2, shards=4)
    out = sess.new_array("out", (16,))

    def proc(ctx):
        return float(out.accumulate(jnp.ones(16))[0])

    assert sess.run(proc) == [4.0] * 4
    with pytest.warns(DeprecationWarning, match="Session.shard_stats"):
        stats = sess.shard_stats()
    sid = out.shard
    assert stats[sid]["wire_traffic"] == (4 + 1) * 16 == sess.wire_traffic()
    assert sum(row["wire_traffic"] for row in stats.values()) == sess.wire_traffic()
    # store per-shard counters roll up to the aggregate
    assert (sum(row["store"]["set"] for row in stats.values())
            == sess.store.stats["set"])
