"""GlobalStore DSM + coarse-grained packing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import GlobalStore, pack_spec, pack_tree, unpack_tree


def test_def_get_set():
    s = GlobalStore()
    s.def_global("x", jnp.arange(4.0))
    np.testing.assert_allclose(s.get("x"), [0, 1, 2, 3])
    s.set("x", jnp.ones(4))
    np.testing.assert_allclose(s.get("x"), 1.0)
    assert s.epoch("x") == 1


def test_arrays_objects_delete():
    s = GlobalStore()
    s.new_array("a", (8,), jnp.int32)
    assert s.get("a").shape == (8,)
    s.new_object("obj", {"w": jnp.ones((2, 2)), "b": jnp.zeros(2)})
    obj = s.get("obj")
    assert set(obj) == {"w", "b"}
    s.delete("obj")
    with pytest.raises(KeyError):
        s.get("obj")


def test_mget_and_inc():
    s = GlobalStore()
    s.def_global("a", 1)
    s.def_global("b", 2)
    va, vb = s.mget(["a", "b"])
    assert int(va) == 1 and int(vb) == 2
    assert int(s.inc("a", 5)) == 6


def test_transfer_accounting_fine_vs_coarse():
    fine = GlobalStore(granularity="fine")
    coarse = GlobalStore(granularity="coarse")
    for s in (fine, coarse):
        s.new_array("v", (256,), jnp.float32)
        s.get("v")
    # 256 f32 = 1024 bytes = 256 words fine-grained vs 1 bulk transfer
    assert fine.stats["transfers"] == 256
    assert coarse.stats["transfers"] == 1


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(1, 40), min_size=1, max_size=6))
def test_pack_roundtrip(sizes):
    tree = {f"l{i}": jnp.arange(float(n)) for i, n in enumerate(sizes)}
    spec = pack_spec(tree)
    buf = pack_tree(tree, spec)
    assert buf.shape[0] % 128 == 0  # package aligned
    back = unpack_tree(buf, spec)
    for k in tree:
        np.testing.assert_allclose(back[k], tree[k])


def test_pack_mixed_shapes_dtypes():
    tree = {"a": jnp.ones((3, 5), jnp.float32), "b": jnp.zeros((130,), jnp.float32)}
    spec = pack_spec(tree)
    back = unpack_tree(pack_tree(tree, spec), spec)
    assert back["a"].shape == (3, 5) and back["b"].shape == (130,)
