"""End-to-end behaviour: train loss decreases, checkpoint-resume exactness,
serve path, FT recovery mid-training."""

import os
import tempfile

import numpy as np

from repro.launch.train import train
from repro.launch.serve import serve


def test_train_loss_decreases():
    losses = train("qwen3-1.7b", smoke=True, steps=15, batch=4, seq=64, lr=3e-3)
    assert len(losses) == 15
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_checkpoint_resume_exact():
    with tempfile.TemporaryDirectory() as d:
        full = train("qwen3-1.7b", smoke=True, steps=10, batch=2, seq=32,
                     ckpt_dir=None, seed=3)
        # run 6 steps, checkpoint at 5, then resume to 10 (same LR horizon)
        train("qwen3-1.7b", smoke=True, steps=6, batch=2, seq=32,
              ckpt_dir=d, ckpt_every=5, seed=3, total_steps=10)
        resumed = train("qwen3-1.7b", smoke=True, steps=10, batch=2, seq=32,
                        ckpt_dir=d, ckpt_every=5, seed=3)
        # data stream is stateless ⇒ resumed steps reproduce the full run
        np.testing.assert_allclose(resumed[-1], full[-1], rtol=1e-4, atol=1e-5)


def test_serve_decode_runs():
    toks = serve("qwen3-1.7b", smoke=True, batch=2, prompt_len=8, gen=8)
    assert toks.shape == (2, 8)


def test_ssm_serve_runs():
    toks = serve("mamba2-2.7b", smoke=True, batch=2, prompt_len=4, gen=4)
    assert toks.shape == (2, 4)


def test_thread_pool_failure_recovery_end_to_end():
    """Kill a node mid-kmeans; recover from checkpointed centers; finish."""
    import jax.numpy as jnp
    from repro.analytics import kmeans
    from repro.data import kmeans_dataset
    from repro.ft import restore_checkpoint, save_checkpoint

    x, _, _ = kmeans_dataset(400, 8, 4, seed=0)
    with tempfile.TemporaryDirectory() as d:
        # phase 1: run 4 iters, checkpoint
        c1, _, _ = kmeans.fit_threads(x, 4, n_nodes=2, threads_per_node=2,
                                      iters=4, seed=0)
        save_checkpoint(d, 4, {"centers": c1})
        # failure + recovery: resume on a SMALLER pool from the checkpoint
        restored, _, _ = restore_checkpoint(d, {"centers": c1})
        # continue 4 more iterations on survivors (1 node)
        from repro.core import GlobalStore
        ref = kmeans.fit_reference(x, 4, iters=8, seed=0)
        # (sequential continuation for determinism)
        import jax
        centers = jnp.asarray(restored["centers"])
        for _ in range(4):
            a, _dist = kmeans._assign(jnp.asarray(x), centers)
            sums, counts = kmeans._partials(jnp.asarray(x), a, 4)
            centers = sums / jnp.maximum(counts[:, None], 1.0)
        np.testing.assert_allclose(np.sort(np.asarray(centers), axis=0),
                                   np.sort(ref, axis=0), rtol=1e-3, atol=1e-3)
