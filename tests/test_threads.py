"""DThread pool (paper §4.2) + failure simulation."""

import time

from repro.core import DThreadPool, ThreadState


def test_pool_runs_thread_procs():
    pool = DThreadPool(n_nodes=2, threads_per_node=3)

    def proc(tid, param):
        return tid * param

    pool.create_threads(proc, param=10)
    pool.start_all()
    pool.join_all()
    assert [t.result for t in pool.threads] == [0, 10, 20, 30, 40, 50]
    assert all(t.get_state() == ThreadState.COMPLETED for t in pool.threads)
    assert {t.node_id for t in pool.threads} == {0, 1}


def test_kill_node_marks_lost():
    pool = DThreadPool(n_nodes=2, threads_per_node=2)
    import threading
    release = threading.Event()

    def proc(tid, _):
        while not release.is_set():
            pool.checkpoint_guard(tid)
            time.sleep(0.01)
        return tid

    pool.create_threads(proc)
    pool.start_all()
    lost = pool.kill_node(1)
    assert lost == [2, 3]
    time.sleep(0.1)
    release.set()
    pool.join_all(5)
    states = pool.states()
    assert states[2] == ThreadState.LOST and states[3] == ThreadState.LOST
    assert states[0] == ThreadState.COMPLETED
    assert pool.healthy_nodes() == [0]
