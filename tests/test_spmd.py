"""SPMD-layer tests (multi-device): run in subprocesses with forced devices."""

import pytest

from conftest import run_subprocess_devices


def test_accumulate_modes_spmd():
    out = run_subprocess_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import accumulate
from repro.core.compat import make_mesh, shard_map
mesh = make_mesh((4, 2), ("data", "model"))
V = 64
x = jnp.arange(4 * V, dtype=jnp.float32).reshape(4, V)
expect = np.sum(np.asarray(x), axis=0)
for mode in ["gather_all", "reduce_scatter", "hierarchical"]:
    f = shard_map(lambda v: accumulate(v[0], "data", mode, inner_axis="data")[None],
                  mesh=mesh, in_specs=P("data", None), out_specs=P("data", None), check_vma=False)
    np.testing.assert_allclose(np.asarray(jax.jit(f)(x))[0], expect, rtol=1e-6)
xs = np.zeros((4, V), np.float32)
for i in range(4): xs[i, i*3:i*3+2] = i + 1.0
for mode, inp, exp in [("sparse", jnp.asarray(xs), xs.sum(0)), ("auto", x, expect)]:
    f = shard_map(lambda v: accumulate(v[0], "data", mode, k=8)[None],
                  mesh=mesh, in_specs=P("data", None), out_specs=P("data", None), check_vma=False)
    np.testing.assert_allclose(np.asarray(jax.jit(f)(inp))[0], exp, rtol=1e-6)
print("SPMD_ACCUM_OK")
""")
    assert "SPMD_ACCUM_OK" in out


def test_zero1_matches_replicated_adamw():
    out = run_subprocess_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim import adamw, zero1_init, zero1_update
from repro.core.dsm import pack_spec
from repro.core.compat import axis_size, make_mesh, shard_map
mesh = make_mesh((8,), ("data",))
params = {"w": jnp.ones((13, 7), jnp.bfloat16), "b": jnp.zeros((5,), jnp.bfloat16)}
spec = pack_spec(params)
opt = adamw(lr=0.1, weight_decay=0.0)
grads = [{"w": jnp.full((13,7), float(i+1), jnp.float32), "b": jnp.full((5,), .5*(i+1), jnp.float32)} for i in range(8)]
mean_g = jax.tree.map(lambda *g: sum(g)/8.0, *grads)
st = opt.init(jax.tree.map(lambda p: p.astype(jnp.float32), params))
upd, _ = opt.update(mean_g, st, jax.tree.map(lambda p: p.astype(jnp.float32), params), 0)
ref = jax.tree.map(lambda p, u: p.astype(jnp.float32) + u, params, upd)
gstack = jax.tree.map(lambda *g: jnp.stack(g), *grads)
def step(gs):
    g = jax.tree.map(lambda x: x[0], gs)
    zst = zero1_init(params, opt, axis_size("data"), jax.lax.axis_index("data"), spec)
    newp, _ = zero1_update(g, zst, opt, "data", spec)
    return jax.tree.map(lambda x: x[None], newp)
f = jax.jit(shard_map(step, mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_vma=False))
got = jax.tree.map(lambda x: np.asarray(x[0], np.float32), f(gstack))
for k in ("w", "b"):
    np.testing.assert_allclose(got[k], np.asarray(ref[k]), rtol=2e-2, atol=2e-2)
print("ZERO1_OK")
""")
    assert "ZERO1_OK" in out


def test_analytics_spmd_paths():
    out = run_subprocess_devices("""
import numpy as np, jax
from repro.data import logreg_dataset, powerlaw_graph
from repro.analytics import logreg, pagerank
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(data=4)
x, y, _ = logreg_dataset(400, 24, seed=0)
ref = logreg.fit_reference(x, y, iters=8, lr=1e-3)
sp = logreg.fit_spmd(x, y, mesh, iters=8, lr=1e-3)
np.testing.assert_allclose(sp, ref, rtol=1e-4, atol=1e-5)
edges = powerlaw_graph(300, 5, seed=3)
rr = pagerank.fit_reference(edges, 300, iters=8)
rs = pagerank.fit_spmd(edges, 300, mesh, iters=8)
np.testing.assert_allclose(rs, rr, rtol=1e-4, atol=1e-6)
print("ANALYTICS_SPMD_OK")
""", n_devices=4)
    assert "ANALYTICS_SPMD_OK" in out


def test_compressed_accumulate_error_feedback():
    out = run_subprocess_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim import compressed_accumulate, ef_init
from repro.core.compat import make_mesh, shard_map
mesh = make_mesh((4,), ("data",))
V, k = 512, 64
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=(4, V)), jnp.float32)
def step(gs):
    ef = ef_init(V)
    total, ef2 = compressed_accumulate(gs[0], ef, "data", k)
    return total[None], ef2.residual[None]
f = jax.jit(shard_map(step, mesh=mesh, in_specs=P("data", None),
                      out_specs=(P("data", None), P("data", None)), check_vma=False))
total, resid = f(g)
# per-device identity: sent + residual = corrected
print("EF_OK", float(jnp.sum(jnp.abs(total))) > 0)
""", n_devices=4)
    assert "EF_OK True" in out


def test_elastic_restore_across_mesh_sizes():
    """FT: checkpoint on a 4-way mesh, recover onto 2-way (multi-node recovery)
    and back onto 8-way (elastic scale-up) — values identical everywhere."""
    out = run_subprocess_devices("""
import tempfile, jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.ft import save_checkpoint, elastic_restore
from repro.launch.mesh import _mk

tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((8,))}
specs = {"w": P("data", None), "b": P()}
with tempfile.TemporaryDirectory() as d:
    m4 = _mk((4,), ("data",), devices=jax.devices()[:4])
    placed = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(m4, s)), tree, specs)
    save_checkpoint(d, 7, placed)
    # scale DOWN to 2 devices (node failure)
    m2 = _mk((2,), ("data",), devices=jax.devices()[:2])
    r2, _, step = elastic_restore(d, tree, m2, specs)
    assert step == 7
    np.testing.assert_allclose(np.asarray(r2["w"]), np.asarray(tree["w"]))
    assert len(r2["w"].sharding.device_set) == 2
    # scale UP to 8 devices (capacity returns)
    m8 = _mk((8,), ("data",))
    r8, _, _ = elastic_restore(d, tree, m8, specs)
    np.testing.assert_allclose(np.asarray(r8["w"]), np.asarray(tree["w"]))
    assert len(r8["w"].sharding.device_set) == 8
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out


def test_perf_knobs_preserve_numerics():
    """seq_shard / remat / block_k are layout-only: loss identical (fp tol)."""
    out = run_subprocess_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch, smoke_config
from repro.launch.mesh import _mk
from repro.launch import shardings as sh
from repro.models.build import build_model

mesh = _mk((2, 2), ("data", "model"))
sh.set_mesh_axis_sizes(mesh)
base = smoke_config(get_arch("qwen3-1.7b")).replace(batch_axes=("data",))
opt_cfgs = {
    "baseline": base,
    "sp": base.replace(seq_shard=True),
    "sp_dots_b128": base.replace(seq_shard=True, remat="dots", block_k=128),
    "full_remat": base.replace(remat="full"),
}
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, base.vocab, (4, 64)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, base.vocab, (4, 64)), jnp.int32)}
losses = {}
with mesh:
    for name, cfg in opt_cfgs.items():
        m = build_model(cfg, data_groups=2)
        p = m.init(jax.random.PRNGKey(0))
        loss, _ = jax.jit(m.loss_fn)(p, batch)
        losses[name] = float(loss)
ref = losses["baseline"]
for name, l in losses.items():
    np.testing.assert_allclose(l, ref, rtol=2e-5), name
print("KNOBS_EQUIV_OK", losses)
""")
    assert "KNOBS_EQUIV_OK" in out


def test_moe_ep_alltoall_matches_dense_oracle():
    """shard_map EP dispatch (all_to_all over the expert axis) == dense oracle."""
    out = run_subprocess_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import _mk
from repro.launch import shardings as sh
from repro.models.ffn import MoEConfig, init_moe, moe_ffn

mesh = _mk((2, 4), ("data", "model"))
sh.set_mesh_axis_sizes(mesh)
cfg_ep = MoEConfig(d_model=16, n_experts=8, top_k=2, d_ff_expert=8,
                   capacity_factor=8.0, impl="ep")
p = init_moe(jax.random.PRNGKey(0), cfg_ep)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(4, 16, 16)), jnp.float32)
with mesh:
    y_ep, aux_ep = jax.jit(lambda p, x: moe_ffn(p, x, cfg_ep))(p, x)
    y_d, aux_d = jax.jit(lambda p, x: moe_ffn(p, x, cfg_ep._replace(impl="dense")))(p, x)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_d), rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(float(aux_ep), float(aux_d), rtol=0.25)
g = jax.jit(jax.grad(lambda p, x: moe_ffn(p, x, cfg_ep)[0].sum()))(p, x)
gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
assert gn > 0 and np.isfinite(gn)
print("EP_ORACLE_OK")
""")
    assert "EP_ORACLE_OK" in out
