import os
import sys

# src layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest


@pytest.fixture(scope="session")
def rng():
    import numpy as np
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _tracer_leak_guard():
    """Fail any test that leaves an *enabled* tracer armed: step.trace is
    no-op by default, and a leaked global arm would silently tax every test
    (and benchmark) that runs after it."""
    yield
    telemetry = sys.modules.get("repro.core.telemetry")
    if telemetry is None:
        return
    leaked = telemetry.armed_count()
    if leaked:
        telemetry.reset()
        pytest.fail(f"test leaked {leaked} enabled tracer(s): disable() or "
                    "reset() tracers you arm (Session(trace=True) tracers "
                    "included) before the test returns")


@pytest.fixture(autouse=True)
def _checker_leak_guard():
    """Same contract for step.check: a leaked armed checker would tax (and
    potentially fail, via strict lint) every later test."""
    yield
    stepcheck = sys.modules.get("repro.check.checker")
    if stepcheck is None:
        return
    leaked = stepcheck.armed_count()
    if leaked:
        stepcheck.reset()
        pytest.fail(f"test leaked {leaked} enabled checker(s): disable() or "
                    "reset() checkers you arm (Session(check=True) checkers "
                    "included) before the test returns")


def run_subprocess_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run a code snippet in a fresh process with a forced host device count.

    Multi-device SPMD tests must NOT set xla_force_host_platform_device_count
    in this process (smoke tests see 1 device), so they shell out.
    """
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env, timeout=timeout,
                          capture_output=True, text=True)
    assert proc.returncode == 0, f"subprocess failed:\n{proc.stdout}\n{proc.stderr}"
    return proc.stdout
