"""step.Session facade: Table-1 handles, backend parity, DSM fixes."""

import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from conftest import run_subprocess_devices
from repro.analytics import kmeans, logreg
from repro.core import AccumMode, Session
from repro.core.compat import make_mesh
from repro.core.dsm import GlobalStore
from repro.data import kmeans_dataset, logreg_dataset


# -- Table-1 handle API -------------------------------------------------------


def test_handles_def_get_set_inc():
    sess = Session(backend="host")
    x = sess.def_global("x", jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(x.get()), [0, 1, 2, 3])
    x.set(jnp.ones(4))
    assert x.epoch == 1
    np.testing.assert_allclose(np.asarray(x.inc(2.0)), 3.0)
    arr = sess.new_array("a", (8,))
    assert arr.get().shape == (8,)
    obj = sess.new_object("o", {"w": jnp.ones((2, 2)), "b": jnp.zeros(2)})
    assert set(obj.get()) == {"w", "b"}
    assert x.address != arr.address
    obj.delete()
    with pytest.raises(KeyError):
        sess.ref("o")


def test_accumulate_outside_worker_is_an_error():
    sess = Session(backend="host")
    out = sess.new_array("out", (4,))
    with pytest.raises(RuntimeError, match="collective"):
        out.accumulate(jnp.ones(4))


def test_spawn_accumulate_and_traffic_accounting():
    sess = Session(backend="host", n_nodes=2, threads_per_node=2)
    out = sess.new_array("out", (16,))

    def proc(ctx):
        total = out.accumulate(jnp.ones(16))
        return float(total[0])

    results = sess.run(proc)
    assert results == [4.0] * 4
    accu = sess.accumulator("out")
    assert accu.bytes_transferred == (4 + 1) * 16   # (N+1)·V, paper §5.2
    assert sess.wire_traffic() == (4 + 1) * 16
    with pytest.warns(DeprecationWarning, match="Session.stats"):
        raw = sess.stats()
    assert raw["cache"].hits + raw["cache"].misses >= 4


def test_data_partitioning_and_broadcast():
    sess = Session(backend="host", n_nodes=2, threads_per_node=2)
    rows = jnp.arange(8.0)
    shared = jnp.full((3,), 7.0)

    def proc(ctx, shard, rep):
        assert rep.shape == (3,)           # broadcast arrives whole
        return (float(shard[0]), int(shard.shape[0]))

    res = sess.run(proc, data=(rows,), broadcast=(shared,))
    assert [r[1] for r in res] == [2, 2, 2, 2]
    assert [r[0] for r in res] == [0.0, 2.0, 4.0, 6.0]


def test_sync_factories():
    sess = Session(backend="host", n_nodes=1, threads_per_node=3)
    b = sess.barrier()
    assert b.count == 3
    c = sess.ssp_clock(staleness=1)
    assert c.staleness == 1
    s = sess.semaphore(2)
    assert s.acquire() and s.acquire()
    assert s.acquire(timeout=0.01) is False


# -- backend parity (the acceptance criterion) --------------------------------


def test_backend_parity_single_device():
    """Same workload code, host vs SPMD session, matching results."""
    x, y, _ = logreg_dataset(400, 24, seed=0)
    th_host, _ = logreg.fit(x, y, backend="host", n_nodes=2,
                            threads_per_node=2, iters=8)
    th_spmd, spmd_sess = logreg.fit(x, y, backend="spmd", iters=8)
    assert spmd_sess.backend.kind == "spmd"
    np.testing.assert_allclose(th_spmd, th_host, rtol=1e-4, atol=1e-5)

    xk, _, _ = kmeans_dataset(600, 8, 5, seed=1)
    c_host, _ = kmeans.fit(xk, 5, backend="host", n_nodes=2,
                           threads_per_node=2, iters=6, seed=1)
    c_spmd, _ = kmeans.fit(xk, 5, backend="spmd", iters=6, seed=1)
    np.testing.assert_allclose(c_spmd, c_host, rtol=1e-3, atol=1e-3)


def test_backend_parity_multidevice():
    """4-device SPMD session == 4-thread host session, same workload code."""
    out = run_subprocess_devices("""
import numpy as np
from repro.analytics import kmeans, logreg, nmf, pagerank
from repro.data import kmeans_dataset, logreg_dataset, nmf_dataset, powerlaw_graph

x, y, _ = logreg_dataset(400, 24, seed=0)
th_host, _ = logreg.fit(x, y, backend="host", n_nodes=2, threads_per_node=2, iters=8)
th_spmd, sess = logreg.fit(x, y, backend="spmd", iters=8)
assert sess.backend.n_threads == 4
np.testing.assert_allclose(th_spmd, th_host, rtol=1e-4, atol=1e-5)

xk, _, _ = kmeans_dataset(800, 8, 5, seed=1)
c_host, _ = kmeans.fit(xk, 5, backend="host", n_nodes=2, threads_per_node=2, iters=6, seed=1)
c_spmd, _ = kmeans.fit(xk, 5, backend="spmd", iters=6, seed=1)
np.testing.assert_allclose(c_spmd, c_host, rtol=1e-3, atol=1e-3)

r, _, _ = nmf_dataset(120, 32, 4, seed=2)
p_h, q_h, _ = nmf.fit(r, 4, backend="host", n_nodes=2, threads_per_node=2, iters=8, seed=2)
p_s, q_s, _ = nmf.fit(r, 4, backend="spmd", iters=8, seed=2)
np.testing.assert_allclose(nmf.frob_loss(r, p_s, q_s), nmf.frob_loss(r, p_h, q_h), rtol=1e-2)

edges = powerlaw_graph(300, 5, seed=3)
r_h, _ = pagerank.fit(edges, 300, backend="host", n_nodes=2, threads_per_node=2,
                      iters=8, mode="reduce_scatter")
r_s, _ = pagerank.fit(edges, 300, backend="spmd", iters=8, mode="reduce_scatter")
np.testing.assert_allclose(r_s, r_h, rtol=1e-4, atol=1e-6)
print("PARITY_OK")
""", n_devices=4)
    assert "PARITY_OK" in out


# -- GlobalStore satellite fixes ----------------------------------------------


def test_store_inc_keeps_sharding_and_counts_stats():
    mesh = make_mesh((1,), ("data",))
    store = GlobalStore(mesh=mesh)
    store.def_global("v", jnp.ones((4,)), spec=P("data"))
    before = store.get("v").sharding
    assert isinstance(before, NamedSharding)
    store.inc("v", 1.0)
    after = store._entries["v"].value
    np.testing.assert_allclose(np.asarray(after), 2.0)
    assert isinstance(after.sharding, NamedSharding)
    assert after.sharding.spec == before.spec
    assert store.stats["inc"] == 1
    assert store.stats["bytes_set"] >= 16
    assert store.stats["transfers"] >= 1


def test_store_set_object_keeps_field_specs():
    mesh = make_mesh((1,), ("data",))
    store = GlobalStore(mesh=mesh)
    store.new_object("o", {"w": jnp.ones((4,)), "b": jnp.zeros((2,))},
                     specs={"w": P("data")})
    store.set("o", {"w": jnp.full((4,), 2.0), "b": jnp.ones((2,))})
    w = store._entries["o"].value["w"]
    assert isinstance(w.sharding, NamedSharding)
    assert w.sharding.spec == P("data")
    np.testing.assert_allclose(np.asarray(w), 2.0)


def test_accumulator_inspection_resolves_per_call_budget():
    """Post-run sess.accumulator(name, mode) with no k must resolve the
    accumulator the run actually used (per-call k), not construct a fresh
    zero-traffic one (unconstructible for SPARSE without a budget)."""
    sess = Session(backend="host", n_nodes=2, threads_per_node=2)
    out = sess.new_array("g", (64,))

    def proc(ctx):
        out.accumulate(jnp.ones(64), mode="sparse", k=8)

    sess.run(proc)
    accu = sess.accumulator("g", "sparse")     # no k: resolve, don't build
    assert accu.k == 8 and accu.bytes_transferred > 0
    assert sess.accumulator("g") is accu       # sole accumulator for the ref


def test_delete_redeclare_facade_no_stale_read():
    """SharedRef.delete → new_array under the same name: a worker whose node
    cached the deleted-era value must NOT be served it (pre-fix the re-declared
    entry restarted at epoch 0 and the stale replica validated as fresh)."""
    sess = Session(backend="host", n_nodes=1, threads_per_node=1)
    v = sess.def_global("v", jnp.full((4,), 1.0))
    warmed = sess.run(lambda ctx: float(np.asarray(v.get())[0]))
    assert warmed == [1.0]                      # node 0 now holds a replica
    v.delete()
    with pytest.raises(KeyError):
        sess.ref("v")
    v2 = sess.def_global("v", jnp.full((4,), 7.0))
    got = sess.run(lambda ctx: float(np.asarray(v2.get())[0]))
    assert got == [7.0]
    # and the sparse budget does not leak across the delete
    a = sess.new_array("g", (8,), sparse_k=4)
    assert sess.sparse_k("g") == 4
    a.delete()
    sess.new_array("g", (8,))
    assert sess.sparse_k("g") is None


def test_ssp_inc_is_atomic_under_contention():
    sess = Session(backend="host", n_nodes=4, threads_per_node=1)
    counter = sess.def_global("counter", 0.0)

    def proc(ctx):
        for _ in range(50):
            counter.inc(1.0)

    sess.run(proc)
    assert float(counter.get()) == 200.0
