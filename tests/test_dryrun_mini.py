"""Miniature dry-run: lower+compile on an 8-device mesh, introspection intact."""

from conftest import run_subprocess_devices


def test_build_cell_lower_compile_train_and_decode():
    out = run_subprocess_devices("""
import jax
from repro.configs import get_arch, smoke_config
from repro.configs.base import ShapeSpec
from repro.launch.mesh import _mk
from repro.launch.steps import build_cell
from repro.launch.roofline import extract_metrics

mesh = _mk((4, 2), ("data", "model"))
for arch, kind, B, T in [("qwen3-1.7b", "train", 8, 64),
                         ("mamba2-2.7b", "decode", 8, 64),
                         ("moonshot-v1-16b-a3b", "train", 8, 64)]:
    cfg = smoke_config(get_arch(arch)).replace(dtype="bfloat16")
    shape = ShapeSpec("mini", T, B, kind)
    cell = build_cell(cfg, shape, mesh, fsdp=False)
    with mesh:
        compiled = cell.jitted.lower(*cell.args).compile()
    m = extract_metrics(compiled)
    assert m["flops"] > 0, arch
    assert m["bytes"] > 0, arch
    assert compiled.memory_analysis() is not None
    print("CELL_OK", arch, kind, int(m["coll_bytes"]))
print("MINI_DRYRUN_OK")
""")
    assert "MINI_DRYRUN_OK" in out
    assert out.count("CELL_OK") == 3


def test_multi_pod_mini_mesh():
    out = run_subprocess_devices("""
import jax
from repro.configs import get_arch, smoke_config
from repro.configs.base import ShapeSpec
from repro.launch.mesh import _mk
from repro.launch.steps import build_cell
mesh = _mk((2, 2, 2), ("pod", "data", "model"))
cfg = smoke_config(get_arch("qwen3-4b")).replace(dtype="bfloat16")
cell = build_cell(cfg, ShapeSpec("mini", 64, 8, "train"), mesh, fsdp=True)
with mesh:
    compiled = cell.jitted.lower(*cell.args).compile()
txt = compiled.as_text()
assert "all-reduce" in txt or "reduce-scatter" in txt
print("MULTIPOD_MINI_OK")
""")
    assert "MULTIPOD_MINI_OK" in out
