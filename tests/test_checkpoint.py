"""Checkpoint/restore, pruning, async, elastic reshard, recovery planning."""

import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ft import (
    AsyncCheckpointer, Checkpoint, latest_step, list_checkpoints,
    plan_recovery, rebalance_batch, restore_checkpoint, save_checkpoint,
)


def tree():
    return {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.int32)}}


def test_roundtrip_and_latest():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, tree(), extra={"k": 1})
        save_checkpoint(d, 9, tree())
        got, extra, step = restore_checkpoint(d, tree())
        assert step == 9
        np.testing.assert_allclose(got["a"], tree()["a"])
        got3, extra3, _ = restore_checkpoint(d, tree(), step=3)
        assert extra3 == {"k": 1}


def test_prune_keep():
    with tempfile.TemporaryDirectory() as d:
        for s in range(6):
            save_checkpoint(d, s, tree(), keep=3)
        assert list_checkpoints(d) == [3, 4, 5]


def test_shape_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 0, tree())
        bad = {"a": jnp.zeros((3, 3)), "b": {"c": jnp.ones(4, jnp.int32)}}
        with pytest.raises(ValueError):
            restore_checkpoint(d, bad)


def test_async_checkpointer():
    with tempfile.TemporaryDirectory() as d:
        ac = AsyncCheckpointer(d, keep=2)
        ac.save(1, tree())
        ac.save(2, tree())
        ac.wait()
        assert latest_step(d) == 2


def test_checkpoint_user_hook():
    class MyCk(Checkpoint):
        def __init__(self):
            self.state = 42
        def do_checkpoint(self):
            return {"state": self.state}
        def do_restart(self, st):
            self.state = st["state"]

    ck = MyCk()
    blob = ck.do_checkpoint()
    ck2 = MyCk(); ck2.state = 0
    ck2.do_restart(blob)
    assert ck2.state == 42


def test_plan_recovery_modes():
    tids = {0: [0, 1], 1: [2, 3], 2: [4, 5]}
    single = plan_recovery([1], [0, 1, 2], tids, mode="single")
    assert set(single.reassignment) == {2, 3}
    assert len(set(single.reassignment.values())) == 1
    multi = plan_recovery([1], [0, 1, 2], tids, mode="multi")
    assert set(multi.reassignment.values()) == {0, 2}
    with pytest.raises(RuntimeError):
        plan_recovery([0, 1, 2], [0, 1, 2], tids)


def test_rebalance_batch():
    assert rebalance_batch(256, 16, 8) == 256
    assert rebalance_batch(256, 16, 15) == 255
    assert rebalance_batch(7, 7, 9) == 9
