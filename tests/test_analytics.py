"""The paper's four applications: threads == reference, traffic accounting."""

import numpy as np
import pytest

from repro.analytics import kmeans, logreg, nmf, pagerank
from repro.core import AccumMode
from repro.data import kmeans_dataset, logreg_dataset, nmf_dataset, powerlaw_graph


def test_logreg_threads_match_reference():
    x, y, _ = logreg_dataset(400, 24, seed=0)
    ref = logreg.fit_reference(x, y, iters=10, lr=1e-3)
    th, store, accu = logreg.fit_threads(x, y, n_nodes=2, threads_per_node=2,
                                         iters=10, lr=1e-3)
    np.testing.assert_allclose(th, ref, rtol=1e-4, atol=1e-5)
    assert accu.bytes_transferred == (4 + 1) * 24 * 10   # (N+1)·V per round
    assert logreg.loss(th, x, y) < logreg.loss(np.zeros(24, np.float32), x, y)


def test_logreg_gather_all_traffic_is_higher():
    x, y, _ = logreg_dataset(200, 16, seed=1)
    _, _, naive = logreg.fit_threads(x, y, n_nodes=2, threads_per_node=2,
                                     iters=5, mode=AccumMode.GATHER_ALL)
    _, _, rs = logreg.fit_threads(x, y, n_nodes=2, threads_per_node=2,
                                  iters=5, mode=AccumMode.REDUCE_SCATTER)
    assert naive.bytes_transferred == (2 * 4 + 1) * 16 * 5
    assert rs.bytes_transferred == (4 + 1) * 16 * 5


def test_kmeans_threads_match_reference():
    x, _, _ = kmeans_dataset(600, 8, 5, seed=1)
    cr = kmeans.fit_reference(x, 5, iters=8, seed=1)
    ct, _, _ = kmeans.fit_threads(x, 5, n_nodes=2, threads_per_node=2, iters=8, seed=1)
    np.testing.assert_allclose(np.sort(ct, axis=0), np.sort(cr, axis=0),
                               rtol=1e-3, atol=1e-3)


def test_kmeans_kernel_path():
    x, _, _ = kmeans_dataset(300, 8, 4, seed=2)
    cr = kmeans.fit_reference(x, 4, iters=5, seed=2)
    ck, _, _ = kmeans.fit_threads(x, 4, n_nodes=1, threads_per_node=2, iters=5,
                                  seed=2, use_kernel=True)
    np.testing.assert_allclose(np.sort(ck, axis=0), np.sort(cr, axis=0),
                               rtol=1e-3, atol=1e-3)


def test_nmf_threads_match_reference():
    r, _, _ = nmf_dataset(120, 32, 4, seed=2)
    pr, qr = nmf.fit_reference(r, 4, iters=10, seed=2)
    pt, qt, _, _ = nmf.fit_threads(r, 4, n_nodes=2, threads_per_node=2,
                                   iters=10, seed=2)
    np.testing.assert_allclose(nmf.frob_loss(r, pt, qt), nmf.frob_loss(r, pr, qr),
                               rtol=1e-2)


def test_pagerank_threads_match_reference():
    edges = powerlaw_graph(300, 5, seed=3)
    rr = pagerank.fit_reference(edges, 300, iters=10)
    rt, _, accu = pagerank.fit_threads(edges, 300, n_nodes=2, threads_per_node=2,
                                       iters=10, mode=AccumMode.AUTO)
    np.testing.assert_allclose(rt, rr, rtol=1e-4, atol=1e-6)
    assert abs(float(np.sum(rr)) - 1.0) < 0.05  # ranks ≈ distribution


def test_deprecated_shims_warn_and_stay_correct():
    """fit_threads / fit_spmd are shims: they must warn DeprecationWarning AND
    still return the same results as the fit() they forward to."""
    from repro.core.compat import make_mesh
    mesh1 = make_mesh((1,), ("data",))

    x, y, _ = logreg_dataset(200, 16, seed=5)
    ref_lr = logreg.fit_reference(x, y, iters=6, lr=1e-3)
    with pytest.warns(DeprecationWarning, match="logreg.fit_threads"):
        th, store, accu = logreg.fit_threads(x, y, n_nodes=2, threads_per_node=2,
                                             iters=6, lr=1e-3)
    np.testing.assert_allclose(th, ref_lr, rtol=1e-4, atol=1e-5)
    assert accu.rounds == 6
    with pytest.warns(DeprecationWarning, match="logreg.fit_spmd"):
        th_s = logreg.fit_spmd(x, y, mesh1, iters=6, lr=1e-3)
    np.testing.assert_allclose(th_s, ref_lr, rtol=1e-4, atol=1e-5)

    xk, _, _ = kmeans_dataset(300, 8, 4, seed=6)
    ref_km = kmeans.fit_reference(xk, 4, iters=5, seed=6)
    with pytest.warns(DeprecationWarning, match="kmeans.fit_threads"):
        ck, _, _ = kmeans.fit_threads(xk, 4, n_nodes=2, threads_per_node=2,
                                      iters=5, seed=6)
    np.testing.assert_allclose(np.sort(ck, axis=0), np.sort(ref_km, axis=0),
                               rtol=1e-3, atol=1e-3)
    with pytest.warns(DeprecationWarning, match="kmeans.fit_spmd"):
        cs = kmeans.fit_spmd(xk, 4, mesh1, iters=5, seed=6)
    np.testing.assert_allclose(np.sort(cs, axis=0), np.sort(ref_km, axis=0),
                               rtol=1e-3, atol=1e-3)


def test_logreg_ssp_async_converges():
    """Bounded-staleness async training reaches the same loss ballpark as sync."""
    x, y, _ = logreg_dataset(400, 16, seed=4)
    ref = logreg.fit_reference(x, y, iters=12, lr=1e-3)
    ssp, clock = logreg.fit_ssp(x, y, n_workers=4, staleness=1, iters=12, lr=1e-3)
    l_ref, l_ssp = logreg.loss(ref, x, y), logreg.loss(ssp, x, y)
    assert l_ssp < l_ref * 1.5 + 0.05  # async: same ballpark, not bitwise
    # staleness=0 degenerates to sync (every worker waits each tick)
    sync0, clock0 = logreg.fit_ssp(x, y, n_workers=2, staleness=0, iters=5, lr=1e-3)
    assert np.all(np.isfinite(sync0))
