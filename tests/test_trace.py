"""step.trace — tracer correctness, export round-trip, stats unification.

The tentpole contract: tracing is a strict no-op by default (no events, no
allocation, nothing armed globally); armed, it records spans/counters/
histograms from every hot path (store ops, barrier waits, accumulator
rounds, sync primitives, SPMD settling) with per-thread attribution; the
Chrome-trace export loads back as plain JSON with all three core span
categories present for a 2-thread logreg host run; and the three legacy
stats shapes stay intact beneath the canonical ``Session.metrics()`` keys.
"""

import json
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analytics import logreg
from repro.core import Session, telemetry
from repro.core.shards import ShardedStore
from repro.core.telemetry import (
    CACHE_METRIC_KEYS,
    SESSION_METRIC_KEYS,
    STORE_METRIC_KEYS,
    Tracer,
)
from repro.ft import metrics_payload, session_recovery


def _logreg_data(n=64, d=16, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = (rng.random(n) > 0.5).astype(np.float32)
    return x, y


# -- no-op by default ---------------------------------------------------------


def test_noop_by_default():
    """A plain Session records nothing, arms nothing, and ctx.span is the
    shared null context manager — the zero-cost guarantee."""
    assert telemetry.armed_count() == 0
    x, y = _logreg_data()
    theta, sess = logreg.fit(x, y, iters=2, n_nodes=1, threads_per_node=2)
    assert not sess.tracer.enabled
    assert telemetry.TRACING is False
    assert telemetry.armed_count() == 0
    snap = sess.tracer.snapshot()
    assert snap["events"] == 0
    assert snap["counters"] == {}
    assert snap["spans_by_category"] == {}
    # metrics() still works against a disabled tracer
    m = sess.metrics()
    assert m["trace"]["enabled"] is False


def test_arm_disarm_scoping():
    t1, t2 = Tracer(enabled=True), Tracer(enabled=True)
    try:
        assert telemetry.TRACING and telemetry.armed_count() == 2
        t1.disable()
        assert telemetry.TRACING and telemetry.armed_count() == 1
        t2.disable()
        assert not telemetry.TRACING and telemetry.armed_count() == 0
    finally:
        telemetry.reset()


# -- the acceptance criterion: export round-trip from a 2-thread logreg run ---


def test_chrome_export_roundtrip_logreg(tmp_path):
    x, y = _logreg_data()
    sess = Session(backend="host", n_nodes=2, threads_per_node=1, trace=True)
    try:
        theta, _ = logreg.fit(x, y, iters=3, session=sess)
        path = sess.tracer.export(str(tmp_path / "trace.json"))
        with open(path) as f:
            trace = json.load(f)          # must round-trip as plain JSON
        events = trace["traceEvents"]
        cats = {e.get("cat") for e in events if e.get("ph") == "X"}
        for required in ("store-op", "barrier-wait", "accumulate-round"):
            assert required in cats, f"missing {required} spans in export"
        # app-round markers from ctx.span land too (host backend)
        assert "app-round" in cats
        # thread metadata: both STEP threads named on their node timelines
        names = {(e["pid"], e["tid"]) for e in events
                 if e.get("ph") == "M" and e["name"] == "thread_name"}
        assert {(0, 0), (1, 1)} <= names
        # every X event carries the Chrome-trace complete-event fields
        for e in events:
            if e.get("ph") == "X":
                assert {"name", "cat", "ts", "dur", "pid", "tid"} <= set(e)
    finally:
        sess.tracer.disable()


# -- span correctness under concurrency ---------------------------------------


def test_accumulate_span_counts_and_thread_attribution():
    """N threads x R rounds => exactly N*R per-thread 'accumulate' spans, R
    reduce spans, and per-thread spans that never overlap on a timeline."""
    N_NODES, TPN, R = 2, 2, 3
    N = N_NODES * TPN
    sess = Session(backend="host", n_nodes=N_NODES, threads_per_node=TPN,
                   trace=True)
    try:
        ref = sess.new_array("v", (32,))

        def proc(ctx, xs):
            def step(c):
                return c + ref.accumulate(xs.sum(axis=0)).sum()
            return ctx.iterate(step, jnp.float32(0), R)

        sess.run(proc, data=(jnp.ones((N * 2, 32)),))
        per_thread = sess.tracer.spans("accumulate-round", "accumulate")
        assert len(per_thread) == N * R
        reduces = sess.tracer.spans("accumulate-round", "accumulate.round")
        assert len(reduces) == R
        assert all(r["args"]["threads"] == N for r in reduces)
        # attribution: spans landed on N distinct (node, tid) timelines, R each
        by_tid = {}
        for e in per_thread:
            by_tid.setdefault((e["pid"], e["tid"]), []).append(e)
        assert len(by_tid) == N
        for timeline in by_tid.values():
            assert len(timeline) == R
            timeline.sort(key=lambda e: e["ts"])
            for a, b in zip(timeline, timeline[1:]):
                # a thread's rounds are sequential: no span starts before the
                # previous one on the same timeline ended
                assert b["ts"] >= a["ts"] + a["dur"] - 1e-3
        # each accumulate span brackets its barrier wait on the same thread
        waits = sess.tracer.spans("barrier-wait", "accumulate.barrier")
        assert len(waits) == N * R
        counters = sess.tracer.counters()
        assert counters["accumulate.rounds"] == R
        assert counters["accumulate.wire_elements"] == sess.wire_traffic()
    finally:
        sess.tracer.disable()


def test_barrier_semaphore_ssp_instrumentation():
    sess = Session(backend="host", n_nodes=2, threads_per_node=2, trace=True)
    try:
        bar = sess.barrier()
        sem = sess.semaphore(1)
        clock = sess.ssp_clock(staleness=0, n_workers=4)

        def proc(ctx, xs):
            sem.acquire()
            sem.release()
            ctx.barrier()          # backend run barrier (tracer attached)
            bar.enter()            # session-factory barrier
            clock.tick(ctx.tid)
            clock.wait(ctx.tid)
            return None

        sess.run(proc, data=(jnp.ones((4, 4)),))
        snap = sess.tracer.snapshot()
        # two traced barriers x 4 threads
        assert snap["ops"]["barrier.wait"]["count"] == 8
        assert len(sess.tracer.spans("barrier-wait", "barrier.wait")) == 8
        assert snap["ops"]["semaphore.queue_depth"]["count"] == 4
        assert snap["ops"]["semaphore.queue_depth"]["max"] >= 1
        assert len(sess.tracer.spans("sync", "semaphore.acquire")) == 4
        skew = snap["ops"]["ssp.skew"]
        assert skew["count"] == 4 and skew["max"] <= 1  # staleness=0 bound+1
    finally:
        sess.tracer.disable()


def test_store_op_shard_attribution_and_lock_wait():
    store = ShardedStore(shards=4)
    trc = Tracer(enabled=True)
    store.tracer = trc
    try:
        for i in range(32):
            store.def_global(f"n{i}", float(i))
            store.get(f"n{i}")
            store.inc(f"n{i}", 1.0)
        store.mget([f"n{i}" for i in range(32)])
        snap = trc.snapshot()
        assert snap["ops"]["store.get"]["count"] == 32
        assert snap["ops"]["store.inc"]["count"] == 32
        assert snap["ops"]["store.mget"]["count"] == 1
        # per-shard histograms: the 32 names spread over all 4 shard rows
        per_shard = snap["ops_by_shard"]["store.get"]
        assert set(per_shard) == set(store.shard_ids())
        assert sum(row["count"] for row in per_shard.values()) == 32
        # lock waits were measured (traced-acquire path) in microseconds
        assert snap["ops"]["store.lock_wait"]["count"] > 0
        # normalized views agree with the raw counters
        assert store.metrics()["gets"] >= 32
        assert set(store.metrics()) == set(STORE_METRIC_KEYS)
    finally:
        trc.disable()


# -- host <-> SPMD parity through metrics() -----------------------------------


def test_metrics_collective_bytes_parity_host_spmd():
    """The same 1-thread workload reports identical wire_traffic through
    metrics() on both backends, and each backend's tracer counter agrees
    with its own figure (host: accumulate.wire_elements; SPMD:
    spmd.collective_elements settled at join)."""
    V, R = 128, 3
    rows = jnp.ones((2, V))

    def run(backend):
        sess = Session(backend=backend, n_nodes=1, threads_per_node=1,
                       trace=True)
        try:
            out = sess.new_array("o", (V,))

            def proc(ctx, xs):
                def step(c):
                    return c + out.accumulate(xs.sum(axis=0)).sum()
                return ctx.iterate(step, jnp.float32(0), R)

            res = sess.run(proc, data=(rows,))
            m = sess.metrics()
            return np.asarray(res[0]), m, sess.tracer.counters()
        finally:
            sess.tracer.disable()

    r_h, m_h, c_h = run("host")
    r_s, m_s, c_s = run("spmd")
    np.testing.assert_allclose(r_h, r_s, rtol=1e-6)
    assert m_h["wire_traffic"] == m_s["wire_traffic"] == 2 * V * R
    assert c_h["accumulate.wire_elements"] == m_h["wire_traffic"]
    assert c_s["spmd.collective_elements"] == m_s["wire_traffic"]
    assert c_s["spmd.scan_trips"] == R and c_s["spmd.scan_sites"] == 1


# -- stats unification: pinned key sets, deprecated views intact --------------


def test_metric_key_sets_pinned():
    x, y = _logreg_data()
    theta, sess = logreg.fit(x, y, iters=2, n_nodes=2, threads_per_node=1,
                             backend="host")
    m = sess.metrics()
    assert set(m) == set(SESSION_METRIC_KEYS)
    assert set(m["store"]) == set(STORE_METRIC_KEYS)
    assert set(m["cache"]) == set(CACHE_METRIC_KEYS)
    assert m["backend"] == "host"
    for sid, row in m["shards"].items():
        assert set(row) == {"store", "cache", "wire_traffic"}
        # per-shard store rows add the entry count to the canonical set
        assert set(row["store"]) == set(STORE_METRIC_KEYS) | {"names"}
        assert set(row["cache"]) == set(CACHE_METRIC_KEYS)
    # canonical counters mirror the raw legacy ones
    with pytest.warns(DeprecationWarning, match="Session.stats"):
        raw = sess.stats()
    assert m["store"]["gets"] == raw["store"]["get"]
    assert m["store"]["bytes_written"] == raw["store"]["bytes_set"]
    assert m["cache"]["hits"] == raw["cache"].hits
    assert m["wire_traffic"] == raw["wire_traffic"]


def test_deprecated_stats_shapes_unchanged():
    """The three legacy shapes are frozen: old callers keep working (they
    just see a DeprecationWarning now — step.check PR)."""
    x, y = _logreg_data()
    theta, sess = logreg.fit(x, y, iters=2, n_nodes=2, threads_per_node=1)
    with pytest.warns(DeprecationWarning, match="Session.stats"):
        raw = sess.stats()
    assert set(raw) == {"store", "cache", "wire_traffic"}
    assert set(raw["store"]) == {"get", "set", "inc", "bytes_get", "bytes_set",
                                 "transfers", "migrated_in", "migrated_out"}
    cs = raw["cache"]          # CacheStats object, not a dict
    for attr in ("hits", "misses", "invalidations", "write_messages",
                 "missing_messages", "evictions", "hit_rate"):
        assert hasattr(cs, attr)
    assert cs.as_dict()["hits"] == cs.hits
    with pytest.warns(DeprecationWarning, match="Session.shard_stats"):
        shard_rows = sess.shard_stats()
    for sid, row in shard_rows.items():
        assert set(row) == {"store", "cache", "wire_traffic"}
        assert "get" in row["store"] and "names" in row["store"]


# -- FT integration -----------------------------------------------------------


def test_recovery_rearms_tracer():
    """session_recovery's replacement session adopts the dead session's
    tracer (still armed) and keeps recording into the same timeline."""
    sess = Session(backend="host", n_nodes=2, threads_per_node=1, shards=2,
                   trace=True)
    try:
        ref = sess.new_array("w", (16,))
        sess.run(lambda ctx, xs: ref.accumulate(xs.sum(axis=0)),
                 data=(jnp.ones((2, 16)),))
        before = sess.tracer.snapshot()["events"]
        assert before > 0
        plan, new_sess = session_recovery(sess, [1])
        assert new_sess.tracer is sess.tracer
        assert new_sess.tracer.enabled
        assert new_sess.store.tracer is sess.tracer
        ref2 = new_sess.ref("w")
        new_sess.run(lambda ctx, xs: ref2.accumulate(xs.sum(axis=0)),
                     data=(jnp.ones((1, 16)),))
        assert new_sess.tracer.snapshot()["events"] > before
    finally:
        sess.tracer.disable()


def test_heartbeat_metrics_payload():
    sess = Session(backend="host", n_nodes=1, threads_per_node=2, trace=True)
    try:
        ref = sess.new_array("v", (8,))

        def proc(ctx, xs):
            ref.accumulate(xs.sum(axis=0))
            ctx.barrier()
            return None

        sess.run(proc, data=(jnp.ones((2, 8)),))
        payload = metrics_payload(sess)
        assert payload["trace_enabled"] is True
        assert payload["barrier_wait_us"]["count"] >= 2
        assert payload["barrier_wait_us"]["p99"] >= payload["barrier_wait_us"]["p50"]
        assert payload["op_rates"]["store.set"] > 0
        assert payload["wire_traffic"] == sess.wire_traffic()
    finally:
        sess.tracer.disable()


# -- recorder robustness ------------------------------------------------------


def test_event_cap_drops_counted():
    trc = Tracer(enabled=True, max_events=10)
    try:
        for i in range(25):
            t0 = trc.now()
            trc.add_span("store-op", "store.get", t0, t0)
        snap = trc.snapshot()
        assert snap["events"] == 10
        assert snap["dropped_events"] == 15
        # span *counts* keep the true total even past the event cap
        assert snap["spans_by_category"]["store-op"] == 25
    finally:
        trc.disable()


def test_tracer_thread_safety_counters():
    trc = Tracer(enabled=True)
    try:
        def work():
            for _ in range(500):
                trc.count("x")
                trc.observe("y", 1.0, shard=0)
        ts = [threading.Thread(target=work) for _ in range(8)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        snap = trc.snapshot()
        assert snap["counters"]["x"] == 4000
        assert snap["ops"]["y"]["count"] == 4000
        assert snap["ops_by_shard"]["y"][0]["count"] == 4000
    finally:
        trc.disable()
