"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the JSONs.

    PYTHONPATH=src python scripts/make_report.py [--out experiments/dryrun]
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ARCH_ORDER = ["deepseek-v3-671b", "moonshot-v1-16b-a3b", "starcoder2-3b",
              "qwen3-4b", "qwen2-72b", "qwen3-1.7b", "llama-3.2-vision-90b",
              "zamba2-2.7b", "hubert-xlarge", "mamba2-2.7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir):
    from repro.configs import SHAPES, get_arch
    from repro.launch.roofline import model_flops

    recs, skips = {}, {}
    for fn in os.listdir(out_dir):
        if not fn.endswith(".json"):
            continue
        r = json.load(open(os.path.join(out_dir, fn)))
        key = (r["arch"], r["shape"], r["mesh"], r.get("variant", "baseline"))
        if r.get("skipped"):
            skips[key] = r
        else:
            # recompute MODEL_FLOPS/useful with the *current* formula so all
            # rows are mutually consistent regardless of when they were run
            mf = model_flops(get_arch(r["arch"]), SHAPES[r["shape"]])
            r["model_flops_total"] = mf
            if r["hlo_flops"]:
                r["useful_ratio"] = (mf / r["n_devices"]) / r["hlo_flops"]
            recs[key] = r
    return recs, skips


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def improvement_note(r):
    """One sentence on what moves the dominant term down."""
    b = r["bottleneck"]
    shape = r["shape"]
    if b == "memory":
        if "decode" in shape or "long" in shape:
            return "decode is cache-read bound: shrink per-token cache reads (MLA/SSM already minimal; quantize KV to int8)"
        return "cut HBM traffic: fuse attention internals into the Pallas flash kernel (keeps scores in VMEM) + bf16 intermediates"
    if b == "collective":
        return "cut TP collectives: bf16 all-reduce, sequence-sharded activations (AG/RS decomposition), hierarchical cross-pod reduce"
    return "compute-bound: raise MXU utilisation (bigger per-device tiles, skip causal-masked blocks, fewer remat recomputes)"


def trace_section(bench_path):
    """§Observability: the step.trace overhead table from BENCH_trace.json."""
    r = json.load(open(bench_path))
    print("\n### step.trace overhead (benchmarks/BENCH_trace.json)\n")
    print("| workload | tracer | seconds | ops/s | events |")
    print("|---|---|---|---|---|")
    for wl, key in (("rw mix (S=8, 8 threads)", "rw"), ("logreg fit", "logreg")):
        for state in ("noop", "disabled", "enabled"):
            row = r.get(f"{key}_{state}")
            if row is None:
                continue
            ops = f"{row['ops_per_sec']:.0f}" if "ops_per_sec" in row else "—"
            print(f"| {wl} | {state} | {row['seconds']:.4f} | {ops} | "
                  f"{row['events']} |")
    pct = r.get("disabled_overhead_pct_rw")
    if pct is not None:
        ok = "within" if r.get("disabled_within_limit") else "OVER"
        print(f"\nDisabled-tracer overhead on the rw mix: **{pct:.2f}%** "
              f"({ok} the {r.get('acceptance_limit_pct', 5.0):.0f}% budget); "
              f"enabled recording costs "
              f"{r.get('enabled_overhead_pct_rw', 0.0):.1f}%.")


def check_section(bench_path):
    """§Correctness: the step.check overhead table from BENCH_check.json."""
    r = json.load(open(bench_path))
    print("\n### step.check overhead (benchmarks/BENCH_check.json)\n")
    print("| workload | checker | seconds | ops/s | findings |")
    print("|---|---|---|---|---|")
    for wl, key in (("rw mix (S=8, 8 threads)", "rw"), ("logreg fit", "logreg")):
        for state in ("noop", "disabled", "armed"):
            row = r.get(f"{key}_{state}")
            if row is None:
                continue
            ops = f"{row['ops_per_sec']:.0f}" if "ops_per_sec" in row else "—"
            print(f"| {wl} | {state} | {row['seconds']:.4f} | {ops} | "
                  f"{row['findings']} |")
    pct = r.get("disabled_overhead_pct_rw")
    if pct is not None:
        ok = "within" if r.get("disabled_within_limit") else "OVER"
        print(f"\nDisabled-checker overhead on the rw mix: **{pct:.2f}%** "
              f"({ok} the {r.get('acceptance_limit_pct', 5.0):.0f}% budget); "
              f"armed analysis costs "
              f"{r.get('armed_overhead_pct_rw', 0.0):.1f}%.")


def export_check_report(path):
    """Run the four analytics apps under an armed checker plus the seeded
    race from examples/race_demo.py, and export one findings JSON — the
    artifact showing zero findings on real apps and a caught seeded race."""
    import numpy as np

    from repro.analytics import kmeans, logreg, nmf, pagerank
    from repro.check import Checker
    from repro.core import Session

    rng = np.random.default_rng(0)
    x = rng.normal(size=(128, 16)).astype(np.float32)
    y = (rng.random(128) > 0.5).astype(np.float32)
    pts = rng.normal(size=(96, 4)).astype(np.float32)
    r = np.abs(rng.normal(size=(32, 16))).astype(np.float32)
    edges = np.stack([rng.integers(0, 24, 80), rng.integers(0, 24, 80)],
                     axis=1).astype(np.int32)

    report = {"apps": {}, "seeded_race": None}
    for name, call in (
            ("logreg", lambda s: logreg.fit(x, y, iters=3, session=s)),
            ("kmeans", lambda s: kmeans.fit(pts, 3, iters=3, session=s)),
            ("nmf", lambda s: nmf.fit(r, 4, iters=3, session=s)),
            ("pagerank", lambda s: pagerank.fit(edges, 24, iters=3, session=s))):
        sess = Session(backend="host", n_nodes=2, threads_per_node=2,
                       shards=8, check=True)
        try:
            call(sess)
            report["apps"][name] = sess.checker.report()
        finally:
            sess.checker.disable()

    ck = Checker(enabled=True)
    try:
        sess = Session(backend="host", n_nodes=1, threads_per_node=2,
                       check=ck)
        import jax.numpy as jnp
        counter = sess.def_global("counter", jnp.float32(0))

        def proc(ctx):
            for _ in range(4):
                v = counter.get()
                counter.set(v + jnp.float32(ctx.tid + 1))
            return None

        sess.run(proc)
        report["seeded_race"] = ck.report()
    finally:
        ck.disable()

    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    clean = all(rep["count"] == 0 for rep in report["apps"].values())
    caught = report["seeded_race"]["count"] > 0
    print(f"wrote {path}: apps clean={clean}, "
          f"seeded race caught={caught} "
          f"({report['seeded_race']['count']} finding(s))")


def export_sample_trace(path):
    """Run a small 2-thread logreg fit with tracing armed and export the
    Chrome-trace JSON — the artifact to drag into https://ui.perfetto.dev."""
    import numpy as np

    from repro.analytics import logreg
    from repro.core import Session

    rng = np.random.default_rng(0)
    x = rng.normal(size=(128, 32)).astype(np.float32)
    y = (rng.random(128) > 0.5).astype(np.float32)
    sess = Session(backend="host", n_nodes=2, threads_per_node=1, trace=True)
    try:
        logreg.fit(x, y, iters=5, session=sess)
        sess.tracer.export(path)
        snap = sess.tracer.snapshot()
        print(f"wrote {path}: {snap['events']} events, "
              f"categories {sorted(snap['spans_by_category'])}")
    finally:
        sess.tracer.disable()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--trace-bench", default="benchmarks/BENCH_trace.json",
                    help="step.trace overhead JSON (section skipped if absent)")
    ap.add_argument("--export-trace", default=None, metavar="PATH",
                    help="run a traced 2-thread logreg fit and write the "
                         "Perfetto-loadable trace JSON to PATH, then exit")
    ap.add_argument("--check-bench", default="benchmarks/BENCH_check.json",
                    help="step.check overhead JSON (section skipped if absent)")
    ap.add_argument("--export-check", default=None, metavar="PATH",
                    help="run the four analytics apps and a seeded race "
                         "under an armed checker and write the findings "
                         "JSON to PATH, then exit")
    args = ap.parse_args()
    if args.export_trace:
        export_sample_trace(args.export_trace)
        return
    if args.export_check:
        export_check_report(args.export_check)
        return
    if not os.path.isdir(args.out):
        print(f"# no dry-run records at {args.out}; skipping dryrun/roofline")
        if os.path.exists(args.trace_bench):
            trace_section(args.trace_bench)
        if os.path.exists(args.check_bench):
            check_section(args.check_bench)
        return
    recs, skips = load(args.out)

    print("### Dry-run matrix (lower+compile status, bytes/device)\n")
    print("| arch | shape | single-pod (256) | multi-pod (512) |")
    print("|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            cells = []
            for mesh in ("single", "multi"):
                k = (a, s, mesh, args.variant)
                if k in recs:
                    r = recs[k]
                    cells.append(f"OK — peak {fmt_bytes(r['peak_bytes'])} GiB, "
                                 f"{r['collective_by_op'] and '+'.join(sorted(r['collective_by_op'])) or 'no-coll'}")
                elif k in skips:
                    cells.append(f"SKIP ({skips[k]['reason'].split(':')[0]})")
                else:
                    cells.append("—")
            print(f"| {a} | {s} | {cells[0]} | {cells[1]} |")

    print("\n### Roofline (single-pod, per device, baseline)\n")
    print("| arch | shape | compute (ms) | memory (ms) | collective (ms) | bottleneck | MODEL_FLOPS | useful | peak GiB | note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            k = (a, s, "single", args.variant)
            if k in recs:
                r = recs[k]
                print(f"| {a} | {s} | {r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} | "
                      f"{r['collective_s']*1e3:.2f} | **{r['bottleneck']}** | "
                      f"{r['model_flops_total']:.2e} | {r['useful_ratio']:.3f} | "
                      f"{fmt_bytes(r['peak_bytes'])} | {improvement_note(r)} |")
            elif k in skips:
                print(f"| {a} | {s} | — | — | — | skipped | — | — | — | {skips[k]['reason']} |")

    if os.path.exists(args.trace_bench):
        trace_section(args.trace_bench)
    if os.path.exists(args.check_bench):
        check_section(args.check_bench)


if __name__ == "__main__":
    main()
