"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the JSONs.

    PYTHONPATH=src python scripts/make_report.py [--out experiments/dryrun]
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ARCH_ORDER = ["deepseek-v3-671b", "moonshot-v1-16b-a3b", "starcoder2-3b",
              "qwen3-4b", "qwen2-72b", "qwen3-1.7b", "llama-3.2-vision-90b",
              "zamba2-2.7b", "hubert-xlarge", "mamba2-2.7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(out_dir):
    from repro.configs import SHAPES, get_arch
    from repro.launch.roofline import model_flops

    recs, skips = {}, {}
    for fn in os.listdir(out_dir):
        if not fn.endswith(".json"):
            continue
        r = json.load(open(os.path.join(out_dir, fn)))
        key = (r["arch"], r["shape"], r["mesh"], r.get("variant", "baseline"))
        if r.get("skipped"):
            skips[key] = r
        else:
            # recompute MODEL_FLOPS/useful with the *current* formula so all
            # rows are mutually consistent regardless of when they were run
            mf = model_flops(get_arch(r["arch"]), SHAPES[r["shape"]])
            r["model_flops_total"] = mf
            if r["hlo_flops"]:
                r["useful_ratio"] = (mf / r["n_devices"]) / r["hlo_flops"]
            recs[key] = r
    return recs, skips


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def improvement_note(r):
    """One sentence on what moves the dominant term down."""
    b = r["bottleneck"]
    shape = r["shape"]
    if b == "memory":
        if "decode" in shape or "long" in shape:
            return "decode is cache-read bound: shrink per-token cache reads (MLA/SSM already minimal; quantize KV to int8)"
        return "cut HBM traffic: fuse attention internals into the Pallas flash kernel (keeps scores in VMEM) + bf16 intermediates"
    if b == "collective":
        return "cut TP collectives: bf16 all-reduce, sequence-sharded activations (AG/RS decomposition), hierarchical cross-pod reduce"
    return "compute-bound: raise MXU utilisation (bigger per-device tiles, skip causal-masked blocks, fewer remat recomputes)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    recs, skips = load(args.out)

    print("### Dry-run matrix (lower+compile status, bytes/device)\n")
    print("| arch | shape | single-pod (256) | multi-pod (512) |")
    print("|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            cells = []
            for mesh in ("single", "multi"):
                k = (a, s, mesh, args.variant)
                if k in recs:
                    r = recs[k]
                    cells.append(f"OK — peak {fmt_bytes(r['peak_bytes'])} GiB, "
                                 f"{r['collective_by_op'] and '+'.join(sorted(r['collective_by_op'])) or 'no-coll'}")
                elif k in skips:
                    cells.append(f"SKIP ({skips[k]['reason'].split(':')[0]})")
                else:
                    cells.append("—")
            print(f"| {a} | {s} | {cells[0]} | {cells[1]} |")

    print("\n### Roofline (single-pod, per device, baseline)\n")
    print("| arch | shape | compute (ms) | memory (ms) | collective (ms) | bottleneck | MODEL_FLOPS | useful | peak GiB | note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            k = (a, s, "single", args.variant)
            if k in recs:
                r = recs[k]
                print(f"| {a} | {s} | {r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} | "
                      f"{r['collective_s']*1e3:.2f} | **{r['bottleneck']}** | "
                      f"{r['model_flops_total']:.2e} | {r['useful_ratio']:.3f} | "
                      f"{fmt_bytes(r['peak_bytes'])} | {improvement_note(r)} |")
            elif k in skips:
                print(f"| {a} | {s} | — | — | — | skipped | — | — | — | {skips[k]['reason']} |")


if __name__ == "__main__":
    main()
