#!/usr/bin/env python
"""step_top — a live terminal view over ``Session.metrics()`` (step.obs).

The `top(1)` of a STEP session: one screen refreshed in place showing ops/s
per store verb, per-shard lock-wait quantiles, tier occupancy, the open
migration window (if any), accumulator round latency, and the watchdog's
anomaly tail.

Rendering is a pure function of two metrics snapshots (:func:`render` —
rates come from counter deltas over the refresh interval), so tests drive
it with synthetic dicts and never need a terminal.

Usage::

    PYTHONPATH=src python scripts/step_top.py --demo            # self-driving
    PYTHONPATH=src python scripts/step_top.py --demo --once     # one frame
    PYTHONPATH=src python scripts/step_top.py --demo --frames 10 --interval 0.5

Embedding in your own driver::

    from scripts.step_top import render
    print(render(session.metrics(), prev, dt, watchdog.anomalies))
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Any, Dict, List, Optional, Sequence

_CLEAR = "\x1b[2J\x1b[H"

#: store-op hist names whose rates headline the view
_OP_NAMES = ("store.get", "store.set", "store.inc", "store.mget")


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.1f}GiB"  # pragma: no cover - unreachable


def _fmt_us(us: float) -> str:
    return f"{us / 1000:.2f}ms" if us >= 1000 else f"{us:.0f}us"


def _rate(cur: Dict[str, Any], prev: Optional[Dict[str, Any]], op: str,
          dt: float) -> float:
    """ops/s for one hist: counter delta over dt when a previous snapshot
    exists, else the tracer's lifetime rate."""
    ops = cur.get("trace", {}).get("ops", {})
    row = ops.get(op)
    if row is None:
        return 0.0
    if prev is None or dt <= 0:
        return row.get("rate_per_s", 0.0)
    prow = prev.get("trace", {}).get("ops", {}).get(op, {})
    return max(0.0, (row.get("count", 0) - prow.get("count", 0)) / dt)


def render(metrics: Dict[str, Any], prev: Optional[Dict[str, Any]] = None,
           dt: float = 1.0, anomalies: Sequence[Any] = ()) -> str:
    """One step_top frame as a plain string (no ANSI codes)."""
    lines: List[str] = []
    trace = metrics.get("trace", {})
    ring = trace.get("ring") or {}
    mode = ("trace" if trace.get("enabled") and not trace.get("record_only")
            else "record" if trace.get("record_only") else "off")
    lines.append(
        f"step_top — backend={metrics.get('backend', '?')} "
        f"obs={mode} ring={ring.get('held', 0)}/{ring.get('capacity', 0)} "
        f"wire={metrics.get('wire_traffic', 0)} elems")
    lines.append("")

    # ops/s + latency per store verb
    lines.append(f"{'op':<12}{'ops/s':>10}{'p50':>10}{'p99':>10}{'max':>10}")
    ops = trace.get("ops", {})
    for op in _OP_NAMES:
        row = ops.get(op)
        if row is None:
            continue
        lines.append(f"{op:<12}{_rate(metrics, prev, op, dt):>10.1f}"
                     f"{_fmt_us(row.get('p50', 0)):>10}"
                     f"{_fmt_us(row.get('p99', 0)):>10}"
                     f"{_fmt_us(row.get('max', 0)):>10}")

    # accumulator round latency (per-thread round + its barrier share)
    acc = ops.get("accumulate")
    bar = ops.get("accumulate.barrier") or ops.get("barrier.wait")
    if acc or bar:
        lines.append("")
        if acc:
            lines.append(
                f"accum round  p50={_fmt_us(acc.get('p50', 0))} "
                f"p99={_fmt_us(acc.get('p99', 0))} "
                f"rounds={int(acc.get('count', 0))} "
                f"rate={_rate(metrics, prev, 'accumulate', dt):.1f}/s")
        if bar:
            lines.append(f"barrier wait p50={_fmt_us(bar.get('p50', 0))} "
                         f"p99={_fmt_us(bar.get('p99', 0))}")

    # per-shard lock wait
    per = trace.get("ops_by_shard", {}).get("store.lock_wait", {})
    if per:
        lines.append("")
        lines.append(f"{'shard':<8}{'lock p50':>10}{'lock p99':>10}"
                     f"{'waits':>8}")
        for sid in sorted(per):
            row = per[sid]
            lines.append(f"{sid:<8}{_fmt_us(row.get('p50', 0)):>10}"
                         f"{_fmt_us(row.get('p99', 0)):>10}"
                         f"{int(row.get('count', 0)):>8}")

    # tiers + migration
    tiers = metrics.get("tiers", {})
    hot, cold = tiers.get("hot", {}), tiers.get("cold", {})
    lines.append("")
    lines.append(
        f"tiers  hot={hot.get('entries', 0)} entries/"
        f"{_fmt_bytes(hot.get('bytes', 0))} "
        f"cold={tiers.get('cold_entries', 0)} entries/"
        f"{_fmt_bytes(cold.get('bytes', 0))} "
        f"promote={tiers.get('promotions', 0)} "
        f"demote={tiers.get('demotions', 0)}")
    mig = tiers.get("migration", {})
    state = (f"OPEN pending={mig.get('pending', 0)}" if mig.get("open")
             else "idle")
    lines.append(
        f"migration  {state}  windows={mig.get('windows', 0)} "
        f"moved={mig.get('entries_moved', 0)} "
        f"({_fmt_bytes(mig.get('bytes_moved', 0))}) "
        f"pulled={mig.get('pulled', 0)}")

    if anomalies:
        lines.append("")
        lines.append(f"anomalies ({len(anomalies)}):")
        for a in list(anomalies)[-5:]:
            kind = a.get("kind") if isinstance(a, dict) else getattr(a, "kind", "?")
            msg = a.get("message") if isinstance(a, dict) else getattr(a, "message", "")
            lines.append(f"  [{kind}] {msg}")
    return "\n".join(lines)


def _demo_session():
    """A self-driving session for ``--demo``: background threads hammer a
    small tiered sharded store so every panel has live numbers."""
    import threading

    import jax.numpy as jnp

    from repro.core.session import Session

    sess = Session(shards=4, cold_tier="host", cold_budget=1 << 16,
                   record=True)
    refs = [sess.new_array(f"demo{i}", (2048,)) for i in range(16)]
    stop = threading.Event()

    def churn(seed: int) -> None:
        i = seed
        while not stop.is_set():
            ref = refs[i % len(refs)]
            if i % 3 == 0:
                ref.set(jnp.full((2048,), float(i)))
            else:
                ref.get()
            i += 1
            time.sleep(0.002)

    workers = [threading.Thread(target=churn, args=(k,), daemon=True)
               for k in range(4)]
    for w in workers:
        w.start()
    return sess, stop, workers


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--demo", action="store_true",
                    help="drive a synthetic workload session to watch")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="refresh interval in seconds")
    ap.add_argument("--frames", type=int, default=0,
                    help="stop after N frames (0 = until interrupted)")
    ap.add_argument("--once", action="store_true",
                    help="print a single frame and exit")
    ap.add_argument("--no-clear", action="store_true",
                    help="append frames instead of redrawing in place")
    args = ap.parse_args(argv)

    if not args.demo:
        ap.error("only --demo mode ships today: step_top needs an in-process "
                 "session (pass --demo, or import render() in your driver)")
    sess, stop, workers = _demo_session()
    watchdog = sess.watchdog(interval_s=0.25).start()
    prev = None
    t_prev = time.perf_counter()
    frames = 1 if args.once else args.frames
    n = 0
    try:
        while True:
            time.sleep(0.25 if prev is None else args.interval)
            cur, t_cur = sess.metrics(), time.perf_counter()
            frame = render(cur, prev, t_cur - t_prev, watchdog.anomalies)
            if not args.no_clear and not args.once:
                sys.stdout.write(_CLEAR)
            sys.stdout.write(frame + "\n")
            sys.stdout.flush()
            prev, t_prev = cur, t_cur
            n += 1
            if frames and n >= frames:
                break
    except KeyboardInterrupt:  # pragma: no cover - interactive exit
        pass
    finally:
        stop.set()
        # a churn thread killed mid-jax-dispatch at interpreter exit aborts
        # the process — wait for each to park before tearing down
        for w in workers:
            w.join(timeout=2)
        watchdog.stop()
        sess.recorder.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
