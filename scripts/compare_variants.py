"""Print baseline vs hillclimb variants for the §Perf cells.

    PYTHONPATH=src python scripts/compare_variants.py
"""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

CELLS = [
    ("qwen3-1.7b", "train_4k"),
    ("qwen2-72b", "prefill_32k"),
    ("moonshot-v1-16b-a3b", "train_4k"),
]


def main(out_dir="experiments/dryrun"):
    from repro.configs import SHAPES, get_arch
    from repro.launch.roofline import model_flops

    for arch, shape in CELLS:
        rows = []
        for fn in sorted(os.listdir(out_dir)):
            if not fn.startswith(f"{arch}__{shape}__single__") or not fn.endswith(".json"):
                continue
            if "skip" in fn:
                continue
            r = json.load(open(os.path.join(out_dir, fn)))
            mf = model_flops(get_arch(arch), SHAPES[shape])
            useful = (mf / r["n_devices"]) / r["hlo_flops"] if r["hlo_flops"] else 0
            rows.append((r.get("variant", "baseline"), r, useful))
        rows.sort(key=lambda x: (x[0] != "baseline", x[0]))
        print(f"\n=== {arch} × {shape} (single-pod, per device) ===")
        print(f"{'variant':<16s} {'C(ms)':>10s} {'M(ms)':>10s} {'X(ms)':>10s} "
              f"{'dominant':>10s} {'Δdom%':>7s} {'useful':>7s} {'peak GiB':>9s}")
        base = None
        for name, r, useful in rows:
            dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
            if name == "baseline":
                base = dom
            delta = f"{(dom-base)/base*100:+.1f}" if base else ""
            print(f"{name:<16s} {r['compute_s']*1e3:10.1f} {r['memory_s']*1e3:10.1f} "
                  f"{r['collective_s']*1e3:10.1f} {r['bottleneck']:>10s} {delta:>7s} "
                  f"{useful:7.3f} {r['peak_bytes']/2**30:9.2f}")


if __name__ == "__main__":
    main(*sys.argv[1:])
