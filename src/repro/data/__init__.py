from repro.data.pipeline import LMDataPipeline, Prefetcher, partition_rows, shard_batch
from repro.data.synthetic import (
    SyntheticLM,
    kmeans_dataset,
    lm_batch,
    logreg_dataset,
    nmf_dataset,
    powerlaw_graph,
)

__all__ = [
    "LMDataPipeline", "Prefetcher", "partition_rows", "shard_batch",
    "SyntheticLM", "kmeans_dataset", "lm_batch", "logreg_dataset",
    "nmf_dataset", "powerlaw_graph",
]
