"""Deterministic synthetic datasets mirroring the paper's evaluation data.

The paper evaluates on GENE/LRS (logistic regression), FOREST/KMS (K-means),
NETFLIX/NMFS (NMF), LJ/FRIEND (PageRank) plus we add LM token streams for the
assigned transformer architectures.  Everything is generated deterministically
from a seed so checkpoint/restart reproduces the exact stream (stateless,
index-addressable — the FT layer only persists the step counter).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp


# -- logistic regression (GENE / LRS analogues) ------------------------------


def logreg_dataset(n_rows: int, n_features: int, seed: int = 0, noise: float = 0.1):
    """Linearly-separable-ish binary data with a known ground-truth theta."""
    rng = np.random.default_rng(seed)
    theta_true = rng.normal(size=(n_features,)).astype(np.float32)
    x = rng.normal(size=(n_rows, n_features)).astype(np.float32)
    logits = x @ theta_true + noise * rng.normal(size=(n_rows,)).astype(np.float32)
    y = (1 / (1 + np.exp(-logits)) > 0.5).astype(np.float32)
    return x, y, theta_true


# -- K-means (FOREST / KMS analogues) -----------------------------------------


def kmeans_dataset(n_rows: int, n_features: int, k: int, seed: int = 0, spread: float = 0.15):
    """Gaussian blobs around k well-separated centers."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-1, 1, size=(k, n_features)).astype(np.float32)
    assign = rng.integers(0, k, size=(n_rows,))
    x = centers[assign] + spread * rng.normal(size=(n_rows, n_features)).astype(np.float32)
    return x.astype(np.float32), centers, assign


# -- NMF (NETFLIX / NMFS analogues) -------------------------------------------


def nmf_dataset(n_rows: int, n_cols: int, rank: int, seed: int = 0, noise: float = 0.01):
    """Non-negative low-rank matrix R ≈ P·Q plus noise."""
    rng = np.random.default_rng(seed)
    p = np.abs(rng.normal(size=(n_rows, rank))).astype(np.float32)
    q = np.abs(rng.normal(size=(rank, n_cols))).astype(np.float32)
    r = p @ q + noise * np.abs(rng.normal(size=(n_rows, n_cols))).astype(np.float32)
    return r.astype(np.float32), p, q


# -- PageRank (LJ / FRIEND analogues) ------------------------------------------


def powerlaw_graph(n_vertices: int, avg_degree: int = 8, seed: int = 0):
    """Preferential-attachment-flavoured directed edge list (src, dst)."""
    rng = np.random.default_rng(seed)
    n_edges = n_vertices * avg_degree
    # Zipf-ish destination popularity, uniform sources — cheap power-law proxy.
    dst_pop = rng.zipf(1.6, size=n_edges) % n_vertices
    src = rng.integers(0, n_vertices, size=n_edges)
    edges = np.stack([src, dst_pop], axis=1).astype(np.int32)
    return edges


# -- LM token streams ----------------------------------------------------------


def lm_batch(step: int, global_batch: int, seq_len: int, vocab: int, seed: int = 0):
    """Index-addressable synthetic token batch: batch(step) is pure in (seed, step)."""
    rng = np.random.default_rng(np.uint64(seed * 1_000_003 + step))
    tokens = rng.integers(0, vocab, size=(global_batch, seq_len + 1), dtype=np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


@dataclass
class SyntheticLM:
    """Stateless LM stream; restart(step) is exact by construction."""

    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0

    def batch(self, step: int):
        return lm_batch(step, self.global_batch, self.seq_len, self.vocab, self.seed)
