"""Sharded, prefetching data pipeline.

Production posture: batches are generated (or read) on host, placed onto the
mesh with a data-axis NamedSharding, and prefetched one step ahead on a
background thread so host→device transfer overlaps the previous step's compute
(the paper's "one thread per node fetches and shares locally" discussion, §4.5,
turned into an input pipeline).

The stream is stateless in (seed, step) — restart-exactness for FT: restoring
a checkpoint at step k and re-iterating reproduces the same batches.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.synthetic import SyntheticLM


def shard_batch(batch, mesh: Optional[Mesh], data_axes=("data",)):
    """Place a host batch dict onto the mesh, sharded along the batch dim."""
    if mesh is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    spec = P(data_axes) if isinstance(data_axes, tuple) else P((data_axes,))
    sharding = NamedSharding(mesh, spec)
    return jax.tree.map(lambda x: jax.device_put(x, sharding), batch)


def partition_rows(n_rows: int, tid: int, n_threads: int):
    """The paper's ``LoadTrainPoint`` — thread tid's contiguous row range."""
    per = n_rows // n_threads
    extra = n_rows % n_threads
    start = tid * per + min(tid, extra)
    stop = start + per + (1 if tid < extra else 0)
    return start, stop


class Prefetcher:
    """Background single-slot prefetcher: overlaps batch build + H2D with compute."""

    def __init__(self, make_batch: Callable[[int], object], start_step: int = 0, depth: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._make(step)), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


class LMDataPipeline:
    """End-to-end LM pipeline: synthetic stream → mesh-sharded, prefetched batches."""

    def __init__(self, global_batch: int, seq_len: int, vocab: int,
                 mesh: Optional[Mesh] = None, seed: int = 0, start_step: int = 0,
                 data_axes=("data",), prefetch: bool = True):
        self.stream = SyntheticLM(global_batch, seq_len, vocab, seed)
        self.mesh = mesh
        self.data_axes = data_axes
        self._prefetcher = None
        if prefetch:
            self._prefetcher = Prefetcher(self._build, start_step)
        self._step = start_step

    def _build(self, step: int):
        return shard_batch(self.stream.batch(step), self.mesh, self.data_axes)

    def next(self):
        if self._prefetcher is not None:
            step, batch = next(self._prefetcher)
        else:
            step, batch = self._step, self._build(self._step)
        self._step = step + 1
        return step, batch

    def close(self):
        if self._prefetcher is not None:
            self._prefetcher.close()
