from repro.ft.checkpoint import (
    AsyncCheckpointer,
    Checkpoint,
    latest_step,
    list_checkpoints,
    restore_checkpoint,
    save_checkpoint,
)
from repro.ft.elastic import (RecoveryPlan, elastic_restore, plan_recovery,
                              rebalance_batch, rebalance_shards, reshard_tree,
                              session_recovery)
from repro.ft.heartbeat import (HeartbeatMonitor, PAYLOAD_KEYS,
                                REBALANCE_KEYS, metrics_payload)

__all__ = [
    "AsyncCheckpointer", "Checkpoint", "latest_step", "list_checkpoints",
    "restore_checkpoint", "save_checkpoint",
    "RecoveryPlan", "elastic_restore", "plan_recovery", "rebalance_batch",
    "rebalance_shards", "reshard_tree", "session_recovery",
    "HeartbeatMonitor", "PAYLOAD_KEYS", "REBALANCE_KEYS", "metrics_payload",
]
