"""Checkpoint-based recovery — STEP §5.4.

The paper checkpoints a consistent copy of DSM every few iterations, right
before barrier release, to a fault-tolerant FS; recovery rolls every thread
back to the latest checkpoint.  Here:

* ``save_checkpoint`` persists any pytree (params / optimizer state / DSM
  GlobalStore contents / data-pipeline step) to a directory of ``.npy`` leaves
  plus a JSON manifest — sharded ``jax.Array``s are gathered to host first.
  Saves are atomic (write to ``.tmp`` then rename) and optionally **async**
  (background thread) so the training loop is not blocked — the paper's
  barrier-adjacent checkpoint with the write overlapped.
* ``restore_checkpoint`` loads the newest (or a specific) step; the mesh/
  sharding to restore *onto* is supplied by the caller, which is what makes
  multi-node recovery and elastic rescale work (ft/elastic.py).
* :class:`Checkpoint` is the paper's user hook (``DoCheckpoint``/``DoRestart``)
  for program-specific state.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.utils.tree import tree_flatten_with_paths


_MANIFEST = "manifest.json"


def _ckpt_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def save_checkpoint(root: str, step: int, tree: Any, *, extra: Optional[Dict] = None,
                    keep: int = 3) -> str:
    """Atomically persist `tree` for `step`; prune to the newest `keep` ckpts."""
    os.makedirs(root, exist_ok=True)
    final = _ckpt_dir(root, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "time": time.time(), "leaves": [], "extra": extra or {}}
    for i, (path, leaf) in enumerate(tree_flatten_with_paths(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({"path": path, "file": fname,
                                   "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(root, keep)
    return final


def _prune(root: str, keep: int) -> None:
    steps = sorted(list_checkpoints(root))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_ckpt_dir(root, s), ignore_errors=True)


def list_checkpoints(root: str):
    if not os.path.isdir(root):
        return []
    out = []
    for d in os.listdir(root):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(root, d, _MANIFEST)):
                out.append(int(d.split("_")[1]))
    return sorted(out)


def latest_step(root: str) -> Optional[int]:
    steps = list_checkpoints(root)
    return steps[-1] if steps else None


def restore_checkpoint(root: str, template: Any, *, step: Optional[int] = None,
                       shardings: Any = None):
    """Restore into the structure of `template` (pytree of arrays or SDS).

    ``shardings`` — optional pytree (or single sharding) to place leaves onto:
    this is the knob multi-node/elastic recovery turns (restore onto the
    *surviving* mesh).  Returns (tree, manifest_extra, step).
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = _ckpt_dir(root, step)
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    by_path = {rec["path"]: rec for rec in manifest["leaves"]}

    flat = tree_flatten_with_paths(template)
    leaves = []
    for path, tmpl in flat:
        rec = by_path.get(path)
        if rec is None:
            raise KeyError(f"checkpoint {d} missing leaf {path}")
        arr = np.load(os.path.join(d, rec["file"]))
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{path}: ckpt shape {arr.shape} != template {tmpl.shape}")
        leaves.append(arr.astype(tmpl.dtype))

    treedef = jax.tree.structure(template)
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        if jax.tree.structure(shardings, is_leaf=lambda x: x is None) != treedef:
            tree = jax.tree.map(lambda x: jax.device_put(x, shardings), tree)
        else:
            tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest.get("extra", {}), step


class AsyncCheckpointer:
    """Non-blocking saver: snapshot to host, write on a background thread."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        self.wait()  # one in flight at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_checkpoint(self.root, step, host_tree, extra=extra, keep=self.keep)
            self.last_saved = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


class Checkpoint:
    """Paper §5.4 user hook: extend and override to persist extra program state."""

    def do_checkpoint(self) -> Dict:
        return {}

    def do_restart(self, state: Dict) -> None:
        pass

    # paper-cased aliases
    DoCheckpoint = do_checkpoint
    DoRestart = do_restart
