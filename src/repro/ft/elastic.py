"""Elastic recovery — STEP §5.4's single-/multi-node recovery, generalised.

The paper recreates failed threads on healthy nodes and rolls everyone back to
the latest DSM checkpoint; *multi-node recovery* spreads the failed node's work
across several survivors (Fig. 11: 196ms → 63ms).  On a TPU pod the equivalent
is **restoring the checkpoint resharded onto the surviving mesh**: the
checkpoint is mesh-agnostic host data, so recovery = rebuild a (smaller or
larger) mesh, recompute shardings, ``device_put``, and continue — elastic
scale-down on failure, scale-up when capacity returns.

``plan_recovery`` also reproduces the paper's work-reassignment choice:
``single`` routes all of the dead node's shards/threads to one survivor;
``multi`` round-robins them across all survivors (the faster option, Fig. 11).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.ft.checkpoint import restore_checkpoint


@dataclass
class RecoveryPlan:
    """Which survivor takes over each failed worker's partition."""

    mode: str                      # "single" | "multi"
    reassignment: Dict[int, int]   # failed worker tid -> survivor node id
    new_world: List[int]           # surviving node ids
    migration: Optional[Any] = None  # ShardMigration when the DSM rebalanced
    # step.obs: the dead session's flight-recorder dump, captured at the
    # moment recovery started (before the open window drains) — the "black
    # box" for the postmortem, attached when the session had an armed
    # FlightRecorder
    flight_dump: Optional[Dict[str, Any]] = None


def rebalance_shards(store, *, join: Sequence[int] = (), leave: Sequence[int] = ()):
    """Elastic ring rebalance on node join/leave (the sharded-store half of
    §5.4 recovery).

    Joining nodes get a shard arc on the consistent-hash ring; leaving nodes'
    shards hand their arcs to the survivors.  Only the ~1/S of keys whose arc
    changed owner migrate — each with its epoch, delete-era generation and
    watcher-directory record intact, so no cache replica goes stale and no
    deleted-era name can resurface after the move.  Since step.tiers each
    topology change runs as an *incremental* migration window (readers and
    writers keep flowing; each moved arc settles one entry at a time), and
    the returned plan records the window cost — ``bytes_moved`` and
    ``window_s`` — alongside the key map.  Returns the merged
    :class:`~repro.core.shards.ShardMigration` (or ``None`` if the topology
    did not change — e.g. a dead node that never had a shard, or the last
    shard, which can't be removed).
    """
    from repro.core.shards import ShardMigration

    merged: Optional[ShardMigration] = None
    for sid in join:
        if sid in store.shard_ids():
            continue
        merged = _merge_migrations(merged, store.add_shard(sid))
    for sid in leave:
        if sid not in store.shard_ids() or store.n_shards == 1:
            continue
        merged = _merge_migrations(merged, store.remove_shard(sid))
    return merged


def _merge_migrations(a, b):
    if a is None:
        return b
    # a key moved twice reports its original source and final destination
    moved = dict(a.moved)
    epochs = dict(a.epochs)
    for name, (src, dst) in b.moved.items():
        moved[name] = (moved[name][0] if name in moved else src, dst)
        epochs[name] = b.epochs[name]
    return type(b)(a.added + b.added, a.removed + b.removed, moved, epochs,
                   b.total_names, a.bytes_moved + b.bytes_moved,
                   a.window_s + b.window_s, a.pulled + b.pulled)


def plan_recovery(failed_nodes: Sequence[int], all_nodes: Sequence[int],
                  tids_by_node: Dict[int, List[int]], mode: str = "multi") -> RecoveryPlan:
    survivors = [n for n in all_nodes if n not in set(failed_nodes)]
    if not survivors:
        raise RuntimeError("no survivors — unrecoverable")
    reassignment: Dict[int, int] = {}
    lost_tids = [t for n in failed_nodes for t in tids_by_node.get(n, [])]
    if mode == "single":
        target = survivors[0]
        for t in lost_tids:
            reassignment[t] = target
    elif mode == "multi":
        for i, t in enumerate(lost_tids):
            reassignment[t] = survivors[i % len(survivors)]
    else:
        raise ValueError(f"unknown recovery mode {mode}")
    return RecoveryPlan(mode, reassignment, survivors)


def session_recovery(session, failed_nodes: Sequence[int], mode: str = "multi",
                     threads_per_node: Optional[int] = None,
                     rebalance: bool | str = "auto"):
    """STEP §5.4 on the Session facade: plan the reassignment of a failed
    node's threads and build a replacement host Session over the survivors.

    The new session adopts the old session's :class:`GlobalStore`, which is
    exactly the paper's "roll back to the latest DSM state": shared data
    survives the node loss, only the thread placement changes.  ``single``
    routes all lost threads to one survivor; ``multi`` round-robins them
    (the faster option, Fig. 11).

    ``rebalance`` controls the ring: ``"auto"`` (default) removes each failed
    node's shard from the consistent-hash ring only when the session follows
    the shards-per-node convention (``store.n_shards == n_nodes``, so shard
    ids ARE node ids) — only its ~1/S of keys migrate to survivors (epochs
    preserved), recorded in ``plan.migration``.  Any other shard count keeps
    the ring untouched (node ids and shard ids are unrelated there; a
    coincidental id match must not evict a healthy shard).  ``True`` forces
    the removal, ``False`` disables it.
    """
    from repro.core.session import HostBackend, Session

    if session.backend.kind != "host":
        raise ValueError("session_recovery drills node failure on the host "
                         "backend; SPMD recovery goes through elastic_restore")
    # black box first: capture the flight recorder *before* recovery mutates
    # anything, so the dump shows the store as the failure left it (open
    # window, pending entries and all) — the recovery mark itself is the
    # dump's last breadcrumb
    from repro.core import telemetry
    recorder = getattr(session, "recorder", None)
    flight_dump = None
    if recorder is not None and getattr(recorder, "armed", False):
        trc = session.tracer
        if telemetry.TRACING and trc.enabled:
            trc.mark("lifecycle", "session_recovery",
                     failed=list(failed_nodes), mode=mode)
        flight_dump = recorder.dump(reason="session-recovery")
    # a crash can land mid-migration: the incremental window lives on the
    # store (which survives the session), so recovery first drains any open
    # window to completion — every entry settles at its ring owner exactly
    # once (moves are idempotent), nothing is lost or duplicated
    if session.store.migration_window is not None:
        session.store.drain_window()
    pool = session.backend.pool
    tids_by_node = {n: [n * pool.threads_per_node + i
                        for i in range(pool.threads_per_node)]
                    for n in range(pool.n_nodes)}
    plan = plan_recovery(failed_nodes, list(range(pool.n_nodes)),
                         tids_by_node, mode=mode)
    shards_follow_nodes = session.store.n_shards == pool.n_nodes
    if rebalance is True or (rebalance == "auto" and shards_follow_nodes):
        plan.migration = rebalance_shards(session.store, leave=failed_nodes)
    plan.flight_dump = flight_dump
    tpn = threads_per_node or pool.threads_per_node
    # the replacement session adopts the dead session's tracer, checker and
    # flight recorder as-is, so an armed step.trace/step.check/step.obs
    # survives recovery (spans, findings and the event ring keep
    # accumulating) and a disabled one stays disabled
    new_session = Session(backend=HostBackend(len(plan.new_world), tpn),
                          store=session.store, accum_mode=session.accum_mode,
                          trace=session.tracer, check=session.checker,
                          record=recorder)
    return plan, new_session


def reshard_tree(tree: Any, mesh: Mesh, specs: Any):
    """Place a host (or device) pytree onto `mesh` with `specs` (pytree or one P)."""
    if isinstance(specs, P) or specs is None:
        sh = NamedSharding(mesh, specs if specs is not None else P())
        return jax.tree.map(lambda x: jax.device_put(np.asarray(jax.device_get(x)), sh), tree)
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), NamedSharding(mesh, s)),
        tree, specs,
    )


def elastic_restore(root: str, template: Any, mesh: Mesh, specs: Any,
                    step: Optional[int] = None):
    """Restore the newest checkpoint onto an arbitrary (new) mesh.

    This is both multi-node recovery (mesh = survivors) and elastic rescale
    (mesh = grown/shrunk cluster).  Checkpoints are mesh-agnostic, so no
    conversion pass is needed — sharding happens at placement time.
    """
    tree, extra, got_step = restore_checkpoint(root, template, step=step)
    return reshard_tree(tree, mesh, specs), extra, got_step


def rebalance_batch(global_batch: int, old_dp: int, new_dp: int) -> int:
    """Keep the global batch stable across a DP-degree change where possible;
    otherwise round down to a multiple of the new degree (logged by caller)."""
    if global_batch % new_dp == 0:
        return global_batch
    return max(new_dp, (global_batch // new_dp) * new_dp)
