"""Heartbeat failure detection — STEP §5.4.

Every slave sends heartbeats to the master; a slave silent for longer than the
timeout is declared dead and recovery starts.  This is a host-side control
plane and ports unchanged: workers (threads here, hosts on a real pod) beat a
monitor; the monitor invokes an ``on_failure`` callback with the dead node ids.
A ``virtual_barrier`` pause (the paper's "checkpoint" command for async tasks)
is exposed as ``pause``/``resume`` events the workers poll.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Set


#: Canonical key set of one heartbeat payload.  Dashboards and exporters key
#: off this — every :func:`metrics_payload` carries exactly these fields, on
#: every session flavour, whether or not tracing/recording/migration ever ran.
PAYLOAD_KEYS = ("trace_enabled", "record_armed", "op_rates",
                "barrier_wait_us", "wire_traffic", "rebalance")

#: Canonical key set of the payload's ``rebalance`` record (the store's
#: lifetime migration totals plus live-window state).  A store that never
#: migrated — or one without migration support at all — still emits every
#: key, zeroed, so the dashboard column set is stable from the first beat.
REBALANCE_KEYS = ("windows", "entries_moved", "bytes_moved", "pulled",
                  "window_s", "open", "pending")

_REBALANCE_ZERO = {"windows": 0, "entries_moved": 0, "bytes_moved": 0,
                   "pulled": 0, "window_s": 0.0, "open": False, "pending": 0}


def metrics_payload(session) -> Dict[str, Any]:
    """A compact metrics snapshot for heartbeat payloads: op rates plus
    barrier-wait latency quantiles, pulled from the session's tracer.  Cheap
    (a handful of dict reads) and safe on a disabled tracer — everything
    degenerates to zeros.  Key set pinned by :data:`PAYLOAD_KEYS` /
    :data:`REBALANCE_KEYS`."""
    snap = session.tracer.snapshot()
    ops = snap.get("ops", {})
    # barrier time has two sources: explicit DBarrier.enter waits and the
    # accumulator's round barrier — merge them (count sums; quantiles take
    # the slower source, a conservative straggler signal)
    waits = [ops[n] for n in ("barrier.wait", "accumulate.barrier") if n in ops]
    # lifetime rebalance totals (windows, entries/bytes moved, reader pulls,
    # open-window flag) — lets the monitor see a live migration.  Built onto
    # the zero record so the key set never depends on the store's history.
    totals = getattr(session.store, "migration_totals", dict)()
    rebalance = {k: totals.get(k, _REBALANCE_ZERO[k]) for k in REBALANCE_KEYS}
    recorder = getattr(session, "recorder", None)
    return {
        "trace_enabled": snap.get("enabled", False),
        "record_armed": bool(recorder is not None and recorder.armed),
        "op_rates": {name: row.get("rate_per_s", 0.0)
                     for name, row in ops.items()},
        "barrier_wait_us": {
            "p50": max((w["p50"] for w in waits), default=0.0),
            "p99": max((w["p99"] for w in waits), default=0.0),
            "count": sum(w["count"] for w in waits),
        },
        "wire_traffic": session.wire_traffic(),
        "rebalance": rebalance,
    }


class HeartbeatMonitor:
    def __init__(self, node_ids: List[int], timeout: float = 0.5,
                 check_interval: float = 0.05,
                 on_failure: Optional[Callable[[List[int]], None]] = None):
        self.timeout = timeout
        self.check_interval = check_interval
        self.on_failure = on_failure
        self._last: Dict[int, float] = {n: time.monotonic() for n in node_ids}
        self._payloads: Dict[int, Any] = {}
        self._dead: Set[int] = set()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._pause = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- slave side ------------------------------------------------------------

    def beat(self, node_id: int, payload: Optional[Any] = None) -> None:
        """Record a heartbeat; ``payload`` (typically :func:`metrics_payload`)
        piggybacks the node's latest metrics snapshot on the liveness signal,
        so the master sees op rates and barrier-wait quantiles without a
        second channel."""
        with self._lock:
            if node_id not in self._dead:
                self._last[node_id] = time.monotonic()
                if payload is not None:
                    self._payloads[node_id] = payload

    # -- master-side payload inspection ----------------------------------------

    def last_payload(self, node_id: int) -> Optional[Any]:
        with self._lock:
            return self._payloads.get(node_id)

    def payloads(self) -> Dict[int, Any]:
        with self._lock:
            return dict(self._payloads)

    def should_pause(self) -> bool:
        """Workers poll this at barrier boundaries (virtual-barrier checkpoint)."""
        return self._pause.is_set()

    # -- master side -------------------------------------------------------------

    def start(self) -> None:
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.is_set():
            now = time.monotonic()
            newly_dead = []
            with self._lock:
                for n, t in self._last.items():
                    if n not in self._dead and now - t > self.timeout:
                        self._dead.add(n)
                        newly_dead.append(n)
            if newly_dead and self.on_failure is not None:
                self.on_failure(newly_dead)
            time.sleep(self.check_interval)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)

    def pause(self) -> None:
        """Broadcast the paper's 'checkpoint' command (enforce a virtual barrier)."""
        self._pause.set()

    def resume(self) -> None:
        self._pause.clear()

    def dead_nodes(self) -> List[int]:
        with self._lock:
            return sorted(self._dead)

    def declare_dead(self, node_id: int) -> None:
        """Test/drill hook: fail a node immediately."""
        with self._lock:
            self._dead.add(node_id)
        if self.on_failure is not None:
            self.on_failure([node_id])

    def revive(self, node_id: int) -> None:
        with self._lock:
            self._dead.discard(node_id)
            self._last[node_id] = time.monotonic()
