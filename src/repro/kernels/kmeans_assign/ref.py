"""Oracle: full distance matrix argmin."""

import jax.numpy as jnp


def kmeans_assign_ref(points, centers):
    pts = points.astype(jnp.float32)
    ctr = centers.astype(jnp.float32)
    d2 = (jnp.sum(pts**2, axis=1, keepdims=True)
          - 2.0 * pts @ ctr.T
          + jnp.sum(ctr**2, axis=1)[None, :])
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)
