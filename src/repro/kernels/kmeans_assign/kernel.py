"""K-means assignment kernel: nearest-center via MXU distance GEMM.

The paper's K-means hot loop (§6.5) is distance computation + argmin per
point.  ‖p − c‖² = ‖p‖² − 2·p·c + ‖c‖², so the TPU schedule is one
(block_n, D) × (D, K) GEMM per point tile (centers stay VMEM-resident) plus a
lane reduction — exactly how the MXU wants it.  Outputs the assignment and
the distance (needed for the inertia metric).  Grid = (N / block_n,).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(pts_ref, ctr_ref, assign_ref, dist_ref):
    pts = pts_ref[...].astype(jnp.float32)                    # (bn, D)
    ctr = ctr_ref[...].astype(jnp.float32)                    # (K, D)
    p2 = jnp.sum(pts * pts, axis=1, keepdims=True)            # (bn, 1)
    c2 = jnp.sum(ctr * ctr, axis=1)[None, :]                  # (1, K)
    dots = jax.lax.dot_general(pts, ctr, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    d2 = p2 - 2.0 * dots + c2                                  # (bn, K)
    assign_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dist_ref[...] = jnp.min(d2, axis=1)


def kmeans_assign_blocked(points, centers, *, block_n: int = 256,
                          interpret: bool = False):
    """points (N, D), centers (K, D) → (assign (N,) int32, dist² (N,) f32)."""
    n, d = points.shape
    k = centers.shape[0]
    block_n = min(block_n, n)
    grid = (pl.cdiv(n, block_n),)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda j: (j, 0)),
            pl.BlockSpec((k, d), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda j: (j,)),
            pl.BlockSpec((block_n,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(points, centers)
