from repro.kernels.kmeans_assign import kernel, ops, ref
