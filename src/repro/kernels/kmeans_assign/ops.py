"""Jit'd wrapper for the k-means assignment kernel."""

from functools import partial

import jax

from repro.kernels.kmeans_assign.kernel import kmeans_assign_blocked


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign(points, centers, *, block_n: int = 256, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return kmeans_assign_blocked(points, centers, block_n=block_n, interpret=interpret)
