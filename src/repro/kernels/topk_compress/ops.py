"""Jit'd wrapper for blocked top-k compression.

This is the entry point :func:`repro.core.sparse.blocked_topk_sparsify`
dispatches to: compiled Pallas on TPU, interpret mode everywhere else (the
kernel then runs as regular traced jax ops, so it stays legal inside
``shard_map`` and ``lax.scan`` — the accumulator's SPMD sparse path relies
on this).
"""

from functools import partial

import jax

from repro.kernels.topk_compress.kernel import topk_compress_blocked


@partial(jax.jit, static_argnames=("k_per_block", "block_v", "interpret", "method"))
def topk_compress(x, *, k_per_block: int, block_v: int = 1024, interpret=None,
                  method=None):
    """``method`` picks the selection kernel: ``"argmax"`` (k-iteration loop),
    ``"bitonic"`` (partial sort, k-independent), or ``None`` to auto-select
    bitonic for budgets past the argmax crossover (k_per_block ≥ 65)."""
    if x.ndim != 1:
        raise ValueError(f"topk_compress wants a 1-D vector, got shape {x.shape}")
    if k_per_block < 1:
        raise ValueError(f"k_per_block must be >= 1, got {k_per_block}")
    if k_per_block > min(block_v, x.shape[0]):
        raise ValueError(
            f"k_per_block={k_per_block} exceeds the block size "
            f"{min(block_v, x.shape[0])} — nothing left to select")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return topk_compress_blocked(x, k_per_block=k_per_block, block_v=block_v,
                                 interpret=interpret, method=method)
