"""Jit'd wrapper for blocked top-k compression."""

from functools import partial

import jax

from repro.kernels.topk_compress.kernel import topk_compress_blocked


@partial(jax.jit, static_argnames=("k_per_block", "block_v", "interpret"))
def topk_compress(x, *, k_per_block: int, block_v: int = 1024, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return topk_compress_blocked(x, k_per_block=k_per_block, block_v=block_v,
                                 interpret=interpret)
