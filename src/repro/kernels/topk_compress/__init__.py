from repro.kernels.topk_compress import kernel, ops, ref
