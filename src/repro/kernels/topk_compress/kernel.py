"""Per-block top-k magnitude compression — the accumulator's sparse mode.

STEP §5.2 ships sparse vectors as (index, value) pairs.  For gradients the
production form is blocked top-k: each 128-lane-aligned block contributes its
``k_per_block`` largest-|x| entries, so selection is lane-parallel with no
global sort (the same schedule :func:`repro.core.sparse.blocked_topk_sparsify`
implements in jnp — that is the oracle).

Grid = (V / block_v,).  Selection is k iterations of (max → record → mask),
k is small (k ≤ 64 per block in practice); everything stays in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_kernel(x_ref, idx_ref, val_ref, *, k: int, block_v: int, total: int):
    j = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                       # (block_v,)
    base = j * block_v
    pos = base + jax.lax.iota(jnp.int32, block_v)
    valid = pos < total
    mag = jnp.where(valid, jnp.abs(x), -1.0)

    def body(i, carry):
        mag_c, = carry
        am = jnp.argmax(mag_c)
        ok = mag_c[am] >= 0                      # padded/exhausted → (0, 0) pair
        idx_ref[i] = jnp.where(ok, base + am, 0).astype(jnp.int32)
        val_ref[i] = jnp.where(ok, x[am], 0.0).astype(val_ref.dtype)
        return (mag_c.at[am].set(-2.0),)

    jax.lax.fori_loop(0, k, body, (mag,))


def topk_compress_blocked(x, *, k_per_block: int, block_v: int = 1024,
                          interpret: bool = False):
    """x (V,) → (idx (nblocks*k,), vals (nblocks*k,)) — blocked top-k pairs."""
    v = x.shape[0]
    block_v = min(block_v, v)
    nblocks = pl.cdiv(v, block_v)
    kernel = functools.partial(_topk_kernel, k=k_per_block, block_v=block_v, total=v)
    idx, vals = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((block_v,), lambda j: (j,))],
        out_specs=[
            pl.BlockSpec((k_per_block,), lambda j: (j,)),
            pl.BlockSpec((k_per_block,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks * k_per_block,), jnp.int32),
            jax.ShapeDtypeStruct((nblocks * k_per_block,), x.dtype),
        ],
        interpret=interpret,
    )(x)
    return idx, vals
