"""Per-block top-k magnitude compression — the accumulator's sparse mode.

STEP §5.2 ships sparse vectors as (index, value) pairs.  For gradients the
production form is blocked top-k: each 128-lane-aligned block contributes its
``k_per_block`` largest-|x| entries, so selection is lane-parallel with no
global sort (the same schedule :func:`repro.core.sparse.blocked_topk_sparsify`
implements in jnp — that is the oracle).

Grid = (V / block_v,).  Two selection methods, identical outputs:

* ``method="argmax"`` — k iterations of (max → record → mask).  k sequential
  reductions; fine for small budgets (k ≤ 64 per block).
* ``method="bitonic"`` — one :mod:`repro.kernels.bitonic` partial sort per
  block, O(log² block_v) vector stages *independent of k*, so large budgets
  stop scaling linearly.

Both stay in VMEM; ties break toward the lower index in both (``jnp.argmax``
picks the first maximum, the bitonic comparator orders (mag desc, idx asc)),
so the pair streams are element-wise identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitonic import bitonic_topk_desc


def _topk_kernel(x_ref, idx_ref, val_ref, *, k: int, block_v: int, total: int):
    j = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                       # (block_v,)
    base = j * block_v
    pos = base + jax.lax.iota(jnp.int32, block_v)
    valid = pos < total
    mag = jnp.where(valid, jnp.abs(x), -1.0)

    def body(i, carry):
        mag_c, = carry
        am = jnp.argmax(mag_c)
        ok = mag_c[am] >= 0                      # padded/exhausted → (0, 0) pair
        idx_ref[i] = jnp.where(ok, base + am, 0).astype(jnp.int32)
        val_ref[i] = jnp.where(ok, x[am], 0.0).astype(val_ref.dtype)
        return (mag_c.at[am].set(-2.0),)

    jax.lax.fori_loop(0, k, body, (mag,))


def _topk_bitonic_kernel(x_ref, idx_ref, val_ref, *, k: int, block_v: int,
                         total: int):
    j = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                       # (block_v,)
    base = j * block_v
    pos = base + jax.lax.iota(jnp.int32, block_v)
    valid = pos < total
    mag = jnp.where(valid, jnp.abs(x), -1.0)
    top_mag, top_idx, top_val = bitonic_topk_desc(mag, pos, x, k=k)
    ok = top_mag >= 0                            # padded/exhausted → (0, 0) pair
    idx_ref[...] = jnp.where(ok, top_idx, 0).astype(jnp.int32)
    val_ref[...] = jnp.where(ok, top_val, 0.0).astype(val_ref.dtype)


_KERNELS = {"argmax": _topk_kernel, "bitonic": _topk_bitonic_kernel}

# The argmax loop pays k sequential reductions, the bitonic network a fixed
# log²-stage cost — the crossover sits around one VMEM block's worth of k.
BITONIC_MIN_K = 65


def topk_compress_blocked(x, *, k_per_block: int, block_v: int = 1024,
                          interpret: bool = False, method: str | None = None):
    """x (V,) → (idx (nblocks*k,), vals (nblocks*k,)) — blocked top-k pairs."""
    v = x.shape[0]
    block_v = min(block_v, v)
    nblocks = pl.cdiv(v, block_v)
    if method is None:
        method = "bitonic" if k_per_block >= BITONIC_MIN_K else "argmax"
    if method not in _KERNELS:
        raise ValueError(f"method must be argmax|bitonic, got {method!r}")
    kernel = functools.partial(_KERNELS[method], k=k_per_block, block_v=block_v,
                               total=v)
    idx, vals = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((block_v,), lambda j: (j,))],
        out_specs=[
            pl.BlockSpec((k_per_block,), lambda j: (j,)),
            pl.BlockSpec((k_per_block,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks * k_per_block,), jnp.int32),
            jax.ShapeDtypeStruct((nblocks * k_per_block,), x.dtype),
        ],
        interpret=interpret,
    )(x)
    return idx, vals
