"""Oracle: per-block top-k pairs (order-insensitive within a block)."""

import jax
import jax.numpy as jnp


def topk_compress_ref(x, *, k_per_block: int, block_v: int = 1024):
    v = x.shape[0]
    nblocks = (v + block_v - 1) // block_v
    pad = nblocks * block_v - v
    xp = jnp.pad(x, (0, pad)).reshape(nblocks, block_v)
    valid = (jnp.arange(nblocks * block_v).reshape(nblocks, block_v)) < v
    mag = jnp.where(valid, jnp.abs(xp), -1.0)
    _, idx = jax.lax.top_k(mag, k_per_block)                 # (nblocks, k)
    base = (jnp.arange(nblocks) * block_v)[:, None]
    flat_idx = (idx + base).reshape(-1)
    vals = jnp.take_along_axis(xp, idx, axis=1).reshape(-1)
    ok = jnp.take_along_axis(mag, idx, axis=1).reshape(-1) >= 0
    return flat_idx.astype(jnp.int32), jnp.where(ok, vals, 0.0)
