"""Sparse scatter-add: densify (index, value) pairs into an output vector.

The receive side of the accumulator's sparse mode (STEP §5.2): a node holding
chunk *i* adds incoming pairs into its shared-array chunk.  TPUs have no
efficient random scatter into VMEM, so the TPU-native schedule inverts the
loop: grid over OUTPUT blocks; each block builds a one-hot (M, block_v)
dispatch of the pairs that land in its range and reduces it with a single
(1, M) × (M, block_v) GEMM — scatter as MXU matmul (DESIGN.md: this replaces
the GPU atomic-add formulation, which has no TPU analogue).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scatter_kernel(idx_ref, val_ref, o_ref, *, block_v: int):
    j = pl.program_id(0)
    idx = idx_ref[...]                                     # (M,)
    val = val_ref[...].astype(jnp.float32)                 # (M,)
    base = j * block_v
    local = idx - base
    inside = jnp.logical_and(local >= 0, local < block_v)
    m = idx.shape[0]
    # one-hot dispatch (M, block_v), masked to this block
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, block_v), 1)
    onehot = jnp.where(
        jnp.logical_and(inside[:, None], cols == jnp.clip(local, 0, block_v - 1)[:, None]),
        1.0, 0.0)
    o_ref[...] = jax.lax.dot_general(
        val[None, :], onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[0].astype(o_ref.dtype)


def sparse_scatter_add(idx, vals, out_len: int, *, block_v: int = 1024,
                       interpret: bool = False):
    """(idx (M,), vals (M,)) → dense (out_len,) with duplicate indices summed."""
    block_v = min(block_v, out_len)
    grid = (pl.cdiv(out_len, block_v),)
    kernel = functools.partial(_scatter_kernel, block_v=block_v)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(idx.shape, lambda j: (0,)),
            pl.BlockSpec(vals.shape, lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_v,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((out_len,), vals.dtype),
        interpret=interpret,
    )(idx, vals)
