from repro.kernels.sparse_update import kernel, ops, ref
