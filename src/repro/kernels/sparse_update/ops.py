"""Jit'd wrapper for the scatter-add kernel."""

from functools import partial

import jax

from repro.kernels.sparse_update.kernel import sparse_scatter_add


@partial(jax.jit, static_argnames=("out_len", "block_v", "interpret"))
def scatter_add(idx, vals, *, out_len: int, block_v: int = 1024, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return sparse_scatter_add(idx, vals, out_len, block_v=block_v, interpret=interpret)
