"""Oracle: jnp scatter-add densify."""

import jax.numpy as jnp


def sparse_scatter_add_ref(idx, vals, out_len: int):
    return jnp.zeros((out_len,), vals.dtype).at[idx].add(vals)
