"""Jit'd wrapper: model layout (b,T,H,P) → kernel layout (BH,T,·)."""

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_bh


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A_log, B, C, *, chunk: int = 128, interpret=None):
    """Same contract as models.mamba.ssd_chunked: returns (y, final_state=None).

    x (b,T,H,P), dt (b,T,H), A_log (H,), B/C (b,T,G,N).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    a = (dt * (-jnp.exp(A_log))[None, None, :]).astype(jnp.float32)
    xbar = (x * dt[..., None].astype(x.dtype))
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)

    def to_bh(t):  # (b,T,H,·) → (bH,T,·)
        perm = (0, 2, 1) + tuple(range(3, t.ndim))
        return t.transpose(perm).reshape((b * H, T) + t.shape[3:])

    y = ssd_scan_bh(to_bh(xbar), to_bh(a), to_bh(Bh), to_bh(Ch),
                    chunk=chunk, interpret=interpret)
    y = y.reshape(b, H, T, P).transpose(0, 2, 1, 3)
    return y, None
