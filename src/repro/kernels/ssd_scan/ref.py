"""Oracle: the pure-jnp chunked SSD from models/mamba.py, plus a fully
sequential recurrence for cross-checking both."""

import jax
import jax.numpy as jnp

from repro.models.mamba import ssd_chunked  # the chunked reference


def ssd_sequential_ref(x, dt, A_log, B, C):
    """Token-by-token recurrence (the SSM definition).  Slow; small tests only.

    x (b,T,H,P), dt (b,T,H), A_log (H,), B/C (b,T,G,N) → y (b,T,H,P)
    """
    b, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    a = jnp.exp(dt * (-jnp.exp(A_log))[None, None, :])        # (b,T,H)
    xbar = x * dt[..., None]

    def step(s, inp):
        a_t, x_t, b_t, c_t = inp                               # (b,H)/(b,H,P)/(b,H,N)/(b,H,N)
        s = s * a_t[..., None, None] + jnp.einsum("bhn,bhp->bhnp", b_t, x_t)
        y = jnp.einsum("bhn,bhnp->bhp", c_t, s)
        return s, y

    s0 = jnp.zeros((b, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(
        step, s0,
        (a.transpose(1, 0, 2).astype(jnp.float32),
         xbar.transpose(1, 0, 2, 3).astype(jnp.float32),
         Bh.transpose(1, 0, 2, 3).astype(jnp.float32),
         Ch.transpose(1, 0, 2, 3).astype(jnp.float32)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
