"""Mamba2 SSD Pallas kernel: chunk GEMMs on the MXU, state carried in VMEM.

Grid = (batch×heads, n_chunks); the chunk axis is minor-most, so TPU executes
chunks sequentially per (b,h) and the (N, P) recurrent state lives in VMEM
scratch across chunk steps — the inter-chunk linear recurrence costs no HBM
round-trip.  Within a chunk everything is (Q×N)/(Q×Q)/(N×P) GEMMs.

This is the TPU adaptation of the GPU SSD scan (DESIGN.md): the GPU version
leans on warp-level scans; the TPU version restructures the recurrence so the
sequential part is one VMEM-resident state update per chunk and all O(T·Q)
work is systolic matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, s_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0].astype(jnp.float32)     # (Q, P)
    a = a_ref[0].astype(jnp.float32)     # (Q,)
    bm = b_ref[0].astype(jnp.float32)    # (Q, N)
    cm = c_ref[0].astype(jnp.float32)    # (Q, N)

    cum = jnp.cumsum(a)                  # (Q,) log-decay prefix, ≤ 0
    # off-chunk: contribution of the carried state
    y_off = jax.lax.dot_general(cm * jnp.exp(cum)[:, None], s_scr[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)      # (Q, P)
    # intra-chunk quadratic, masked decay before exp (upper triangle overflows)
    li = cum[:, None]
    lj = cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(jnp.where(mask, li - lj, -jnp.inf))
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * decay
    y_diag = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: S ← S·exp(Σa) + Σ_j exp(Σa − cum_j)·B_j ⊗ x_j
    total = jnp.exp(cum[chunk - 1])
    sdecay = jnp.exp(cum[chunk - 1] - cum)                   # (Q,)
    s_new = s_scr[...] * total + jax.lax.dot_general(
        bm * sdecay[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (N, P)
    s_scr[...] = s_new


def ssd_scan_bh(x, a, bm, cm, *, chunk: int, interpret: bool = False):
    """x (BH, T, P), a (BH, T), bm/cm (BH, T, N) → y (BH, T, P).  T % chunk == 0."""
    BH, T, P = x.shape
    N = bm.shape[-1]
    assert T % chunk == 0, f"T={T} must divide chunk={chunk}"
    nc = T // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk), lambda bh, c: (bh, c)),
            pl.BlockSpec((1, chunk, N), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, c: (bh, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda bh, c: (bh, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, a, bm, cm)
