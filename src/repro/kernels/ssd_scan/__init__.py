from repro.kernels.ssd_scan import kernel, ops, ref
