"""Jit'd wrapper for the blocked accumulator kernel."""

from functools import partial

import jax

from repro.kernels.accumulate.kernel import accumulate_blocked


@partial(jax.jit, static_argnames=("block_v", "interpret"))
def accumulate(x, *, block_v: int = 1024, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return accumulate_blocked(x, block_v=block_v, interpret=interpret)
