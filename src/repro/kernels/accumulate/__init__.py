from repro.kernels.accumulate import kernel, ops, ref
