from repro.kernels.accumulate import fused_scatter, kernel, ops, ref
