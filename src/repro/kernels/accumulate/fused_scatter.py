"""Fused sparsify→scatter-add — the accumulator's SPARSE reduce in one launch.

The unfused host path materialises, per round, N pair arrays (one
``topk_compress`` launch per thread) plus a dense scatter-add over their
concatenation.  But the blocked top-k selection is *block-local*: whether an
entry of block ``j`` survives depends only on block ``j``'s magnitudes.  So
selection and application fuse — grid over V-blocks, and for each block:

1. per-row (mag desc, idx asc) bitonic partial sort → the ``per_block``-th
   entry is each row's selection threshold,
2. mask each row to its selected entries (ties broken toward the lower
   index, matching ``topk_compress``'s pair stream exactly),
3. left-fold the N masked rows in fp32 — the same association order as
   scatter-adding the threads' pairs in thread order, so the fused result is
   bit-exact with the compress→densify→add path.

No (index, value) pairs or dense per-thread intermediates ever hit HBM; the
wire-accounting figures are unchanged because the *logical* pair count of a
budget-k compression is static (:func:`repro.core.sparse.pair_capacity`)
whether or not the pairs are materialised.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitonic import bitonic_sort_desc


def _fused_scatter_kernel(x_ref, o_ref, *, per_block: int, block_eff: int,
                          total: int):
    j = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                       # (N, block_eff)
    base = j * block_eff
    pos = base + jax.lax.iota(jnp.int32, block_eff)
    valid = pos < total
    mag = jnp.where(valid[None, :], jnp.abs(x), -1.0)
    if per_block < block_eff:
        idx = jnp.broadcast_to(pos[None, :], mag.shape)
        sorted_mag, sorted_idx = bitonic_sort_desc(mag, idx)
        thr_mag = sorted_mag[:, per_block - 1][:, None]      # (N, 1)
        thr_idx = sorted_idx[:, per_block - 1][:, None]
        # Selected ⇔ ranks at or above the threshold entry in (mag desc,
        # idx asc) order — exactly per_block entries per row.
        sel = (mag > thr_mag) | ((mag == thr_mag) & (idx <= thr_idx))
    else:
        sel = valid[None, :]                                 # quota ≥ block: all
    contrib = jnp.where(sel & valid[None, :], x, 0.0)
    # Left-fold, not jnp.sum: matches the scatter-add's per-index association
    # order (thread 0 first) for bit-exact parity with the unfused path.
    acc = contrib[0]
    for t in range(1, contrib.shape[0]):
        acc = acc + contrib[t]
    o_ref[...] = acc.astype(o_ref.dtype)


def fused_topk_scatter_blocked(x, *, per_block: int, block_eff: int,
                               interpret: bool = False):
    """x (N, V) → (V,): sum of each row's blocked top-``per_block`` entries."""
    n, v = x.shape
    block_eff = min(block_eff, v)
    grid = (pl.cdiv(v, block_eff),)
    kernel = functools.partial(_fused_scatter_kernel, per_block=per_block,
                               block_eff=block_eff, total=v)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, block_eff), lambda j: (0, j))],
        out_specs=pl.BlockSpec((block_eff,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((v,), x.dtype),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("per_block", "block_eff", "interpret"))
def fused_topk_scatter(x, *, per_block: int, block_eff: int, interpret=None):
    """Jit'd entry point: compiled Pallas on TPU, interpret mode elsewhere."""
    if x.ndim != 2:
        raise ValueError(f"fused_topk_scatter wants (N, V), got shape {x.shape}")
    if per_block < 1:
        raise ValueError(f"per_block must be >= 1, got {per_block}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return fused_topk_scatter_blocked(x, per_block=per_block,
                                      block_eff=block_eff, interpret=interpret)
