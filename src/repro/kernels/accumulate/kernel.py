"""Blocked N-vector accumulation kernel — the DAddAccumulator's local combine.

STEP §5.2: a node receiving its chunk from N threads reduces the N
sub-vectors in local memory.  On TPU the chunk lives in HBM as an (N, V)
block; this kernel streams 128-lane-aligned (N, block_v) tiles through VMEM
and reduces in fp32 — one pass, fully bandwidth-bound, which is the roofline
for a reduction.  Grid = (V / block_v,).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _accum_kernel(x_ref, o_ref):
    o_ref[...] = jnp.sum(x_ref[...].astype(jnp.float32), axis=0).astype(o_ref.dtype)


def accumulate_blocked(x, *, block_v: int = 1024, interpret: bool = False):
    """x (N, V) → (V,): column sum, tiled over V."""
    n, v = x.shape
    block_v = min(block_v, v)
    grid = (pl.cdiv(v, block_v),)
    return pl.pallas_call(
        _accum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, block_v), lambda j: (0, j))],
        out_specs=pl.BlockSpec((block_v,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((v,), x.dtype),
        interpret=interpret,
    )(x)
