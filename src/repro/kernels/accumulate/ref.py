"""Oracle for the blocked accumulator."""

import jax.numpy as jnp


def accumulate_ref(x):
    """x (N, V) → (V,)."""
    return jnp.sum(x.astype(jnp.float32), axis=0).astype(x.dtype)
