"""Pallas TPU kernels for the compute hot-spots (each: kernel.py + ops.py + ref.py).

flash_attention — online-softmax attention, VMEM accumulator over KV tiles
accumulate      — the DAddAccumulator's blocked local combine (STEP §5.2)
topk_compress   — blocked top-k pairs (accumulator sparse mode)
sparse_update   — scatter-add of pairs via one-hot MXU GEMM (receive side)
kmeans_assign   — nearest-center assignment via distance GEMM (paper §6.5)
ssd_scan        — Mamba2 SSD: chunk GEMMs + VMEM-carried recurrent state

All validated on CPU with interpret=True against the ref.py oracles; compiled
(Mosaic) lowering engages on a real TPU backend.
"""
