"""Jit'd wrapper adapting model layout (B,T,KH,G,d) to the kernel layout."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "q_offset", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128, interpret=None):
    """q (B,T,KH,G,d); k (B,S,KH,d); v (B,S,KH,dv) → (B,T,KH,G,dv).

    GQA is handled by fusing (KH, G) into the kernel's batch×heads axis and
    broadcasting K/V over G (zero-copy along the new axis).
    """
    if interpret is None:
        interpret = _default_interpret()
    B, T, KH, G, d = q.shape
    S = k.shape[1]
    dv = v.shape[-1]
    qb = q.transpose(0, 2, 3, 1, 4).reshape(B * KH * G, T, d)
    kb = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None], (B, KH, G, S, d)).reshape(B * KH * G, S, d)
    vb = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None], (B, KH, G, S, dv)).reshape(B * KH * G, S, dv)
    out = flash_attention_bhsd(qb, kb, vb, causal=causal, q_offset=q_offset,
                               block_q=block_q, block_k=block_k, interpret=interpret)
    return out.reshape(B, KH, G, T, dv).transpose(0, 3, 1, 2, 4)
