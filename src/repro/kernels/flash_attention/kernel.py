"""Flash attention Pallas TPU kernel: online-softmax over KV tiles in VMEM.

Grid = (batch×heads, q_blocks, kv_blocks); the kv axis is the minor-most grid
dimension, which TPU executes sequentially per (bh, iq) — the running max /
denominator / accumulator therefore live in VMEM scratch across kv steps and
q/k/v tiles stream HBM→VMEM exactly once.  MXU work is the two tile GEMMs
(q·kᵀ and p·v); tile shapes should be multiples of (8, 128) for bf16.

Adaptation note (DESIGN.md): this is the standard TPU flash schedule — the
VMEM-resident accumulator replaces the GPU kernel's shared-memory tiles.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  seq_q: int, seq_k: int, q_offset: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)                    # (bk, dv)
    # zero the OOB tail of the last KV tile: p is 0 there but 0·garbage = NaN
    kvalid = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_k, 1), 0) < seq_k
    v = jnp.where(kvalid, v, 0.0)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (bq, bk)

    qpos = q_offset + iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_k
    if causal:
        mask = jnp.logical_and(mask, qpos >= kpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, q_offset: int = 0,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False):
    """q (BH, T, d), k (BH, S, d), v (BH, S, dv) → (BH, T, dv)."""
    BH, T, d = q.shape
    S = k.shape[1]
    dv = v.shape[-1]
    scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, T)
    block_k = min(block_k, S)
    nq = pl.cdiv(T, block_q)
    nk = pl.cdiv(S, block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, seq_q=T, seq_k=S, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, block_k, dv), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
