"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_bhsd_ref(q, k, v, *, causal: bool = True, q_offset: int = 0):
    """q (BH, T, d), k (BH, S, d), v (BH, S, dv) → (BH, T, dv)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        T, S = q.shape[1], k.shape[1]
        tpos = q_offset + jnp.arange(T)
        mask = tpos[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsd->btd", p, v.astype(jnp.float32)).astype(q.dtype)
