"""Bitonic sorting network over (magnitude, index) keys + carried payloads.

The top-k selection kernels need a *partial sort*: the ``per_block`` largest
|x| entries of each lane block, ties broken toward the lower index (so the
result is element-wise identical to the historical argmax→mask loop, whose
``jnp.argmax`` picks the first maximum).  A bitonic network gives that in
``O(log² L)`` compare-exchange stages of full-width vector ops — independent
of k — where the argmax loop pays k sequential reductions.

The network is expressed as reshapes + ``jnp.where`` only, so the same
function runs inside a Pallas kernel (compiled or interpret mode) and as a
plain jnp reference.  Stage structure (``L`` padded to a power of two)::

    for k in 2, 4, ..., L:          # bitonic run length being merged
        for j in k/2, k/4, ..., 1:  # compare-exchange distance
            partner pairs are (i, i+j) for i with (i // j) even

Element ``i = q·2j + h·j + r`` maps to position ``[..., q, h, r]`` of a
``(..., L/2j, 2, j)`` view; since ``h·j + r < 2j ≤ k`` the region direction
bit ``i & k`` depends only on ``q``, so it is a trace-time constant mask.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _compare_exchange(mag, idx, payloads, k: int, j: int):
    """One stage: order partner pairs at distance j within runs of length k."""
    lead = mag.shape[:-1]
    length = mag.shape[-1]
    pairs = length // (2 * j)
    # Direction of each run: descending where region bit k is clear (the
    # overall sort is descending, so the usual asc/desc roles are flipped).
    # Built from an iota, not a host constant — Pallas kernels cannot capture
    # device constants.
    q = jax.lax.broadcasted_iota(jnp.int32, (pairs, 1), 0)
    desc = (q * (2 * j)) & k == 0                                     # (pairs, 1)

    def halves(t):
        s = t.reshape(lead + (pairs, 2, j))
        return s[..., 0, :], s[..., 1, :]

    a_mag, b_mag = halves(mag)
    a_idx, b_idx = halves(idx)
    # Order: mag descending, ties by idx ascending.  "a ranks below b":
    a_less = (a_mag < b_mag) | ((a_mag == b_mag) & (a_idx > b_idx))
    swap = jnp.where(desc, a_less, ~a_less)

    def merge(a, b):
        na = jnp.where(swap, b, a)
        nb = jnp.where(swap, a, b)
        return jnp.stack([na, nb], axis=-2).reshape(lead + (length,))

    new_payloads = tuple(merge(*halves(p)) for p in payloads)
    return merge(a_mag, b_mag), merge(a_idx, b_idx), new_payloads


def bitonic_sort_desc(mag, idx, *payloads):
    """Sort along the last axis by (mag descending, idx ascending).

    ``idx`` must be unique along the last axis (positions), making the order
    a strict total order, so the network's output is deterministic and
    matches first-occurrence argmax selection on magnitude ties.  Extra
    ``payloads`` arrays (same shape) are carried through the permutation.
    Non-power-of-two lengths are padded with ``-inf`` magnitudes (sort last)
    and sliced back off.  Returns ``(mag, idx, *payloads)`` sorted.
    """
    length = mag.shape[-1]
    padded = 1 << max(0, length - 1).bit_length()
    if padded != length:
        pad = padded - length
        widths = [(0, 0)] * (mag.ndim - 1) + [(0, pad)]
        mag = jnp.pad(mag, widths, constant_values=-jnp.inf)
        # Unique pad indices keep the comparator a strict total order.
        pad_idx = (length + jax.lax.iota(jnp.int32, pad)).astype(idx.dtype)
        idx = jnp.concatenate(
            [idx, jnp.broadcast_to(pad_idx, idx.shape[:-1] + (pad,))], axis=-1)
        payloads = tuple(jnp.pad(p, widths) for p in payloads)

    k = 2
    while k <= padded:
        j = k // 2
        while j >= 1:
            mag, idx, payloads = _compare_exchange(mag, idx, payloads, k, j)
            j //= 2
        k *= 2

    if padded != length:
        mag, idx = mag[..., :length], idx[..., :length]
        payloads = tuple(p[..., :length] for p in payloads)
    return (mag, idx, *payloads)


def bitonic_topk_desc(mag, idx, *payloads, k: int):
    """First ``k`` entries of :func:`bitonic_sort_desc` — a partial sort.

    (The network still sorts the full axis; the slice just names the
    contract call sites rely on.)
    """
    out = bitonic_sort_desc(mag, idx, *payloads)
    return tuple(t[..., :k] for t in out)


__all__ = ["bitonic_sort_desc", "bitonic_topk_desc"]
