"""STEP-JAX: a distributed multi-threading framework for data analytics on TPU pods.

Reproduction + TPU-native adaptation of:
  "STEP: A Distributed Multi-threading Framework Towards Efficient Data Analytics"
  (Mei, Shen, Zhu, Huang - SJTU, 2018).

Public surface:
  repro.core       - step.Session (the Table-1 facade), DSM GlobalStore,
                     DAddAccumulator, sync, threads, cache
  repro.optim      - optimizers, ZeRO-1 (accumulator-sharded), compression
  repro.models     - the assigned LM architectures
  repro.analytics  - the paper's four applications (logreg/kmeans/nmf/pagerank)
  repro.launch     - mesh / dryrun / roofline / train / serve drivers
"""

__version__ = "1.0.0"
