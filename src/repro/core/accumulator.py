"""DAddAccumulator — STEP §4.4/§5.2, in both its host form and its SPMD form.

The paper's accumulator: N threads each split a local V-vector into M chunks;
chunk *i* goes to node *i*, which reduces its chunk locally and writes it into
the output shared array.  Total wire traffic drops from ``(2N+1)·V`` (send all
vectors to one node, reduce, send the result back) to ``(N+1)·V``.

On a TPU mesh that schedule *is* reduce-scatter: ``psum_scatter`` leaves shard
*i* of the sum on device *i* (each device "owns" its chunk, exactly the
watcher-node role), and an optional ``all_gather`` republishes the full vector.
The naive baseline corresponds to an ``all_gather`` of whole vectors followed
by a local reduction (what a driver-aggregation system does).

Two layers:

* **SPMD functions** (``accumulate`` / ``accumulate_scatter``) — used inside
  ``shard_map`` by the production training path, the analytics apps and the
  ZeRO-1 optimizer.  Modes: ``gather_all`` (strawman), ``reduce_scatter``
  (paper), ``hierarchical`` (paper §4.5 node-local-combine → cross-pod),
  ``sparse`` (top-k pairs), ``auto`` (paper's rule, lossless by construction).
* **DAddAccumulator** — the host-side class with the paper's exact API
  (``Accumulate(local, len)`` blocking until all N threads contribute), used by
  the Pthreads-style thread pool.  It *accounts traffic per mode* so the
  ``(2N+1)·V → (N+1)·V`` claim is assertable in tests.
"""

from __future__ import annotations

import threading
from enum import Enum
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.addressing import align_up
from repro.core.compat import axis_size as compat_axis_size
from repro.core.sparse import blocked_topk_sparsify, densify, sparse_beneficial


class AccumMode(str, Enum):
    GATHER_ALL = "gather_all"          # (2N+1)V-class strawman
    REDUCE_SCATTER = "reduce_scatter"  # (N+1)V-class, the paper's accumulator
    HIERARCHICAL = "hierarchical"      # §4.5: combine per node, then across
    SPARSE = "sparse"                  # (index,value) pairs
    AUTO = "auto"                      # paper's auto rule


# ---------------------------------------------------------------------------
# SPMD layer (inside shard_map: `axis` names are mesh axes)
# ---------------------------------------------------------------------------


def _axis_size(axis) -> int:
    return compat_axis_size(axis)


def _pad_to(x: jax.Array, multiple: int) -> jax.Array:
    n = x.shape[0]
    target = align_up(n, multiple)
    return jnp.pad(x, [(0, target - n)] + [(0, 0)] * (x.ndim - 1))


def accumulate_scatter(x: jax.Array, axis) -> jax.Array:
    """Reduce-scatter: return this device's owned chunk of the global sum.

    This is the paper's "node i receives chunk i and reduces locally" —
    the primitive behind ZeRO-1 (the owner then updates its optimizer shard).
    """
    n_dev = _axis_size(axis)
    xp = _pad_to(x, n_dev)
    return jax.lax.psum_scatter(xp, axis, scatter_dimension=0, tiled=True)


def _gather_chunks(chunk: jax.Array, axis, orig_len: int) -> jax.Array:
    full = jax.lax.all_gather(chunk, axis, axis=0, tiled=True)
    return full[:orig_len] if full.shape[0] != orig_len else full


def accumulate(
    x: jax.Array,
    axis,
    mode: AccumMode | str = AccumMode.REDUCE_SCATTER,
    *,
    inner_axis=None,
    outer_axis=None,
    k: Optional[int] = None,
) -> jax.Array:
    """Sum `x` over mesh axis(es); every device receives the full result.

    Must be called inside ``shard_map`` (or under a mesh context with manual
    axes).  `x` is the per-device local vector (leading dim = vector length).
    """
    mode = AccumMode(mode)
    n = x.shape[0]

    if mode == AccumMode.GATHER_ALL:
        # strawman: everyone receives every vector, reduces locally.
        allv = jax.lax.all_gather(x, axis, axis=0)          # (N, V)
        return jnp.sum(allv, axis=0)

    if mode == AccumMode.REDUCE_SCATTER:
        chunk = accumulate_scatter(x, axis)
        return _gather_chunks(chunk, axis, n)

    if mode == AccumMode.HIERARCHICAL:
        # paper §4.5: one combine inside the node (pod), then across nodes.
        inner = inner_axis if inner_axis is not None else axis
        outer = outer_axis
        chunk = accumulate_scatter(x, inner)                 # intra-pod RS
        if outer is not None:
            chunk = jax.lax.psum(chunk, outer)               # cross-pod on 1/N of data
        return _gather_chunks(chunk, inner, n)               # intra-pod AG

    if mode == AccumMode.SPARSE:
        if k is None:
            raise ValueError("sparse mode needs a top-k budget k")
        idx, vals = blocked_topk_sparsify(x, k)
        all_idx = jax.lax.all_gather(idx, axis, axis=0)      # (N, k) ints
        all_val = jax.lax.all_gather(vals, axis, axis=0)     # (N, k)
        return densify(all_idx, all_val, n)

    if mode == AccumMode.AUTO:
        if k is None:
            raise ValueError("auto mode needs a top-k budget k")
        # the paper's rule must agree across devices: decide on the *global*
        # benefit (all_gather of one scalar nnz flag).
        my_ok = sparse_beneficial(x, k)
        all_ok = jax.lax.all_gather(my_ok, axis)
        use_sparse = jnp.all(all_ok)
        dense_fn = lambda v: accumulate(v, axis, AccumMode.REDUCE_SCATTER)
        sparse_fn = lambda v: accumulate(v, axis, AccumMode.SPARSE, k=k)
        return jax.lax.cond(use_sparse, sparse_fn, dense_fn, x)

    raise ValueError(f"unknown accumulator mode: {mode}")


def accumulate_tree(tree, axis, mode=AccumMode.REDUCE_SCATTER, **kw):
    """Accumulate every leaf of a pytree (each flattened to 1-D and restored)."""

    def one(leaf):
        flat = leaf.reshape(-1)
        out = accumulate(flat, axis, mode, **kw)
        return out.reshape(leaf.shape)

    return jax.tree.map(one, tree)


# ---------------------------------------------------------------------------
# Host layer: the paper's class API with per-mode traffic accounting
# ---------------------------------------------------------------------------


class DAddAccumulator:
    """Paper-faithful blocking accumulator for the host thread pool.

    ``Accumulate(tid, local_vec)`` blocks until all N threads have contributed,
    then the sum is written into the output shared array in the
    :class:`~repro.core.dsm.GlobalStore`.  Traffic is accounted per the paper's
    cost model so unit tests can assert (N+1)·V vs (2N+1)·V.
    """

    def __init__(self, store, output_name: str, n_threads: int, n_nodes: int,
                 mode: AccumMode | str = AccumMode.REDUCE_SCATTER):
        self.store = store
        self.output_name = output_name
        self.n = n_threads
        self.m = max(1, n_nodes)
        self.mode = AccumMode(mode)
        self._lock = threading.Lock()
        self._partial = None
        self._count = 0
        self._barrier = threading.Barrier(n_threads)
        self.bytes_transferred = 0  # wire-traffic in vector *elements*
        self.rounds = 0

    def _account(self, vec_len: int, nnz_by_thread: Sequence[int]):
        if self.mode == AccumMode.GATHER_ALL:
            # every thread ships V to the root; root ships V back to each: (2N+1)V
            self.bytes_transferred += (2 * self.n + 1) * vec_len
        elif self.mode in (AccumMode.REDUCE_SCATTER, AccumMode.HIERARCHICAL):
            # each thread ships its V once (chunked to owners); owners write V total
            self.bytes_transferred += (self.n + 1) * vec_len
        elif self.mode == AccumMode.SPARSE:
            self.bytes_transferred += sum(2 * z for z in nnz_by_thread) + vec_len
        else:  # AUTO: cheaper of dense / sparse (paper's rule)
            dense = (self.n + 1) * vec_len
            sparse = sum(2 * z for z in nnz_by_thread) + vec_len
            self.bytes_transferred += min(dense, sparse)

    def accumulate(self, local_vec) -> None:
        """Paper's ``Accumulate`` — synchronization point across all N threads."""
        local_vec = jnp.asarray(local_vec)
        with self._lock:
            if self._partial is None:
                self._partial = local_vec
                self._nnzs = [int(jnp.sum(local_vec != 0))]
            else:
                self._partial = self._partial + local_vec
                self._nnzs.append(int(jnp.sum(local_vec != 0)))
            self._count += 1
            if self._count == self.n:
                self.store.set(self.output_name, self._partial)
                self._account(int(local_vec.size), self._nnzs)
                self.rounds += 1
                self._partial = None
                self._count = 0
        self._barrier.wait()

    # paper-cased alias
    Accumulate = accumulate
