"""DAddAccumulator — STEP §4.4/§5.2, in both its host form and its SPMD form.

The paper's accumulator: N threads each split a local V-vector into M chunks;
chunk *i* goes to node *i*, which reduces its chunk locally and writes it into
the output shared array.  Total wire traffic drops from ``(2N+1)·V`` (send all
vectors to one node, reduce, send the result back) to ``(N+1)·V``.

On a TPU mesh that schedule *is* reduce-scatter: ``psum_scatter`` leaves shard
*i* of the sum on device *i* (each device "owns" its chunk, exactly the
watcher-node role), and an optional ``all_gather`` republishes the full vector.
The naive baseline corresponds to an ``all_gather`` of whole vectors followed
by a local reduction (what a driver-aggregation system does).

Two layers:

* **SPMD functions** (``accumulate`` / ``accumulate_scatter``) — used inside
  ``shard_map`` by the production training path, the analytics apps and the
  ZeRO-1 optimizer.  Modes: ``gather_all`` (strawman), ``reduce_scatter``
  (paper), ``hierarchical`` (paper §4.5 node-local-combine → cross-pod),
  ``sparse`` (top-k pairs), ``auto`` (paper's rule, lossless by construction).
* **DAddAccumulator** — the host-side class with the paper's exact API
  (``Accumulate(local, len)`` blocking until all N threads contribute), used by
  the Pthreads-style thread pool.  It *accounts traffic per mode* so the
  ``(2N+1)·V → (N+1)·V`` claim is assertable in tests.

Sparse parity contract (both layers): a contribution is compressed with the
*same* :func:`~repro.core.sparse.blocked_topk_sparsify` dispatch (Pallas
``topk_compress`` kernel, interpret mode off-TPU), the reduction sums the
scattered pairs, and wire traffic is ``2 · pair_capacity(V, k)`` elements per
contribution plus the ``V``-element republish — derived from the actual pair
arrays, never from a dense sum with sparse accounting.  Compression is lossy
iff some block's nnz exceeds its per-block quota; ``auto`` only selects pairs
when they are lossless AND cheaper, so it never changes results.
"""

from __future__ import annotations

import threading
import time
from enum import Enum
from typing import Optional

import jax
import jax.numpy as jnp

from repro.check import checker as stepcheck
from repro.core import telemetry
from repro.core.addressing import align_up
from repro.core.compat import axis_size as compat_axis_size
from repro.core.sparse import (
    DEFAULT_BLOCK,
    blocked_topk_accumulate,
    blocked_topk_sparsify,
    default_auto_k,
    densify,
    pair_capacity,
    sparse_beneficial,
    sparse_beneficial_batch,
)


class AccumMode(str, Enum):
    GATHER_ALL = "gather_all"          # (2N+1)V-class strawman
    REDUCE_SCATTER = "reduce_scatter"  # (N+1)V-class, the paper's accumulator
    HIERARCHICAL = "hierarchical"      # §4.5: combine per node, then across
    SPARSE = "sparse"                  # (index,value) pairs
    AUTO = "auto"                      # paper's auto rule


# ---------------------------------------------------------------------------
# SPMD layer (inside shard_map: `axis` names are mesh axes)
# ---------------------------------------------------------------------------


def _axis_size(axis) -> int:
    return compat_axis_size(axis)


def _pad_to(x: jax.Array, multiple: int) -> jax.Array:
    n = x.shape[0]
    target = align_up(n, multiple)
    return jnp.pad(x, [(0, target - n)] + [(0, 0)] * (x.ndim - 1))


def accumulate_scatter(x: jax.Array, axis) -> jax.Array:
    """Reduce-scatter: return this device's owned chunk of the global sum.

    This is the paper's "node i receives chunk i and reduces locally" —
    the primitive behind ZeRO-1 (the owner then updates its optimizer shard).
    """
    n_dev = _axis_size(axis)
    xp = _pad_to(x, n_dev)
    return jax.lax.psum_scatter(xp, axis, scatter_dimension=0, tiled=True)


def _gather_chunks(chunk: jax.Array, axis, orig_len: int) -> jax.Array:
    full = jax.lax.all_gather(chunk, axis, axis=0, tiled=True)
    return full[:orig_len] if full.shape[0] != orig_len else full


def accumulate(
    x: jax.Array,
    axis,
    mode: AccumMode | str = AccumMode.REDUCE_SCATTER,
    *,
    inner_axis=None,
    outer_axis=None,
    k: Optional[int] = None,
    with_branch: bool = False,
) -> jax.Array:
    """Sum `x` over mesh axis(es); every device receives the full result.

    Must be called inside ``shard_map`` (or under a mesh context with manual
    axes).  `x` is the per-device local vector (leading dim = vector length).

    ``with_branch=True`` (``auto`` mode only) additionally returns the
    globally-agreed branch decision as a traced bool — the hook the SPMD
    session uses to carry a device-side "sparse branch taken" counter out of
    the program, so wire accounting can settle to the branch actually taken.
    """
    mode = AccumMode(mode)
    if with_branch and mode != AccumMode.AUTO:
        raise ValueError("with_branch reports the auto rule's runtime "
                         f"decision; mode {mode.value!r} has no branch")
    n = x.shape[0]

    if mode == AccumMode.GATHER_ALL:
        # strawman: everyone receives every vector, reduces locally.
        allv = jax.lax.all_gather(x, axis, axis=0)          # (N, V)
        return jnp.sum(allv, axis=0)

    if mode == AccumMode.REDUCE_SCATTER:
        chunk = accumulate_scatter(x, axis)
        return _gather_chunks(chunk, axis, n)

    if mode == AccumMode.HIERARCHICAL:
        # paper §4.5: one combine inside the node (pod), then across nodes.
        inner = inner_axis if inner_axis is not None else axis
        outer = outer_axis
        chunk = accumulate_scatter(x, inner)                 # intra-pod RS
        if outer is not None:
            chunk = jax.lax.psum(chunk, outer)               # cross-pod on 1/N of data
        return _gather_chunks(chunk, inner, n)               # intra-pod AG

    if mode == AccumMode.SPARSE:
        if k is None:
            raise ValueError("sparse mode needs a top-k budget k")
        pairs = blocked_topk_sparsify(x, k)     # Pallas kernel (interpret off-TPU)
        all_idx = jax.lax.all_gather(pairs.idx, axis, axis=0)   # (N, P) ints
        all_val = jax.lax.all_gather(pairs.vals, axis, axis=0)  # (N, P)
        return densify(all_idx, all_val, n)

    if mode == AccumMode.AUTO:
        if k is None:
            k = default_auto_k(n)
        # the paper's rule must agree across devices: decide on the *global*
        # benefit (all_gather of one scalar nnz flag).
        my_ok = sparse_beneficial(x, k)
        all_ok = jax.lax.all_gather(my_ok, axis)
        use_sparse = jnp.all(all_ok)
        dense_fn = lambda v: accumulate(v, axis, AccumMode.REDUCE_SCATTER)
        sparse_fn = lambda v: accumulate(v, axis, AccumMode.SPARSE, k=k)
        total = jax.lax.cond(use_sparse, sparse_fn, dense_fn, x)
        return (total, use_sparse) if with_branch else total

    raise ValueError(f"unknown accumulator mode: {mode}")


def accumulate_tree(tree, axis, mode=AccumMode.REDUCE_SCATTER, **kw):
    """Accumulate every leaf of a pytree (each flattened to 1-D and restored)."""

    def one(leaf):
        flat = leaf.reshape(-1)
        out = accumulate(flat, axis, mode, **kw)
        return out.reshape(leaf.shape)

    return jax.tree.map(one, tree)


# ---------------------------------------------------------------------------
# Host layer: the paper's class API with per-mode traffic accounting
# ---------------------------------------------------------------------------


class DAddAccumulator:
    """Paper-faithful blocking accumulator for the host thread pool.

    ``Accumulate(tid, local_vec)`` blocks until all N threads have contributed,
    then the sum is written into the output shared array in the
    :class:`~repro.core.dsm.GlobalStore`.  Traffic is accounted per the paper's
    cost model so unit tests can assert (N+1)·V vs (2N+1)·V.

    ``mode=SPARSE`` needs a top-k budget ``k``: each thread's contribution is
    compressed to :class:`~repro.core.sparse.SparsePairs` (the same Pallas
    ``topk_compress`` dispatch the SPMD collective uses), the round sums the
    scattered pairs, and traffic is ``Σ_threads 2·pairs + V`` from the actual
    pair-array lengths.  ``mode=AUTO`` buffers the round, applies the paper's
    benefit rule to every contribution (lossless AND cheaper), and takes the
    pairs path only when all threads agree — mirroring the SPMD collective's
    globally-agreed branch.  All contributions in a round must have the same
    shape; a ragged contribution raises ``ValueError``, aborts the barrier
    (parked peers get ``BrokenBarrierError``) and poisons the accumulator —
    subsequent rounds raise ``RuntimeError`` instead of publishing.
    """

    def __init__(self, store, output_name: str, n_threads: int, n_nodes: int,
                 mode: AccumMode | str = AccumMode.REDUCE_SCATTER, *,
                 k: Optional[int] = None, block: int = DEFAULT_BLOCK,
                 fused: bool = True, tracer=None, checker=None):
        self.store = store
        self.tracer = tracer if tracer is not None else telemetry.NULL_TRACER
        self.checker = checker if checker is not None else stepcheck.NULL_CHECKER
        self.output_name = output_name
        self.n = n_threads
        self.m = max(1, n_nodes)
        self.mode = AccumMode(mode)
        if self.mode == AccumMode.SPARSE and k is None:
            raise ValueError("sparse mode needs a top-k budget k")
        self.k = k                  # AUTO with k=None defaults per round (~V/4)
        self.block = block
        # fused=True applies SPARSE/AUTO pairs rounds as one sparsify→
        # scatter-add kernel launch (bit-exact, same wire accounting);
        # fused=False keeps the historical compress→densify→add path
        self.fused = fused
        self._owner = None          # memoised (ring_version, shard) of output
        self._lock = threading.Lock()
        self._vecs: list = []           # buffered contributions (SPARSE/AUTO)
        self._partial = None            # running sum (fixed dense modes)
        self._count = 0
        self._round_len: Optional[int] = None
        self._round_shape: Optional[tuple] = None
        self._barrier = threading.Barrier(n_threads)
        self._broken = False        # poisoned by an aborted round
        self.bytes_transferred = 0  # wire-traffic in vector *elements*
        self.rounds = 0
        self.last_mode: Optional[AccumMode] = None  # branch taken last round
        self.last_pair_counts: list = []  # per-thread pairs shipped last round

    # modes that can never take the pairs branch keep a running sum — O(V)
    # peak memory per round; SPARSE/AUTO must buffer the N contributions
    # (compression/benefit is per contribution, decided when the round closes)
    _DENSE_MODES = (AccumMode.GATHER_ALL, AccumMode.REDUCE_SCATTER,
                    AccumMode.HIERARCHICAL)

    def _account_dense(self, vec_len: int) -> None:
        if self.mode == AccumMode.GATHER_ALL:
            # every thread ships V to the root; root ships V back to each: (2N+1)V
            self.bytes_transferred += (2 * self.n + 1) * vec_len
        else:
            # each thread ships its V once (chunked to owners); owners write V
            self.bytes_transferred += (self.n + 1) * vec_len

    def _abort_round(self) -> None:
        self._broken = True
        self._barrier.abort()

    def _reset_round(self) -> None:
        self._vecs = []
        self._partial = None
        self._count = 0
        self._round_len = None
        self._round_shape = None

    def _reduce_round(self) -> None:
        """Runs under the lock when the round's last contribution arrives."""
        trc = self.tracer
        tracing = telemetry.TRACING and trc.enabled
        t0 = time.perf_counter() if tracing else 0.0
        wire_before = self.bytes_transferred
        vec_len, shape = self._round_len, self._round_shape
        if self.mode in self._DENSE_MODES:
            total = self._partial
            self.last_pair_counts = []
            self._account_dense(vec_len)
            mode = self.mode
        else:
            k = self.k if self.k is not None else default_auto_k(vec_len)
            # compression works on flat vectors (scalars and matrices ride
            # along flattened, mirroring the SPMD ctx's rank normalisation)
            flats = [v.reshape(-1) for v in self._vecs]
            mode = self.mode
            if mode == AccumMode.AUTO:
                # pairs only when every contribution is losslessly
                # compressible AND cheaper — the same globally-agreed branch
                # as the collective.  One jitted call decides the whole round
                # (the N contributions are same-shape by the ragged check):
                # a single device sync instead of N small ones per round.
                all_ok = bool(sparse_beneficial_batch(flats, k, self.block))
                mode = AccumMode.SPARSE if all_ok else AccumMode.REDUCE_SCATTER
            if mode == AccumMode.SPARSE:
                tc = time.perf_counter() if tracing else 0.0
                if self.fused:
                    # one fused sparsify→scatter-add launch over the stacked
                    # round — no pair arrays or dense intermediates; the
                    # logical pair count is the static capacity either way
                    # (under jit num_pairs always equals pair_capacity), so
                    # wire accounting is unchanged
                    total = blocked_topk_accumulate(
                        jnp.stack(flats), k, self.block).reshape(shape)
                    self.last_pair_counts = (
                        [pair_capacity(vec_len, k, self.block)] * self.n)
                else:
                    pairs = [blocked_topk_sparsify(f, k, self.block)
                             for f in flats]
                    # one scatter-add over the concatenated pair arrays — the
                    # same "densify everything at once" the SPMD all-gather
                    # path does
                    total = densify(jnp.concatenate([p.idx for p in pairs]),
                                    jnp.concatenate([p.vals for p in pairs]),
                                    vec_len).reshape(shape)
                    self.last_pair_counts = [p.num_pairs for p in pairs]
                if tracing:
                    trc.observe("accumulate.compress",
                                (time.perf_counter() - tc) * 1e6)
                self.bytes_transferred += (
                    sum(2 * c for c in self.last_pair_counts) + vec_len)
            else:
                total = flats[0]
                for f in flats[1:]:
                    total = total + f
                total = total.reshape(shape)
                self.last_pair_counts = []
                self._account_dense(vec_len)
        self.last_mode = mode
        self._store_output(total)
        self.rounds += 1
        if tracing:
            if mode == AccumMode.SPARSE:
                path = "fused" if self.fused else "sparse"
            else:
                path = "dense"
            trc.count(f"accum.kernel_path.{path}")
            trc.count("accumulate.rounds")
            trc.count("accumulate.wire_elements",
                      self.bytes_transferred - wire_before)
            trc.add_span("accumulate-round", "accumulate.round", t0,
                         time.perf_counter(),
                         {"mode": mode.value, "vec_len": vec_len,
                          "threads": self.n,
                          "pairs": sum(self.last_pair_counts),
                          "wire_elements":
                              self.bytes_transferred - wire_before})
        self._reset_round()

    def _store_output(self, total) -> None:
        """Publish the round sum, with the output's owner shard memoised.

        The output name never changes, so its ring owner is stable between
        rebalances — pass the cached :class:`~repro.core.shards.OwnerHandle`
        to skip the blake2b + bisect on every round (refreshed lazily when
        ``add_shard``/``remove_shard`` bumps the ring version)."""
        store = self.store
        if hasattr(store, "owner_handle"):
            handle = self._owner
            if handle is None or handle.version != store.ring_version:
                handle = store.owner_handle(self.output_name)
                self._owner = handle
            store.set(self.output_name, total, owner=handle)
        else:
            store.set(self.output_name, total)

    def accumulate(self, local_vec) -> None:
        """Paper's ``Accumulate`` — synchronization point across all N threads.

        With an armed tracer, each call records one per-thread span (category
        ``accumulate-round``, name ``accumulate``, entry→barrier release) plus
        a ``barrier-wait`` span for the time parked on the round barrier; the
        round-closing thread additionally records the ``accumulate.round``
        reduce span from :meth:`_reduce_round`."""
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            # publish this thread's clock into the round edge; the collective
            # output write is recorded at the publish-time epoch after the
            # round barrier releases, so peers' post-join clocks dominate it
            token = ck.acc_begin(self)
            self._accumulate_traced(local_vec)
            ck.acc_done(self, self.output_name, token)
            return
        self._accumulate_traced(local_vec)

    def _accumulate_traced(self, local_vec) -> None:
        trc = self.tracer
        if telemetry.TRACING and trc.enabled:
            t0 = time.perf_counter()
            self._accumulate(local_vec, trc)
            trc.wait_span("accumulate-round", "accumulate", t0)
        else:
            self._accumulate(local_vec, None)

    def _accumulate(self, local_vec, trc) -> None:
        local_vec = jnp.asarray(local_vec)
        with self._lock:
            if self._broken:
                # the barrier was aborted by an earlier error; without this
                # guard a later round would publish its sum to the store and
                # THEN raise BrokenBarrierError in every thread
                raise RuntimeError(
                    "DAddAccumulator is unusable after an aborted round — "
                    "create a fresh accumulator")
            if self._count == 0:
                self._round_shape = local_vec.shape
                self._round_len = int(local_vec.size)
            elif local_vec.shape != self._round_shape:
                # release threads already parked on the barrier, drop the
                # poisoned round, then surface
                self._abort_round()
                shape = self._round_shape
                self._reset_round()
                raise ValueError(
                    f"ragged accumulate contribution: round opened with shape "
                    f"{shape}, got {local_vec.shape} — all threads must "
                    "contribute identically-shaped vectors")
            if self.mode in self._DENSE_MODES:
                self._partial = (local_vec if self._partial is None
                                 else self._partial + local_vec)
            else:
                self._vecs.append(local_vec)
            self._count += 1
            if self._count == self.n:
                try:
                    self._reduce_round()
                except BaseException:
                    # never strand the N-1 threads parked on the barrier
                    self._abort_round()
                    self._reset_round()
                    raise
        if trc is not None:
            tb = time.perf_counter()
            self._barrier.wait()
            trc.wait_span("barrier-wait", "accumulate.barrier", tb)
        else:
            self._barrier.wait()

    # paper-cased alias
    Accumulate = accumulate
