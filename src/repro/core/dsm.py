"""Distributed shared memory (DSM) — STEP §4.1/§5.1 adapted to a JAX mesh.

The paper keeps globally shared data in an in-memory key-value store; every
thread in the cluster addresses it through a 64-bit ``object_id ++ field_id``
address.  On a TPU pod the analogous substrate is a set of named, *sharded*
``jax.Array``s living across the mesh: the NamedSharding plays the role the KV
store's hash ring played, ICI collectives play the network.

Three STEP concepts are kept first-class:

* **shared variables / arrays / objects** — ``def_global`` / ``new_array`` /
  ``new_object`` mirror ``DefGlobal`` / ``NewArray`` / ``NewObj``.  Objects are
  pytrees of fields under one ``object_id``.
* **fine- vs coarse-grained DSM** (§5.1) — a *layout policy*.  ``coarse`` packs
  pytree leaves into 128-element-aligned flat *packages* (``pack_tree``), so a
  collective over the packed buffer moves few large aligned blocks; ``fine``
  leaves every leaf as its own transfer.  The paper's Fig. 3 ablation is
  reproduced structurally in ``benchmarks/bench_dsm_modes.py``.
* **host/device split** — between barriers the store owns the arrays (the KV
  store's role); inside a jitted step, state is threaded functionally and the
  store is only consulted for packing metadata.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.addressing import (
    AddressAllocator,
    FieldSlot,
    GLOBALS_OBJECT_ID,
    TPU_PACKAGE_ELEMS,
    WORD_BYTES,
    align_up,
)


@dataclass
class GlobalEntry:
    """One named piece of shared data plus its DSM directory record."""

    name: str
    slot: FieldSlot
    sharding: Optional[NamedSharding]
    value: Any  # jax.Array | ShapeDtypeStruct (abstract mode)
    epoch: int = 0  # bumped on every Set — drives cache invalidation
    # re-placement metadata: the declared spec (arrays) / per-field specs
    # (objects), so Set/Inc restore the same NamedSharding they started with
    spec: Optional[P] = None
    field_specs: Optional[Dict[str, P]] = None


class GlobalStore:
    """The DSM: a named global address space of (optionally sharded) arrays.

    ``mesh=None`` gives a single-device store (the paper's single-node
    degenerate case) used by unit tests and the analytics examples on CPU.
    """

    def __init__(self, mesh: Optional[Mesh] = None, *, granularity: str = "coarse"):
        if granularity not in ("coarse", "fine"):
            raise ValueError(f"granularity must be coarse|fine, got {granularity}")
        self.mesh = mesh
        self.granularity = granularity
        self._alloc = AddressAllocator(coarse=(granularity == "coarse"))
        self._entries: Dict[str, GlobalEntry] = {}
        # per-name monotonic generation: a name deleted at epoch e re-declares
        # at e+1, so no cache replica of the deleted era can ever validate as
        # fresh against the new entry (delete→redeclare stale-read fix)
        self._gen: Dict[str, int] = {}
        self._lock = threading.Lock()  # serialises Inc (atomic by contract)
        # stats mirroring the paper's DSM throughput discussion
        self.stats = {"get": 0, "set": 0, "inc": 0,
                      "bytes_get": 0, "bytes_set": 0, "transfers": 0}

    # -- declaration ----------------------------------------------------------

    def _sharding(self, spec: Optional[P]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec if spec is not None else P())

    def _num_words(self, shape, dtype) -> int:
        nbytes = int(np.prod(shape, dtype=np.int64)) * jnp.dtype(dtype).itemsize if shape else jnp.dtype(dtype).itemsize
        return max(1, (nbytes + WORD_BYTES - 1) // WORD_BYTES)

    def _fresh_epoch(self, name: str) -> int:
        """Starting epoch for a (re-)declared name: strictly above every epoch
        the name has ever had, so stale replicas can never validate."""
        prev = self._gen.get(name, 0)
        if name in self._entries:
            prev = max(prev, self._entries[name].epoch + 1)
        return prev

    def def_global(self, name: str, value, *, spec: Optional[P] = None) -> str:
        """``DefGlobal(NAME, TYPE)`` — declare a shared variable and set it."""
        value = jnp.asarray(value)
        epoch = self._fresh_epoch(name)
        slot = self._alloc.alloc_field(GLOBALS_OBJECT_ID, self._num_words(value.shape, value.dtype))
        self._entries[name] = GlobalEntry(name, slot, self._sharding(spec),
                                          self._place(value, spec), epoch=epoch,
                                          spec=spec)
        return name

    def new_array(self, name: str, shape, dtype=jnp.float32, *, spec: Optional[P] = None) -> str:
        """``NewArray<TYPE>(n)`` — allocate a zeroed shared array."""
        epoch = self._fresh_epoch(name)
        oid = self._alloc.new_object()
        slot = self._alloc.alloc_field(oid, self._num_words(shape, dtype))
        value = jnp.zeros(shape, dtype)
        self._entries[name] = GlobalEntry(name, slot, self._sharding(spec),
                                          self._place(value, spec), epoch=epoch,
                                          spec=spec)
        return name

    def new_object(self, name: str, fields: Dict[str, Any], *, specs: Optional[Dict[str, P]] = None) -> str:
        """``NewObj`` — a shared object: a pytree of fields under one object_id."""
        epoch = self._fresh_epoch(name)
        oid = self._alloc.new_object()
        specs = specs or {}
        placed = {}
        words = 0
        for fname, fval in fields.items():
            fval = jnp.asarray(fval)
            words += self._num_words(fval.shape, fval.dtype)
            placed[fname] = self._place(fval, specs.get(fname))
        slot = self._alloc.alloc_field(oid, words)
        self._entries[name] = GlobalEntry(name, slot, None, placed, epoch=epoch,
                                          field_specs=dict(specs))
        return name

    def delete(self, name: str) -> None:
        """``DelArray`` / ``DelObj``.  Records the retired epoch so a later
        re-declaration of the same name starts strictly past it."""
        e = self._entries.pop(name)
        self._gen[name] = max(self._gen.get(name, 0), e.epoch + 1)

    # -- access (the DSM-internal-layer Get/Set of Table 1) -------------------

    def _place(self, value, spec: Optional[P]):
        if self.mesh is None:
            return value
        return jax.device_put(value, self._sharding(spec))

    def get(self, name: str):
        e = self._entries[name]
        self.stats["get"] += 1
        self.stats["bytes_get"] += _nbytes(e.value)
        self.stats["transfers"] += self._transfer_count(e.value)
        return e.value

    def set(self, name: str, value, *, bump_epoch: bool = True) -> None:
        e = self._entries[name]
        if isinstance(e.value, dict):
            specs = e.field_specs or {}
            e.value = {k: self._place(jnp.asarray(v), specs.get(k))
                       for k, v in value.items()}
        else:
            value = jnp.asarray(value)
            if e.sharding is not None:
                value = jax.device_put(value, e.sharding)
            e.value = value
        if bump_epoch:
            e.epoch += 1
        self.stats["set"] += 1
        self.stats["bytes_set"] += _nbytes(e.value)
        self.stats["transfers"] += self._transfer_count(e.value)

    def mget(self, names) -> list:
        """``MGet`` — batched get (one logical round trip)."""
        vals = [self._entries[n].value for n in names]
        self.stats["get"] += 1
        self.stats["transfers"] += 1
        for v in vals:
            self.stats["bytes_get"] += _nbytes(v)
        return vals

    def inc(self, name: str, amount=1):
        """Atomic increment (Table 1) — skips the cache layer by contract.

        Serialised under the store lock, re-placed with the entry's declared
        spec (an incremented sharded entry keeps its NamedSharding), and
        accounted in ``stats`` like any other DSM write.
        """
        with self._lock:
            e = self._entries[name]
            e.value = self._place(jnp.asarray(e.value) + amount, e.spec)
            e.epoch += 1
            self.stats["inc"] += 1
            self.stats["bytes_set"] += _nbytes(e.value)
            self.stats["transfers"] += self._transfer_count(e.value)
            return e.value

    def epoch(self, name: str) -> int:
        return self._entries[name].epoch

    def address(self, name: str) -> int:
        return self._entries[name].slot.address

    def names(self):
        return list(self._entries)

    def _transfer_count(self, value) -> int:
        """How many physical transfers a get/set of `value` costs under the
        current granularity — the quantity Fig. 3 is about."""
        leaves = jax.tree.leaves(value)
        if self.granularity == "coarse":
            return len(leaves)  # one package-aligned bulk transfer per leaf
        # fine-grained: one word-sized KV op per word
        return int(sum(max(1, _nbytes(l) // WORD_BYTES) for l in leaves))


def _nbytes(v) -> int:
    return int(sum(l.size * jnp.dtype(l.dtype).itemsize for l in jax.tree.leaves(v)))


# ---------------------------------------------------------------------------
# Coarse-grained packing: fuse a pytree into package-aligned flat buffers.
# This is the TPU realisation of the paper's 32-word packages: collectives over
# the packed representation move one large lane-aligned block instead of one
# (latency-bound) transfer per leaf.
# ---------------------------------------------------------------------------


@dataclass
class PackSpec:
    """Metadata to unpack a fused buffer back into the original pytree."""

    treedef: Any
    shapes: list
    dtypes: list
    offsets: list  # start offset of each leaf in the packed buffer (elements)
    sizes: list    # padded size of each leaf (elements)
    total: int

    @property
    def padding_waste(self) -> int:
        return self.total - sum(int(np.prod(s, dtype=np.int64)) for s in self.shapes)


def pack_spec(tree, *, package: int = TPU_PACKAGE_ELEMS, dtype=jnp.float32) -> PackSpec:
    leaves, treedef = jax.tree.flatten(tree)
    shapes, dtypes, offsets, sizes = [], [], [], []
    off = 0
    for l in leaves:
        shapes.append(tuple(l.shape))
        dtypes.append(jnp.dtype(l.dtype))
        size = align_up(max(1, int(np.prod(l.shape, dtype=np.int64))), package)
        offsets.append(off)
        sizes.append(size)
        off += size
    return PackSpec(treedef, shapes, dtypes, offsets, sizes, off)


def pack_tree(tree, spec: PackSpec, *, dtype=jnp.float32):
    """Fuse all leaves into one package-aligned flat buffer (coarse DSM)."""
    leaves = jax.tree.leaves(tree)
    parts = []
    for l, size in zip(leaves, spec.sizes):
        flat = jnp.ravel(l).astype(dtype)
        parts.append(jnp.pad(flat, (0, size - flat.size)))
    return jnp.concatenate(parts) if parts else jnp.zeros((0,), dtype)


def unpack_tree(buf, spec: PackSpec):
    """Inverse of :func:`pack_tree`."""
    leaves = []
    for shape, dt, off, size in zip(spec.shapes, spec.dtypes, spec.offsets, spec.sizes):
        n = int(np.prod(shape, dtype=np.int64))
        leaves.append(jax.lax.dynamic_slice_in_dim(buf, off, n).astype(dt).reshape(shape))
    return jax.tree.unflatten(spec.treedef, leaves)
