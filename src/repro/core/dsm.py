"""Distributed shared memory (DSM) — STEP §4.1/§5.1 adapted to a JAX mesh.

The paper keeps globally shared data in an in-memory key-value store; every
thread in the cluster addresses it through a 64-bit ``object_id ++ field_id``
address.  On a TPU pod the analogous substrate is a set of named, *sharded*
``jax.Array``s living across the mesh: the NamedSharding plays the role the KV
store's hash ring played, ICI collectives play the network.

Three STEP concepts are kept first-class:

* **shared variables / arrays / objects** — ``def_global`` / ``new_array`` /
  ``new_object`` mirror ``DefGlobal`` / ``NewArray`` / ``NewObj``.  Objects are
  pytrees of fields under one ``object_id``.
* **fine- vs coarse-grained DSM** (§5.1) — a *layout policy*.  ``coarse`` packs
  pytree leaves into 128-element-aligned flat *packages* (``pack_tree``), so a
  collective over the packed buffer moves few large aligned blocks; ``fine``
  leaves every leaf as its own transfer.  The paper's Fig. 3 ablation is
  reproduced structurally in ``benchmarks/bench_dsm_modes.py``.
* **host/device split** — between barriers the store owns the arrays (the KV
  store's role); inside a jitted step, state is threaded functionally and the
  store is only consulted for packing metadata.

Since the ``step.shards`` subsystem landed, :class:`GlobalStore` is a thin
facade over :class:`repro.core.shards.ShardedStore`: the namespace is
partitioned over a consistent-hash ring of S shards (``shards=1`` by default,
behaviour-identical to the seed's flat store), each shard owning its entries,
epoch generations, watcher directory and its own lock.  See
:mod:`repro.core.shards` for the ring, the per-shard locking discipline and
elastic rebalancing.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.addressing import TPU_PACKAGE_ELEMS, align_up
from repro.core.shards import (  # noqa: F401  (re-exported, public surface)
    GlobalEntry,
    HashRing,
    MigrationWindow,
    OwnerHandle,
    Shard,
    ShardedStore,
    ShardMigration,
    _nbytes,
)
from repro.core.tiers import (  # noqa: F401  (re-exported, public surface)
    ColdTier,
    DiskTier,
    HostMemTier,
)


class GlobalStore(ShardedStore):
    """The DSM: a named global address space of (optionally sharded) arrays.

    A thin facade over :class:`~repro.core.shards.ShardedStore` — the Table-1
    store API (``def_global`` / ``new_array`` / ``new_object`` / ``get`` /
    ``set`` / ``mget`` / ``inc`` / ``delete``) routed through the consistent-
    hash ring.  ``shards=1`` (the default) is the paper's single-store setup;
    ``shards=S`` partitions the namespace so operations on names owned by
    different shards never contend on a common lock.
    """


# ---------------------------------------------------------------------------
# Coarse-grained packing: fuse a pytree into package-aligned flat buffers.
# This is the TPU realisation of the paper's 32-word packages: collectives over
# the packed representation move one large lane-aligned block instead of one
# (latency-bound) transfer per leaf.
# ---------------------------------------------------------------------------


@dataclass
class PackSpec:
    """Metadata to unpack a fused buffer back into the original pytree."""

    treedef: Any
    shapes: list
    dtypes: list
    offsets: list  # start offset of each leaf in the packed buffer (elements)
    sizes: list    # padded size of each leaf (elements)
    total: int

    @property
    def padding_waste(self) -> int:
        return self.total - sum(int(np.prod(s, dtype=np.int64)) for s in self.shapes)


def pack_spec(tree, *, package: int = TPU_PACKAGE_ELEMS, dtype=jnp.float32) -> PackSpec:
    leaves, treedef = jax.tree.flatten(tree)
    shapes, dtypes, offsets, sizes = [], [], [], []
    off = 0
    for l in leaves:
        shapes.append(tuple(l.shape))
        dtypes.append(jnp.dtype(l.dtype))
        size = align_up(max(1, int(np.prod(l.shape, dtype=np.int64))), package)
        offsets.append(off)
        sizes.append(size)
        off += size
    return PackSpec(treedef, shapes, dtypes, offsets, sizes, off)


def pack_tree(tree, spec: PackSpec, *, dtype=jnp.float32):
    """Fuse all leaves into one package-aligned flat buffer (coarse DSM)."""
    leaves = jax.tree.leaves(tree)
    parts = []
    for l, size in zip(leaves, spec.sizes):
        flat = jnp.ravel(l).astype(dtype)
        parts.append(jnp.pad(flat, (0, size - flat.size)))
    return jnp.concatenate(parts) if parts else jnp.zeros((0,), dtype)


def unpack_tree(buf, spec: PackSpec):
    """Inverse of :func:`pack_tree`."""
    leaves = []
    for shape, dt, off, size in zip(spec.shapes, spec.dtypes, spec.offsets, spec.sizes):
        n = int(np.prod(shape, dtype=np.int64))
        leaves.append(jax.lax.dynamic_slice_in_dim(buf, off, n).astype(dt).reshape(shape))
    return jax.tree.unflatten(spec.treedef, leaves)
