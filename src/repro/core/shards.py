"""step.shards — the partitioned KV store beneath the DSM (paper §5.1 scaled).

STEP's key idea is that "the underlying key-value store serves as distributed
shared memory".  The seed repro kept that store as one flat dict behind one
lock, which serialises every cached read/write across all nodes and names —
the exact bottleneck a partitioned store exists to remove.  This module is the
partitioned form:

* :class:`HashRing` — a consistent-hash ring (``vnodes`` virtual points per
  shard, :func:`~repro.core.addressing.ring_hash` positions) mapping every DSM
  name to its owning shard.  Ring objects are immutable; topology changes
  build a *new* ring, so readers can take a lock-free snapshot (``self._ring``)
  and validate it after locking.
* :class:`Shard` — one partition: its entries, its delete-era generations,
  its watcher directory and **its own lock**.  Reads/writes/increments/cache
  invalidations for names on different shards never touch a common lock.
* :class:`ShardedStore` — the store facade over the ring.  API-identical to
  the seed's ``GlobalStore`` (which is now a thin subclass in
  :mod:`repro.core.dsm`); with ``shards=1`` it is behaviour-identical to the
  flat store.
* **Elastic rebalancing** — ``add_shard`` / ``remove_shard`` migrate only the
  keys whose ring arc changed owner (~1/S of the namespace), moving each
  entry *with its epoch*, its delete-era generation and its directory record,
  so no stale cache replica can survive a migration and a post-migration
  redeclare still starts past every epoch the name ever had.

Keys are placed by *name* rather than by allocated block address: names are
the stable identity of shared data (addresses depend on allocation order and
change on redeclare), and placement must be derivable before allocation and
after adoption by a recovered session.  The name plays the role the block
address played in §5.1's ``watcher_node``.

Locking order is strictly ``shard → node-cache``; the rebalancer takes every
involved shard lock in sorted id order and publishes the new ring before
releasing, so in-flight operations either finish under the old topology or
retry under the new one (see ``locked_entry``).
"""

from __future__ import annotations

import bisect
import threading
import time
import weakref
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.check import checker as stepcheck
from repro.core import telemetry
from repro.core.addressing import (
    AddressAllocator,
    FieldSlot,
    GLOBALS_OBJECT_ID,
    WORD_BYTES,
    ring_hash,
)

DEFAULT_VNODES = 128


def _nbytes(v) -> int:
    return int(sum(l.size * jnp.dtype(l.dtype).itemsize for l in jax.tree.leaves(v)))


@dataclass
class GlobalEntry:
    """One named piece of shared data plus its DSM directory record."""

    name: str
    slot: FieldSlot
    sharding: Optional[NamedSharding]
    value: Any  # jax.Array | ShapeDtypeStruct (abstract mode)
    epoch: int = 0  # bumped on every Set — drives cache invalidation
    # re-placement metadata: the declared spec (arrays) / per-field specs
    # (objects), so Set/Inc restore the same NamedSharding they started with
    spec: Optional[P] = None
    field_specs: Optional[Dict[str, P]] = None


class HashRing:
    """Immutable consistent-hash ring over shard ids.

    Each shard contributes ``vnodes`` virtual points; a key is owned by the
    first point clockwise of ``ring_hash(key)``.  ``added``/``removed``
    return new rings, never mutate — the store publishes a new ring by
    swapping one reference.
    """

    __slots__ = ("ids", "vnodes", "version", "_keys", "_owners")

    def __init__(self, shard_ids, vnodes: int = DEFAULT_VNODES,
                 version: int = 0):
        ids = tuple(sorted(set(int(i) for i in shard_ids)))
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {vnodes}")
        self.ids = ids
        self.vnodes = int(vnodes)
        # Monotonic topology epoch, carried ON the ring so one atomic
        # reference read yields a consistent (arcs, version) pair — memoised
        # OwnerHandles compare against it to detect rebalances.
        self.version = int(version)
        points = sorted((ring_hash(f"shard:{sid}#vnode:{v}"), sid)
                        for sid in ids for v in range(self.vnodes))
        self._keys = [h for h, _ in points]
        self._owners = [sid for _, sid in points]

    def owner(self, key) -> int:
        """Shard id owning ``key`` (a DSM name, or any hashable address)."""
        if not self._keys:
            # an empty ring is a legal value object (removed() of the last
            # shard), but it owns nothing — without this guard the modulo
            # below raises a bare ZeroDivisionError
            raise ValueError(
                "cannot resolve an owner on an empty hash ring — all shards "
                "have been removed")
        i = bisect.bisect_right(self._keys, ring_hash(key)) % len(self._keys)
        return self._owners[i]

    def added(self, shard_id: int) -> "HashRing":
        return HashRing(self.ids + (shard_id,), self.vnodes, self.version + 1)

    def removed(self, shard_id: int) -> "HashRing":
        return HashRing(tuple(i for i in self.ids if i != shard_id),
                        self.vnodes, self.version + 1)

    def __len__(self) -> int:
        return len(self.ids)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"HashRing(ids={self.ids}, vnodes={self.vnodes}, "
                f"version={self.version})")


class OwnerHandle:
    """Memoised (ring version, shard id) owner resolution of one name.

    Hot-path store ops pay a blake2b hash + bisect per call just to find the
    owning shard; a holder that touches the same name repeatedly (a
    ``SharedRef``, an accumulator's output) can resolve once and pass the
    handle back in.  Immutable by contract: a stale handle is never repaired
    in place (a torn two-field write could route a concurrent reader to the
    wrong shard *with* a matching version) — holders compare ``version``
    against :attr:`ShardedStore.ring_version` and atomically swap in a fresh
    handle from :meth:`ShardedStore.owner_handle`.  A stale handle passed to
    a store op is simply ignored (the op re-hashes), so lazy refresh is safe.
    """

    __slots__ = ("version", "shard")

    def __init__(self, version: int, shard: int):
        self.version = int(version)
        self.shard = int(shard)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"OwnerHandle(version={self.version}, shard={self.shard})"


def _fresh_stats() -> Dict[str, int]:
    return {"get": 0, "set": 0, "inc": 0, "bytes_get": 0, "bytes_set": 0,
            "transfers": 0, "migrated_in": 0, "migrated_out": 0}


class Shard:
    """One partition of the namespace: entries + generations + directory,
    guarded by this shard's own lock (an RLock: the cache layer composes
    store ops while already holding it)."""

    __slots__ = ("id", "lock", "entries", "gen", "directory", "stats")

    def __init__(self, shard_id: int):
        self.id = int(shard_id)
        self.lock = threading.RLock()
        self.entries: Dict[str, GlobalEntry] = {}
        # per-name monotonic generation: a name deleted at epoch e re-declares
        # at e+1, so no cache replica of the deleted era can ever validate as
        # fresh against the new entry (delete→redeclare stale-read fix)
        self.gen: Dict[str, int] = {}
        # shard-local watcher directory: name -> node ids holding a replica
        self.directory: Dict[str, Set[int]] = {}
        self.stats = _fresh_stats()

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Shard(id={self.id}, names={len(self.entries)})"


@dataclass
class ShardMigration:
    """Report of one ring topology change: which keys moved where, and the
    epoch each moved key carried across (preserved by contract)."""

    added: Tuple[int, ...]
    removed: Tuple[int, ...]
    moved: Dict[str, Tuple[int, int]]   # name -> (old shard, new shard)
    epochs: Dict[str, int]              # preserved epoch of each moved name
    total_names: int                    # namespace size at migration time

    @property
    def moved_names(self) -> List[str]:
        return list(self.moved)

    @property
    def moved_fraction(self) -> float:
        return len(self.moved) / self.total_names if self.total_names else 0.0


class ShardedStore:
    """The DSM: a named global address space partitioned over a hash ring.

    ``mesh=None`` gives a single-device store (the paper's single-node
    degenerate case) used by unit tests and the analytics examples on CPU.
    ``shards=1`` reproduces the seed's flat ``GlobalStore`` exactly; larger
    shard counts let operations on different shards proceed concurrently.
    """

    def __init__(self, mesh: Optional[Mesh] = None, *, granularity: str = "coarse",
                 shards: int = 1, vnodes: int = DEFAULT_VNODES):
        if granularity not in ("coarse", "fine"):
            raise ValueError(f"granularity must be coarse|fine, got {granularity}")
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.mesh = mesh
        self.granularity = granularity
        self._alloc = AddressAllocator(coarse=(granularity == "coarse"))
        self._alloc_lock = threading.Lock()
        # retired shards stay in _shards (empty) so stragglers holding an old
        # ring snapshot can still lock them, fail the ownership check, retry
        self._shards: Dict[int, Shard] = {i: Shard(i) for i in range(shards)}
        self._ring = HashRing(range(shards), vnodes=vnodes)
        self._rebalance_lock = threading.Lock()
        self._delete_hooks: List[Callable[[str], None]] = []
        # step.trace instrumentation target; Session attaches its tracer here.
        # Disabled default + the module-level TRACING guard keep every store
        # op at one extra branch when nothing is armed.
        self.tracer = telemetry.NULL_TRACER
        # step.check target: the lock-order sanitizer sees every shard/alloc
        # acquisition through _lock_shard/_unlock_shard/_locked_alloc
        self.checker = stepcheck.NULL_CHECKER

    # -- topology -------------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return len(self._ring)

    def shard_ids(self) -> List[int]:
        return list(self._ring.ids)

    def shard_of(self, name: str) -> int:
        """Owning shard id of ``name`` under the current ring."""
        return self._ring.owner(name)

    def shard_for(self, name: str) -> Shard:
        """Owning :class:`Shard` handle of ``name`` (lock NOT held)."""
        return self._shards[self._ring.owner(name)]

    @property
    def ring_version(self) -> int:
        """Topology epoch of the current ring — bumped by every
        ``add_shard``/``remove_shard``; :class:`OwnerHandle` holders compare
        against it to detect staleness."""
        return self._ring.version

    def owner_handle(self, name: str) -> OwnerHandle:
        """Resolve ``name``'s owner once and return the memoisable handle.

        Pass it back as the ``owner=`` argument of ``get``/``set``/``inc``
        (or ``owners=`` of ``mget``) to skip the per-op hash + bisect while
        the ring topology is unchanged."""
        ring = self._ring
        return OwnerHandle(ring.version, ring.owner(name))

    def _resolve_owner(self, ring: HashRing, name: str,
                       owner: Optional[OwnerHandle]) -> int:
        """Owning shard id under ``ring``, via the handle when still valid."""
        if owner is not None and owner.version == ring.version:
            trc = self.tracer
            if telemetry.TRACING and trc.enabled:
                trc.count("store.owner_cache_hit")
            return owner.shard
        return ring.owner(name)

    def _lock_shard(self, shard: Shard) -> None:
        """Acquire a shard's lock, recording the wait when tracing is armed
        (the per-shard contention signal the ROADMAP's overlap work needs)."""
        trc = self.tracer
        if telemetry.TRACING and trc.enabled:
            t0 = time.perf_counter()
            shard.lock.acquire()
            trc.observe("store.lock_wait", (time.perf_counter() - t0) * 1e6,
                        shard=shard.id)
        else:
            shard.lock.acquire()
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            ck.lock_acquired(("shard", shard.id))

    def _unlock_shard(self, shard: Shard) -> None:
        shard.lock.release()
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            ck.lock_released(("shard", shard.id))

    @contextmanager
    def _locked_alloc(self):
        with self._alloc_lock:
            ck = self.checker
            checking = stepcheck.CHECKING and ck.enabled
            if checking:
                ck.lock_acquired(("alloc", 0))
            try:
                yield
            finally:
                if checking:
                    ck.lock_released(("alloc", 0))

    @contextmanager
    def locked_entry(self, name: str, owner: Optional[OwnerHandle] = None):
        """Yield ``(shard, entry)`` with the owning shard's lock held.

        Lock-free ring snapshot + validate-after-lock: if a rebalance moved
        the name between the snapshot and the lock, retry against the new
        ring.  A missing name under a *current* ring is a ``KeyError`` —
        the same contract the flat dict had.  ``owner`` is an optional
        :class:`OwnerHandle` *for this name*: when its version matches the
        snapshot it replaces the hash + bisect; otherwise it is ignored.
        """
        while True:
            ring = self._ring
            shard = self._shards[self._resolve_owner(ring, name, owner)]
            self._lock_shard(shard)
            try:
                entry = shard.entries.get(name)
                if entry is not None:
                    yield shard, entry
                    return
                if self._ring is ring:
                    raise KeyError(name)
            finally:
                self._unlock_shard(shard)
            # the ring moved under us — resolve the new owner and retry

    @contextmanager
    def locked_owner(self, name: str, owner: Optional[OwnerHandle] = None):
        """Like :meth:`locked_entry` but for declarations: the name need not
        exist, only the ring snapshot must still be current once locked."""
        while True:
            ring = self._ring
            shard = self._shards[self._resolve_owner(ring, name, owner)]
            self._lock_shard(shard)
            try:
                if self._ring is ring:
                    yield shard
                    return
            finally:
                self._unlock_shard(shard)

    # -- elastic rebalancing ---------------------------------------------------

    def add_shard(self, shard_id: Optional[int] = None) -> ShardMigration:
        """Grow the ring by one shard (node join); migrates only the keys
        whose owner changed, epochs preserved."""
        with self._rebalance_lock:
            if shard_id is None:
                shard_id = max(self._shards) + 1 if self._shards else 0
            shard_id = int(shard_id)
            if shard_id in self._ring.ids:
                raise ValueError(f"shard {shard_id} already on the ring")
            self._shards.setdefault(shard_id, Shard(shard_id))
            return self._migrate(self._ring.added(shard_id),
                                 added=(shard_id,), removed=())

    def remove_shard(self, shard_id: int) -> ShardMigration:
        """Shrink the ring by one shard (node leave); its keys migrate to the
        survivors that inherit its arcs, epochs preserved."""
        with self._rebalance_lock:
            shard_id = int(shard_id)
            if shard_id not in self._ring.ids:
                raise KeyError(f"shard {shard_id} is not on the ring")
            if len(self._ring) == 1:
                raise ValueError("cannot remove the last shard")
            return self._migrate(self._ring.removed(shard_id),
                                 added=(), removed=(shard_id,))

    def _migrate(self, new_ring: HashRing, *, added, removed) -> ShardMigration:
        """Move every entry/generation/directory record whose owner changed.

        Caller holds ``_rebalance_lock``.  All involved shard locks are taken
        in sorted id order; the new ring is published before any lock is
        released, so concurrent ops either complete under the old topology or
        observe the new ring when they validate after locking.
        """
        old_ring = self._ring
        ids = sorted(set(old_ring.ids) | set(new_ring.ids))
        shards = [self._shards[i] for i in ids]
        ck = self.checker
        checking = stepcheck.CHECKING and ck.enabled
        if checking:
            ck.rebalance_begin()
        for s in shards:
            self._lock_shard(s)
        try:
            moved: Dict[str, Tuple[int, int]] = {}
            epochs: Dict[str, int] = {}
            total = sum(len(s.entries) for s in shards)
            for s in shards:
                for name in list(s.entries):
                    owner = new_ring.owner(name)
                    if owner == s.id:
                        continue
                    dst = self._shards[owner]
                    e = s.entries.pop(name)
                    dst.entries[name] = e          # epoch rides with the entry
                    moved[name] = (s.id, owner)
                    epochs[name] = e.epoch
                    if name in s.gen:
                        dst.gen[name] = max(dst.gen.get(name, 0), s.gen.pop(name))
                    if name in s.directory:
                        dst.directory[name] = s.directory.pop(name)
                    s.stats["migrated_out"] += 1
                    dst.stats["migrated_in"] += 1
                # delete-era generations of names with no live entry follow
                # the ring too: a redeclare after migration must still start
                # strictly past the deleted era
                for name in list(s.gen):
                    owner = new_ring.owner(name)
                    if owner != s.id:
                        dst = self._shards[owner]
                        dst.gen[name] = max(dst.gen.get(name, 0), s.gen.pop(name))
                # defensive: orphan directory records (no entry) follow too
                for name in list(s.directory):
                    owner = new_ring.owner(name)
                    if owner != s.id:
                        self._shards[owner].directory[name] = s.directory.pop(name)
            self._ring = new_ring   # publish while every lock is still held
            return ShardMigration(tuple(added), tuple(removed), moved, epochs,
                                  total)
        finally:
            for s in reversed(shards):
                self._unlock_shard(s)
            if checking:
                ck.rebalance_end()

    # -- store-side delete hooks (cache coherence teardown) --------------------

    def add_delete_hook(self, hook: Callable[[str], None], *,
                        weak: bool = False) -> Callable[[str], None]:
        """Register ``hook(name)`` to fire inside :meth:`delete`, under the
        owning shard's lock.  The DSM cache registers its replica/directory
        teardown here, so a *direct* store delete (not via ``Session.delete``)
        also kills every phantom holder.

        ``weak=True`` holds a bound-method hook only weakly: a store outlives
        the sessions rolled over it (FT recovery adopts the surviving store),
        and a strong ref would pin every dead session's cache — and fan
        deletes out to it — for the store's lifetime."""
        self._delete_hooks.append(weakref.WeakMethod(hook) if weak else hook)
        return hook

    def _fire_delete_hooks(self, name: str) -> None:
        """Invoke live hooks; prune weak entries whose cache was collected."""
        dead = []
        for entry in list(self._delete_hooks):
            hook = entry() if isinstance(entry, weakref.WeakMethod) else entry
            if hook is None:
                dead.append(entry)
            else:
                hook(name)
        for entry in dead:
            self._delete_hooks.remove(entry)

    # -- declaration ----------------------------------------------------------

    def _sharding(self, spec: Optional[P]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec if spec is not None else P())

    def _num_words(self, shape, dtype) -> int:
        nbytes = int(np.prod(shape, dtype=np.int64)) * jnp.dtype(dtype).itemsize if shape else jnp.dtype(dtype).itemsize
        return max(1, (nbytes + WORD_BYTES - 1) // WORD_BYTES)

    @staticmethod
    def _fresh_epoch(shard: Shard, name: str) -> int:
        """Starting epoch for a (re-)declared name: strictly above every epoch
        the name has ever had, so stale replicas can never validate."""
        prev = shard.gen.get(name, 0)
        if name in shard.entries:
            prev = max(prev, shard.entries[name].epoch + 1)
        return prev

    def def_global(self, name: str, value, *, spec: Optional[P] = None) -> str:
        """``DefGlobal(NAME, TYPE)`` — declare a shared variable and set it."""
        value = jnp.asarray(value)
        with self._locked_alloc():
            slot = self._alloc.alloc_field(
                GLOBALS_OBJECT_ID, self._num_words(value.shape, value.dtype))
        placed = self._place(value, spec)
        with self.locked_owner(name) as shard:
            shard.entries[name] = GlobalEntry(name, slot, self._sharding(spec),
                                              placed,
                                              epoch=self._fresh_epoch(shard, name),
                                              spec=spec)
        return name

    def new_array(self, name: str, shape, dtype=jnp.float32, *, spec: Optional[P] = None) -> str:
        """``NewArray<TYPE>(n)`` — allocate a zeroed shared array."""
        with self._locked_alloc():
            oid = self._alloc.new_object()
            slot = self._alloc.alloc_field(oid, self._num_words(shape, dtype))
        placed = self._place(jnp.zeros(shape, dtype), spec)
        with self.locked_owner(name) as shard:
            shard.entries[name] = GlobalEntry(name, slot, self._sharding(spec),
                                              placed,
                                              epoch=self._fresh_epoch(shard, name),
                                              spec=spec)
        return name

    def new_object(self, name: str, fields: Dict[str, Any], *, specs: Optional[Dict[str, P]] = None) -> str:
        """``NewObj`` — a shared object: a pytree of fields under one object_id."""
        specs = specs or {}
        placed = {}
        words = 0
        for fname, fval in fields.items():
            fval = jnp.asarray(fval)
            words += self._num_words(fval.shape, fval.dtype)
            placed[fname] = self._place(fval, specs.get(fname))
        with self._locked_alloc():
            oid = self._alloc.new_object()
            slot = self._alloc.alloc_field(oid, words)
        with self.locked_owner(name) as shard:
            shard.entries[name] = GlobalEntry(name, slot, None, placed,
                                              epoch=self._fresh_epoch(shard, name),
                                              field_specs=dict(specs))
        return name

    def delete(self, name: str) -> None:
        """``DelArray`` / ``DelObj``.  Records the retired epoch so a later
        re-declaration of the same name starts strictly past it, and fires
        the registered delete hooks (cache replica + directory teardown)
        under the owning shard's lock."""
        with self.locked_entry(name) as (shard, e):
            del shard.entries[name]
            shard.gen[name] = max(shard.gen.get(name, 0), e.epoch + 1)
            shard.directory.pop(name, None)
            self._fire_delete_hooks(name)

    # -- access (the DSM-internal-layer Get/Set of Table 1) -------------------

    def _place(self, value, spec: Optional[P]):
        if self.mesh is None:
            return value
        return jax.device_put(value, self._sharding(spec))

    def get(self, name: str, *, owner: Optional[OwnerHandle] = None):
        trc = self.tracer
        tracing = telemetry.TRACING and trc.enabled
        t0 = time.perf_counter() if tracing else 0.0
        with self.locked_entry(name, owner) as (shard, e):
            shard.stats["get"] += 1
            shard.stats["bytes_get"] += _nbytes(e.value)
            shard.stats["transfers"] += self._transfer_count(e.value)
            value, sid = e.value, shard.id
        if tracing:
            trc.store_op("get", sid, t0, name=name)
        return value

    def set(self, name: str, value, *, bump_epoch: bool = True,
            owner: Optional[OwnerHandle] = None) -> None:
        trc = self.tracer
        tracing = telemetry.TRACING and trc.enabled
        t0 = time.perf_counter() if tracing else 0.0
        with self.locked_entry(name, owner) as (shard, e):
            if isinstance(e.value, dict):
                specs = e.field_specs or {}
                e.value = {k: self._place(jnp.asarray(v), specs.get(k))
                           for k, v in value.items()}
            else:
                value = jnp.asarray(value)
                if e.sharding is not None:
                    value = jax.device_put(value, e.sharding)
                e.value = value
            if bump_epoch:
                e.epoch += 1
            shard.stats["set"] += 1
            shard.stats["bytes_set"] += _nbytes(e.value)
            shard.stats["transfers"] += self._transfer_count(e.value)
            sid = shard.id
        if tracing:
            trc.store_op("set", sid, t0, name=name)

    def mget(self, names, *, owners=None) -> list:
        """``MGet`` — batched get, one logical round trip *per shard touched*
        (names are grouped by owner, each group read under one lock hold).

        ``owners`` is an optional sequence of :class:`OwnerHandle` (or None)
        aligned with ``names``; current handles skip that name's hash+bisect.
        """
        trc = self.tracer
        tracing = telemetry.TRACING and trc.enabled
        t0 = time.perf_counter() if tracing else 0.0
        names = list(names)
        if owners is not None:
            owners = list(owners)
            if len(owners) != len(names):
                raise ValueError(
                    f"owners must align with names: got {len(owners)} handles "
                    f"for {len(names)} names")
        vals: list = [None] * len(names)
        ring = self._ring
        groups: Dict[int, List[int]] = {}
        for i, n in enumerate(names):
            h = owners[i] if owners is not None else None
            groups.setdefault(self._resolve_owner(ring, n, h), []).append(i)
        for sid, idxs in groups.items():
            shard = self._shards[sid]
            stragglers: List[int] = []
            self._lock_shard(shard)
            try:
                got_bytes = 0
                served = 0
                for i in idxs:
                    e = shard.entries.get(names[i])
                    if e is None:   # migrated (or missing) — retry per name
                        stragglers.append(i)
                        continue
                    vals[i] = e.value
                    got_bytes += _nbytes(e.value)
                    served += 1
                if served:
                    shard.stats["get"] += 1
                    shard.stats["transfers"] += 1
                    shard.stats["bytes_get"] += got_bytes
            finally:
                self._unlock_shard(shard)
            for i in stragglers:
                vals[i] = self.get(names[i])
        if tracing:
            t1 = time.perf_counter()
            trc.add_span("store-op", "store.mget", t0, t1,
                         {"names": len(names), "shards": len(groups)})
            trc.observe("store.mget", (t1 - t0) * 1e6)
        return vals

    def inc(self, name: str, amount=1, *, owner: Optional[OwnerHandle] = None):
        """Atomic increment (Table 1) — skips the cache layer by contract.

        Serialised under the *owning shard's* lock (increments to names on
        different shards proceed concurrently), re-placed with the entry's
        declared spec, and accounted like any other DSM write.
        """
        trc = self.tracer
        tracing = telemetry.TRACING and trc.enabled
        t0 = time.perf_counter() if tracing else 0.0
        with self.locked_entry(name, owner) as (shard, e):
            e.value = self._place(jnp.asarray(e.value) + amount, e.spec)
            e.epoch += 1
            shard.stats["inc"] += 1
            shard.stats["bytes_set"] += _nbytes(e.value)
            shard.stats["transfers"] += self._transfer_count(e.value)
            value, sid = e.value, shard.id
        if tracing:
            trc.store_op("inc", sid, t0, name=name)
        return value

    def epoch(self, name: str) -> int:
        with self.locked_entry(name) as (_, e):
            return e.epoch

    def address(self, name: str) -> int:
        with self.locked_entry(name) as (_, e):
            return e.slot.address

    def names(self):
        out: List[str] = []
        for sid in self._ring.ids:
            shard = self._shards[sid]
            with shard.lock:
                out.extend(shard.entries)
        return out

    # -- stats / introspection -------------------------------------------------

    @property
    def stats(self) -> Dict[str, int]:
        """Aggregate op counters across every shard (retired shards included,
        so counters never run backwards across a rebalance)."""
        total = _fresh_stats()
        for shard in self._shards.values():
            for key, v in shard.stats.items():
                total[key] += v
        return total

    def shard_stats(self) -> Dict[int, Dict[str, Any]]:
        """Per-shard op counters + entry count, keyed by shard id (active
        ring members only)."""
        out: Dict[int, Dict[str, Any]] = {}
        for sid in self._ring.ids:
            shard = self._shards[sid]
            with shard.lock:
                row = dict(shard.stats)
                row["names"] = len(shard.entries)
            out[sid] = row
        return out

    def metrics(self) -> Dict[str, Any]:
        """Aggregate counters under the canonical (normalized) key set —
        :data:`repro.core.telemetry.STORE_METRIC_KEYS`.  The raw ``stats``
        property keeps the legacy singular-verb keys as a deprecated view."""
        return telemetry.normalize_store_stats(self.stats)

    def shard_metrics(self) -> Dict[int, Dict[str, Any]]:
        """Per-shard :meth:`metrics` rows (normalized ``shard_stats``)."""
        return {sid: telemetry.normalize_store_stats(row)
                for sid, row in self.shard_stats().items()}

    @property
    def _entries(self) -> Dict[str, GlobalEntry]:
        """Merged name→entry view across shards (read-only compatibility with
        the flat store; mutate through the store API, not this view)."""
        merged: Dict[str, GlobalEntry] = {}
        for shard in self._shards.values():
            merged.update(shard.entries)
        return merged

    def _transfer_count(self, value) -> int:
        """How many physical transfers a get/set of `value` costs under the
        current granularity — the quantity Fig. 3 is about."""
        leaves = jax.tree.leaves(value)
        if self.granularity == "coarse":
            return len(leaves)  # one package-aligned bulk transfer per leaf
        # fine-grained: one word-sized KV op per word
        return int(sum(max(1, _nbytes(l) // WORD_BYTES) for l in leaves))
