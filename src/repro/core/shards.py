"""step.shards — the partitioned KV store beneath the DSM (paper §5.1 scaled).

STEP's key idea is that "the underlying key-value store serves as distributed
shared memory".  The seed repro kept that store as one flat dict behind one
lock, which serialises every cached read/write across all nodes and names —
the exact bottleneck a partitioned store exists to remove.  This module is the
partitioned form:

* :class:`HashRing` — a consistent-hash ring (``vnodes`` virtual points per
  shard, :func:`~repro.core.addressing.ring_hash` positions) mapping every DSM
  name to its owning shard.  Ring objects are immutable; topology changes
  build a *new* ring, so readers can take a lock-free snapshot (``self._ring``)
  and validate it after locking.
* :class:`Shard` — one partition: its entries, its delete-era generations,
  its watcher directory and **its own lock**.  Reads/writes/increments/cache
  invalidations for names on different shards never touch a common lock.
* :class:`ShardedStore` — the store facade over the ring.  API-identical to
  the seed's ``GlobalStore`` (which is now a thin subclass in
  :mod:`repro.core.dsm`); with ``shards=1`` it is behaviour-identical to the
  flat store.
* **Elastic rebalancing** — ``add_shard`` / ``remove_shard`` migrate only the
  keys whose ring arc changed owner (~1/S of the namespace), moving each
  entry *with its epoch*, its delete-era generation and its directory record,
  so no stale cache replica can survive a migration and a post-migration
  redeclare still starts past every epoch the name ever had.
* **Tiered entries** (step.tiers) — each :class:`Shard` is a two-tier store:
  the hot in-memory dict plus a per-store pluggable
  :class:`~repro.core.tiers.ColdTier` (host-mem or disk).  When a
  ``cold_budget`` is set, least-recently-used entries demote their *value
  payload* to the cold tier (metadata — epoch, slot, spec, directory — stays
  hot, so coherence never touches the backend) and promote back on access
  with their epoch intact: a cache replica that validated before a
  demote/promote cycle still validates after it.
* **Incremental arc handoff** — by default ``add_shard``/``remove_shard``
  open a :class:`MigrationWindow` instead of freezing the store: the new
  ring is published immediately, and each moved key crosses shards on first
  access (pull-on-access under exactly the two involved shard locks) or via
  the inline drain.  A reader's worst-case pause is one entry migration, not
  the whole arc; ``incremental=False`` keeps the legacy stop-the-world path.

During a window an operation that resolves the *new* owner and misses
double-checks the window's pending set (pulling the entry across before
retrying), while an operation that locked the *old* owner before the ring
was published simply completes there — the entry lives in exactly one shard
dict at any instant and every mutation happens under the lock of the shard
currently holding it, so no reader can observe a stale value.

Keys are placed by *name* rather than by allocated block address: names are
the stable identity of shared data (addresses depend on allocation order and
change on redeclare), and placement must be derivable before allocation and
after adoption by a recovered session.  The name plays the role the block
address played in §5.1's ``watcher_node``.

Locking order is strictly ``shard → node-cache``; the rebalancer takes every
involved shard lock in sorted id order and publishes the new ring before
releasing, so in-flight operations either finish under the old topology or
retry under the new one (see ``locked_entry``).
"""

from __future__ import annotations

import bisect
import math
import threading
import time
import weakref
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.check import checker as stepcheck
from repro.core import telemetry
from repro.core.addressing import (
    AddressAllocator,
    FieldSlot,
    GLOBALS_OBJECT_ID,
    WORD_BYTES,
    ring_hash,
)
from repro.core.tiers import ColdTier, resolve_cold_tier

DEFAULT_VNODES = 128


def _nbytes(v) -> int:
    # leaf.size is a cheap attribute on concrete arrays (this runs on every
    # get/set); math.prod over the shape covers abstract leaves without one
    total = 0
    for leaf in jax.tree.leaves(v):
        n = getattr(leaf, "size", None)
        if n is None:
            n = math.prod(leaf.shape)
        total += int(n) * jnp.dtype(leaf.dtype).itemsize
    return total


def _demotable(value) -> bool:
    """Only concrete array pytrees can spill — abstract entries (trace-mode
    ShapeDtypeStructs) carry no payload to store."""
    leaves = jax.tree.leaves(value)
    return bool(leaves) and not any(isinstance(l, jax.ShapeDtypeStruct)
                                    for l in leaves)


@dataclass
class GlobalEntry:
    """One named piece of shared data plus its DSM directory record."""

    name: str
    slot: FieldSlot
    sharding: Optional[NamedSharding]
    value: Any  # jax.Array | ShapeDtypeStruct (abstract mode)
    epoch: int = 0  # bumped on every Set — drives cache invalidation
    # re-placement metadata: the declared spec (arrays) / per-field specs
    # (objects), so Set/Inc restore the same NamedSharding they started with
    spec: Optional[P] = None
    field_specs: Optional[Dict[str, P]] = None
    # tier bookkeeping (step.tiers): hot_nbytes is this entry's share of the
    # shard's hot-byte budget; cold_bytes is the payload size parked in the
    # cold tier while value is None.  Both stay 0 when no tier is configured.
    hot_nbytes: int = 0
    cold_bytes: int = 0


class HashRing:
    """Immutable consistent-hash ring over shard ids.

    Each shard contributes ``vnodes`` virtual points; a key is owned by the
    first point clockwise of ``ring_hash(key)``.  ``added``/``removed``
    return new rings, never mutate — the store publishes a new ring by
    swapping one reference.
    """

    __slots__ = ("ids", "vnodes", "version", "_keys", "_owners")

    def __init__(self, shard_ids, vnodes: int = DEFAULT_VNODES,
                 version: int = 0):
        ids = tuple(sorted(set(int(i) for i in shard_ids)))
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {vnodes}")
        self.ids = ids
        self.vnodes = int(vnodes)
        # Monotonic topology epoch, carried ON the ring so one atomic
        # reference read yields a consistent (arcs, version) pair — memoised
        # OwnerHandles compare against it to detect rebalances.
        self.version = int(version)
        points = sorted((ring_hash(f"shard:{sid}#vnode:{v}"), sid)
                        for sid in ids for v in range(self.vnodes))
        self._keys = [h for h, _ in points]
        self._owners = [sid for _, sid in points]

    def owner(self, key) -> int:
        """Shard id owning ``key`` (a DSM name, or any hashable address)."""
        if not self._keys:
            # an empty ring is a legal value object (removed() of the last
            # shard), but it owns nothing — without this guard the modulo
            # below raises a bare ZeroDivisionError
            raise ValueError(
                "cannot resolve an owner on an empty hash ring — all shards "
                "have been removed")
        i = bisect.bisect_right(self._keys, ring_hash(key)) % len(self._keys)
        return self._owners[i]

    def added(self, shard_id: int) -> "HashRing":
        return HashRing(self.ids + (shard_id,), self.vnodes, self.version + 1)

    def removed(self, shard_id: int) -> "HashRing":
        return HashRing(tuple(i for i in self.ids if i != shard_id),
                        self.vnodes, self.version + 1)

    def __len__(self) -> int:
        return len(self.ids)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"HashRing(ids={self.ids}, vnodes={self.vnodes}, "
                f"version={self.version})")


class OwnerHandle:
    """Memoised (ring version, shard id) owner resolution of one name.

    Hot-path store ops pay a blake2b hash + bisect per call just to find the
    owning shard; a holder that touches the same name repeatedly (a
    ``SharedRef``, an accumulator's output) can resolve once and pass the
    handle back in.  Immutable by contract: a stale handle is never repaired
    in place (a torn two-field write could route a concurrent reader to the
    wrong shard *with* a matching version) — holders compare ``version``
    against :attr:`ShardedStore.ring_version` and atomically swap in a fresh
    handle from :meth:`ShardedStore.owner_handle`.  A stale handle passed to
    a store op is simply ignored (the op re-hashes), so lazy refresh is safe.
    """

    __slots__ = ("version", "shard")

    def __init__(self, version: int, shard: int):
        self.version = int(version)
        self.shard = int(shard)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"OwnerHandle(version={self.version}, shard={self.shard})"


def _fresh_stats() -> Dict[str, int]:
    return {"get": 0, "set": 0, "inc": 0, "bytes_get": 0, "bytes_set": 0,
            "transfers": 0, "migrated_in": 0, "migrated_out": 0,
            "migrated_bytes": 0, "hot_hits": 0, "cold_hits": 0,
            "promotions": 0, "demotions": 0}


class Shard:
    """One partition of the namespace: entries + generations + directory,
    guarded by this shard's own lock (an RLock: the cache layer composes
    store ops while already holding it).

    ``entries`` is the *hot* tier — insertion order doubles as LRU order
    when a cold tier is configured (hits reinsert at the MRU end).  ``cold``
    indexes entries whose value payload lives in the store's cold tier:
    the :class:`GlobalEntry` metadata (epoch, slot, spec, directory record)
    stays here so validation and coherence never touch the backend."""

    __slots__ = ("id", "lock", "entries", "cold", "hot_bytes", "gen",
                 "directory", "stats")

    def __init__(self, shard_id: int):
        self.id = int(shard_id)
        self.lock = threading.RLock()
        self.entries: Dict[str, GlobalEntry] = {}
        self.cold: Dict[str, GlobalEntry] = {}
        self.hot_bytes = 0
        # per-name monotonic generation: a name deleted at epoch e re-declares
        # at e+1, so no cache replica of the deleted era can ever validate as
        # fresh against the new entry (delete→redeclare stale-read fix)
        self.gen: Dict[str, int] = {}
        # shard-local watcher directory: name -> node ids holding a replica
        self.directory: Dict[str, Set[int]] = {}
        self.stats = _fresh_stats()

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Shard(id={self.id}, names={len(self.entries)}, "
                f"cold={len(self.cold)})")


@dataclass
class ShardMigration:
    """Report of one ring topology change: which keys moved where, the epoch
    each moved key carried across (preserved by contract), how many bytes
    crossed shards and how long the migration window stayed open."""

    added: Tuple[int, ...]
    removed: Tuple[int, ...]
    moved: Dict[str, Tuple[int, int]]   # name -> (old shard, new shard)
    epochs: Dict[str, int]              # preserved epoch of each moved name
    total_names: int                    # namespace size at migration time
    bytes_moved: int = 0                # payload bytes that crossed shards
    window_s: float = 0.0               # open → closed wall time of the window
    pulled: int = 0                     # entries migrated by reader/writer pulls

    @property
    def moved_names(self) -> List[str]:
        return list(self.moved)

    @property
    def moved_fraction(self) -> float:
        return len(self.moved) / self.total_names if self.total_names else 0.0


class MigrationWindow:
    """State of one in-flight incremental arc handoff.

    The new ring is already published when a window exists; ``pending`` maps
    each not-yet-moved name to its ``(old owner, new owner)`` pair.  Until
    the planner finishes snapshotting the source shards (``sealed``), the
    pending set is still filling and membership is decided by comparing the
    two rings instead.  The window closes (and fills in its
    :class:`ShardMigration`'s ``bytes_moved``/``window_s``/``pulled``) when
    the sealed pending set drains — by access pulls, ``migrate_step`` /
    ``drain_window``, or the default inline drain of ``add_shard`` /
    ``remove_shard``."""

    __slots__ = ("old_ring", "new_ring", "pending", "lock", "t_open",
                 "sealed", "closed", "entries_moved", "bytes_moved",
                 "pulled", "migration")

    def __init__(self, old_ring: HashRing, new_ring: HashRing):
        self.old_ring = old_ring
        self.new_ring = new_ring
        self.pending: Dict[str, Tuple[int, int]] = {}
        self.lock = threading.Lock()     # guards pending + the counters below
        self.t_open = time.perf_counter()
        self.sealed = False
        self.closed = False
        self.entries_moved = 0
        self.bytes_moved = 0
        self.pulled = 0
        self.migration: Optional[ShardMigration] = None

    @property
    def remaining(self) -> int:
        return len(self.pending)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"MigrationWindow(v{self.old_ring.version}->"
                f"v{self.new_ring.version}, pending={len(self.pending)}, "
                f"closed={self.closed})")


class ShardedStore:
    """The DSM: a named global address space partitioned over a hash ring.

    ``mesh=None`` gives a single-device store (the paper's single-node
    degenerate case) used by unit tests and the analytics examples on CPU.
    ``shards=1`` reproduces the seed's flat ``GlobalStore`` exactly; larger
    shard counts let operations on different shards proceed concurrently.
    """

    def __init__(self, mesh: Optional[Mesh] = None, *, granularity: str = "coarse",
                 shards: int = 1, vnodes: int = DEFAULT_VNODES,
                 cold_tier: "ColdTier | str | None" = None,
                 cold_budget: Optional[int] = None):
        if granularity not in ("coarse", "fine"):
            raise ValueError(f"granularity must be coarse|fine, got {granularity}")
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if cold_budget is not None and cold_budget < 0:
            raise ValueError(f"cold_budget must be >= 0 bytes, got {cold_budget}")
        self.mesh = mesh
        self.granularity = granularity
        self._alloc = AddressAllocator(coarse=(granularity == "coarse"))
        self._alloc_lock = threading.Lock()
        # retired shards stay in _shards (empty) so stragglers holding an old
        # ring snapshot can still lock them, fail the ownership check, retry
        self._shards: Dict[int, Shard] = {i: Shard(i) for i in range(shards)}
        self._ring = HashRing(range(shards), vnodes=vnodes)
        self._rebalance_lock = threading.Lock()
        self._delete_hooks: List[Callable[[str], None]] = []
        # step.tiers: the shared cold backend ("host" | "disk" | a ColdTier)
        # and the per-shard hot-byte budget that triggers LRU demotion.  None
        # keeps every path single-tier at one extra branch per op.
        self._cold = resolve_cold_tier(cold_tier)
        self._cold_budget = int(cold_budget) if cold_budget is not None else None
        # incremental arc handoff: at most one open window at a time (the
        # rebalance lock serialises openers; pulls run lock-free against it)
        self._window: Optional[MigrationWindow] = None
        self._mig_lock = threading.Lock()
        self._migration_totals: Dict[str, Any] = {
            "windows": 0, "entries_moved": 0, "bytes_moved": 0,
            "pulled": 0, "window_s": 0.0}
        # test/benchmark seam: called with the name inside each pair-locked
        # entry move (deterministic stress tests inject per-entry delay here)
        self._migrate_entry_hook: Optional[Callable[[str], None]] = None
        # step.trace instrumentation target; Session attaches its tracer here.
        # Disabled default + the module-level TRACING guard keep every store
        # op at one extra branch when nothing is armed.
        self.tracer = telemetry.NULL_TRACER
        # step.check target: the lock-order sanitizer sees every shard/alloc
        # acquisition through _lock_shard/_unlock_shard/_locked_alloc
        self.checker = stepcheck.NULL_CHECKER

    # -- topology -------------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return len(self._ring)

    def shard_ids(self) -> List[int]:
        return list(self._ring.ids)

    def shard_of(self, name: str) -> int:
        """Owning shard id of ``name`` under the current ring."""
        return self._ring.owner(name)

    def shard_for(self, name: str) -> Shard:
        """Owning :class:`Shard` handle of ``name`` (lock NOT held)."""
        return self._shards[self._ring.owner(name)]

    @property
    def ring_version(self) -> int:
        """Topology epoch of the current ring — bumped by every
        ``add_shard``/``remove_shard``; :class:`OwnerHandle` holders compare
        against it to detect staleness."""
        return self._ring.version

    def owner_handle(self, name: str) -> OwnerHandle:
        """Resolve ``name``'s owner once and return the memoisable handle.

        Pass it back as the ``owner=`` argument of ``get``/``set``/``inc``
        (or ``owners=`` of ``mget``) to skip the per-op hash + bisect while
        the ring topology is unchanged."""
        ring = self._ring
        return OwnerHandle(ring.version, ring.owner(name))

    def _resolve_owner(self, ring: HashRing, name: str,
                       owner: Optional[OwnerHandle]) -> int:
        """Owning shard id under ``ring``, via the handle when still valid."""
        if owner is not None and owner.version == ring.version:
            trc = self.tracer
            if telemetry.TRACING and trc.enabled:
                trc.count("store.owner_cache_hit")
            return owner.shard
        return ring.owner(name)

    def _lock_shard(self, shard: Shard) -> None:
        """Acquire a shard's lock, recording the wait when tracing is armed
        (the per-shard contention signal the ROADMAP's overlap work needs)."""
        trc = self.tracer
        if telemetry.TRACING and trc.enabled:
            lock = shard.lock
            if lock._is_owned():
                # re-entrant acquire (cache → nested store op on the same
                # shard): by definition not a wait — recording its constant
                # zero would only dilute the contention histogram
                lock.acquire()
            else:
                t0 = time.perf_counter()
                lock.acquire()
                wait_us = (time.perf_counter() - t0) * 1e6
                # record-only (armed flight recorder) keeps only true waits:
                # sub-µs uncontended acquires are 95%+ of acquisitions and
                # the per-call tracer time they cost is exactly what the
                # armed ≤5% overhead budget cannot afford
                if not trc.record_only or wait_us >= 1.0:
                    trc.observe("store.lock_wait", wait_us, shard=shard.id)
        else:
            shard.lock.acquire()
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            ck.lock_acquired(("shard", shard.id))

    def _unlock_shard(self, shard: Shard) -> None:
        shard.lock.release()
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            ck.lock_released(("shard", shard.id))

    @contextmanager
    def _locked_alloc(self):
        with self._alloc_lock:
            ck = self.checker
            checking = stepcheck.CHECKING and ck.enabled
            if checking:
                ck.lock_acquired(("alloc", 0))
            try:
                yield
            finally:
                if checking:
                    ck.lock_released(("alloc", 0))

    @contextmanager
    def locked_entry(self, name: str, owner: Optional[OwnerHandle] = None):
        """Yield ``(shard, entry)`` with the owning shard's lock held.

        Lock-free ring snapshot + validate-after-lock: if a rebalance moved
        the name between the snapshot and the lock, retry against the new
        ring.  A missing name under a *current* ring is a ``KeyError`` —
        the same contract the flat dict had.  ``owner`` is an optional
        :class:`OwnerHandle` *for this name*: when its version matches the
        snapshot it replaces the hash + bisect; otherwise it is ignored.

        During an open migration window the name is settled first: if its
        arc changed owner and it has not crossed yet, it is pulled to the
        new owner under exactly the two involved shard locks — the reader's
        pause is that one entry move, never the whole arc.  The entry may be
        cold (``entry.value is None``); value-reading callers go through
        ``_promote``.
        """
        while True:
            ring = self._ring
            win = self._window
            pinned = self._settle(win, name) if win is not None else None
            if pinned is not None:
                shard = self._shards[pinned]
            else:
                shard = self._shards[self._resolve_owner(ring, name, owner)]
            self._lock_shard(shard)
            try:
                entry = shard.entries.get(name)
                if entry is not None:
                    if self._cold is not None:
                        shard.stats["hot_hits"] += 1
                        # LRU touch: reinsertion puts the name at the MRU end
                        shard.entries[name] = shard.entries.pop(name)
                    yield shard, entry
                    return
                entry = shard.cold.get(name)
                if entry is not None:
                    shard.stats["cold_hits"] += 1
                    yield shard, entry
                    return
                if self._ring is ring and (pinned is not None
                                           or not self._window_pending(name)):
                    raise KeyError(name)
            finally:
                self._unlock_shard(shard)
            # the ring (or the window) moved under us — resolve and retry

    @contextmanager
    def locked_owner(self, name: str, owner: Optional[OwnerHandle] = None):
        """Like :meth:`locked_entry` but for declarations: the name need not
        exist, only the ring snapshot must still be current once locked.
        Settling first matters here too — a redeclare during a window must
        see the old owner's delete-era generation, or it could reuse an
        epoch a stale replica still validates against."""
        while True:
            ring = self._ring
            win = self._window
            pinned = self._settle(win, name) if win is not None else None
            if pinned is not None:
                shard = self._shards[pinned]
            else:
                shard = self._shards[self._resolve_owner(ring, name, owner)]
            self._lock_shard(shard)
            try:
                if pinned is not None or self._ring is ring:
                    yield shard
                    return
            finally:
                self._unlock_shard(shard)

    # -- tiers (step.tiers: hot dict + pluggable cold backend) -----------------

    def _promote(self, shard: Shard, e: GlobalEntry, *, load: bool = True) -> None:
        """Move a cold entry back into the hot dict (owning shard lock held).

        ``load=True`` reads the payload back from the cold tier and re-places
        it under the entry's declared spec — the entry's epoch is untouched,
        so a replica that validated before the demote still validates after
        the promote.  ``load=False`` (Set overwrites the whole value) only
        reclaims the tier slot; the caller assigns the value and accounts
        bytes via :meth:`_note_resize`."""
        name = e.name
        if shard.cold.pop(name, None) is None:
            return
        if load:
            payload = self._cold.get(name)
            if isinstance(payload, dict):
                specs = e.field_specs or {}
                e.value = {k: self._place(jnp.asarray(v), specs.get(k))
                           for k, v in payload.items()}
            else:
                e.value = self._place(jnp.asarray(payload), e.spec)
            shard.stats["promotions"] += 1
            trc = self.tracer
            if telemetry.TRACING and trc.enabled:
                trc.count("tier.promotions")
        self._cold.delete(name)
        e.cold_bytes = 0
        e.hot_nbytes = _nbytes(e.value) if load else 0
        shard.hot_bytes += e.hot_nbytes
        shard.entries[name] = e

    def _note_resize(self, shard: Shard, e: GlobalEntry) -> None:
        """Re-account an entry's hot bytes after its value changed (owning
        shard lock held), then demote LRU entries past the budget."""
        nb = _nbytes(e.value)
        shard.hot_bytes += nb - e.hot_nbytes
        e.hot_nbytes = nb
        self._maybe_demote(shard)

    def _install(self, shard: Shard, entry: GlobalEntry) -> None:
        """Insert a (re-)declared entry into the hot dict (owning shard lock
        held), displacing any previous hot or cold incarnation of the name."""
        name = entry.name
        if self._cold is None:
            shard.entries[name] = entry
            return
        prev = shard.entries.get(name)
        if prev is not None:
            shard.hot_bytes -= prev.hot_nbytes
        elif shard.cold.pop(name, None) is not None:
            self._cold.delete(name)
        entry.hot_nbytes = _nbytes(entry.value)
        shard.hot_bytes += entry.hot_nbytes
        shard.entries[name] = entry
        self._maybe_demote(shard)

    def _maybe_demote(self, shard: Shard) -> None:
        """Spill least-recently-used hot entries to the cold tier until the
        shard is back under its hot-byte budget (owning shard lock held).
        The just-touched entry sits at the MRU end, so it is only demoted
        when it is the lone demotable entry left — never preferentially."""
        budget = self._cold_budget
        if budget is None or shard.hot_bytes <= budget:
            return
        trc = self.tracer
        tracing = telemetry.TRACING and trc.enabled
        while shard.hot_bytes > budget and len(shard.entries) > 1:
            victim = None
            for name, e in shard.entries.items():
                if _demotable(e.value):
                    victim = (name, e)
                    break
            if victim is None:
                break
            name, e = victim
            nb = self._cold.put(name, e.value)
            del shard.entries[name]
            shard.hot_bytes -= e.hot_nbytes
            e.hot_nbytes = 0
            e.cold_bytes = nb
            e.value = None
            shard.cold[name] = e
            shard.stats["demotions"] += 1
            if tracing:
                trc.count("tier.demotions")

    @property
    def cold_tier(self) -> Optional[ColdTier]:
        """The configured cold backend (None when single-tier)."""
        return self._cold

    def tier_stats(self) -> Dict[str, Any]:
        """Hot/cold occupancy and movement counters across every shard
        (advisory reads, stats-grade like the ``stats`` property)."""
        hot_entries = hot_bytes = cold_entries = 0
        hot_hits = cold_hits = promotions = demotions = 0
        for shard in list(self._shards.values()):
            hot_entries += len(shard.entries)
            cold_entries += len(shard.cold)
            hot_bytes += shard.hot_bytes
            hot_hits += shard.stats["hot_hits"]
            cold_hits += shard.stats["cold_hits"]
            promotions += shard.stats["promotions"]
            demotions += shard.stats["demotions"]
        cold = (self._cold.stats() if self._cold is not None else
                {"puts": 0, "gets": 0, "deletes": 0, "entries": 0, "bytes": 0})
        return {"kind": self._cold.kind if self._cold is not None else None,
                "budget_bytes": self._cold_budget,
                "hot": {"entries": hot_entries, "bytes": hot_bytes},
                "cold": cold,
                "cold_entries": cold_entries,
                "hot_hits": hot_hits, "cold_hits": cold_hits,
                "promotions": promotions, "demotions": demotions}

    # -- elastic rebalancing ---------------------------------------------------

    def add_shard(self, shard_id: Optional[int] = None, *,
                  incremental: bool = True, drain: bool = True) -> ShardMigration:
        """Grow the ring by one shard (node join); migrates only the keys
        whose owner changed, epochs preserved.

        ``incremental=True`` (default) publishes the new ring immediately and
        opens a :class:`MigrationWindow`: moved keys cross on first access or
        via the inline drain, each under exactly the two involved shard locks.
        ``drain=False`` returns with the window still open (drive it with
        :meth:`migrate_step` / :meth:`drain_window`).  ``incremental=False``
        is the legacy stop-the-world path (all involved locks held for the
        whole move)."""
        with self._rebalance_lock:
            if self._window is not None:    # one window at a time
                self._drain_locked(self._window)
            if shard_id is None:
                shard_id = max(self._shards) + 1 if self._shards else 0
            shard_id = int(shard_id)
            if shard_id in self._ring.ids:
                raise ValueError(f"shard {shard_id} already on the ring")
            self._shards.setdefault(shard_id, Shard(shard_id))
            new_ring = self._ring.added(shard_id)
            if not incremental:
                return self._migrate(new_ring, added=(shard_id,), removed=())
            return self._open_window(new_ring, added=(shard_id,), removed=(),
                                     drain=drain)

    def remove_shard(self, shard_id: int, *, incremental: bool = True,
                     drain: bool = True) -> ShardMigration:
        """Shrink the ring by one shard (node leave); its keys migrate to the
        survivors that inherit its arcs, epochs preserved.  Window semantics
        as in :meth:`add_shard`; with ``drain=False`` the retired shard keeps
        its un-pulled entries until the window drains."""
        with self._rebalance_lock:
            if self._window is not None:
                self._drain_locked(self._window)
            shard_id = int(shard_id)
            if shard_id not in self._ring.ids:
                raise KeyError(f"shard {shard_id} is not on the ring")
            if len(self._ring) == 1:
                raise ValueError("cannot remove the last shard")
            new_ring = self._ring.removed(shard_id)
            if not incremental:
                return self._migrate(new_ring, added=(), removed=(shard_id,))
            return self._open_window(new_ring, added=(), removed=(shard_id,),
                                     drain=drain)

    # -- incremental arc handoff (the migration-window state machine) ----------

    def _open_window(self, new_ring: HashRing, *, added, removed,
                     drain: bool) -> ShardMigration:
        """Publish ``new_ring`` behind a migration window and plan the moves.

        Caller holds ``_rebalance_lock``.  The window is published *before*
        the ring so any op resolving under the new ring is guaranteed to see
        it; ops that locked under the old ring complete at the old owner
        (the entry is still there — moves need that same lock).  Planning
        then snapshots each source shard's names one lock at a time: the
        longest pause planning imposes on a concurrent op is one key-list
        copy, not a payload move."""
        old_ring = self._ring
        win = MigrationWindow(old_ring, new_ring)
        self._window = win
        self._ring = new_ring
        src_ids = tuple(removed) if removed else old_ring.ids
        moved: Dict[str, Tuple[int, int]] = {}
        epochs: Dict[str, int] = {}
        for sid in src_ids:
            src = self._shards[sid]
            self._lock_shard(src)
            try:
                names = set(src.entries) | set(src.cold) | set(src.gen) \
                    | set(src.directory)
                for name in names:
                    dst = new_ring.owner(name)
                    if dst == sid:
                        continue
                    with win.lock:
                        win.pending[name] = (sid, dst)
                    e = src.entries.get(name) or src.cold.get(name)
                    if e is not None:
                        moved[name] = (sid, dst)
                        epochs[name] = e.epoch
            finally:
                self._unlock_shard(src)
        total = sum(len(self._shards[i].entries) + len(self._shards[i].cold)
                    for i in set(old_ring.ids) | set(new_ring.ids))
        mig = ShardMigration(tuple(added), tuple(removed), moved, epochs,
                             total)
        win.migration = mig
        with win.lock:
            win.sealed = True
            empty = not win.pending
            pending = len(win.pending)
        trc = self.tracer
        if telemetry.TRACING and trc.enabled:
            # lifecycle breadcrumb: a window that then *stalls* emits no
            # further events, so the open mark is what a flight-recorder
            # dump shows the watchdog fired against
            trc.mark("migration", "window.open", pending=pending,
                     added=list(added), removed=list(removed))
        if empty:
            self._close_window(win)
        elif drain:
            self._drain_locked(win)
        return mig

    @property
    def migration_window(self) -> Optional[MigrationWindow]:
        """The currently-open incremental handoff window, or None."""
        return self._window

    def migrate_step(self, max_entries: int = 1) -> int:
        """Drive up to ``max_entries`` pending migrations of the open window
        (no-op without one); returns how many names remain pending."""
        win = self._window
        if win is None:
            return 0
        for _ in range(max_entries):
            with win.lock:
                item = next(iter(win.pending.items()), None)
            if item is None:
                break
            name, (src, dst) = item
            self._migrate_one(win, name, src, dst, pulled=False)
        with win.lock:
            return len(win.pending)

    def drain_window(self) -> Optional[ShardMigration]:
        """Complete any open migration window inline (idempotent; safe to
        race with access pulls) and return its migration report."""
        win = self._window
        if win is None:
            return None
        self._drain_locked(win)
        return win.migration

    def _drain_locked(self, win: MigrationWindow) -> None:
        while True:
            with win.lock:
                item = next(iter(win.pending.items()), None)
            if item is None:
                return
            name, (src, dst) = item
            self._migrate_one(win, name, src, dst, pulled=False)

    def _window_move(self, win: MigrationWindow,
                     name: str) -> Optional[Tuple[int, int]]:
        """``(src, dst)`` if ``name`` may still need to cross shards under
        ``win``, else None.  Before the planner seals the pending set,
        membership is decided by comparing the rings (a false positive just
        costs one empty pair-locked pull)."""
        if win.closed:
            return None
        if win.sealed:
            return win.pending.get(name)
        src = win.old_ring.owner(name)
        dst = win.new_ring.owner(name)
        return (src, dst) if src != dst else None

    def _window_pending(self, name: str) -> bool:
        win = self._window
        return win is not None and self._window_move(win, name) is not None

    def _settle(self, win: MigrationWindow, name: str) -> Optional[int]:
        """Ensure ``name`` is on its new-ring owner before an op proceeds.

        Returns None in the common case (nothing to move, or the pull
        completed).  Returns a shard id to serve from when this thread is
        already inside an operation holding one of the pair's locks (the
        cache composes store ops re-entrantly): pulling here would acquire
        the pair out of order, and serving in place is correct — the entry
        is the single authoritative copy on whichever side it sits, and no
        other thread can move it while this thread holds that lock.  The
        new-owner check matters during the brief unsealed window phase,
        where _window_move decides by ring comparison and still reports a
        move for a name that has already crossed: without it, a re-entrant
        op holding the new owner's lock would re-enter _migrate_one and
        take the source lock second — a lock-order inversion that can
        deadlock against a concurrent puller of the same shard pair."""
        mv = self._window_move(win, name)
        if mv is None:
            return None
        if self._shards[mv[0]].lock._is_owned():
            return mv[0]
        if self._shards[mv[1]].lock._is_owned():
            return mv[1]
        self._migrate_one(win, name, mv[0], mv[1], pulled=True)
        return None

    def _migrate_one(self, win: MigrationWindow, name: str, src_id: int,
                     dst_id: int, *, pulled: bool) -> None:
        """Move one name across shards under exactly the two involved locks
        (sorted id order; the checker's handoff exemption).  Entry (hot or
        cold index), delete-era generation and directory record cross
        together, so a concurrent cache write never sees the entry without
        its holders.  Idempotent: a racer that loses finds nothing at the
        source and only drops the pending record."""
        if src_id == dst_id:
            return
        src, dst = self._shards[src_id], self._shards[dst_id]
        first, second = (src, dst) if src.id < dst.id else (dst, src)
        ck = self.checker
        checking = stepcheck.CHECKING and ck.enabled
        if checking:
            ck.handoff_begin()
        self._lock_shard(first)
        self._lock_shard(second)
        try:
            hook = self._migrate_entry_hook
            if hook is not None:
                hook(name)
            nb = 0
            e = src.entries.pop(name, None)
            if e is not None:
                dst.entries[name] = e
                nb = e.hot_nbytes or _nbytes(e.value)
                if self._cold is not None:
                    src.hot_bytes -= e.hot_nbytes
                    dst.hot_bytes += e.hot_nbytes
            else:
                e = src.cold.pop(name, None)
                if e is not None:
                    dst.cold[name] = e
                    nb = e.cold_bytes
            moved_entry = e is not None
            if moved_entry:
                src.stats["migrated_out"] += 1
                src.stats["migrated_bytes"] += nb
                dst.stats["migrated_in"] += 1
            g = src.gen.pop(name, None)
            if g is not None:
                dst.gen[name] = max(dst.gen.get(name, 0), g)
            d = src.directory.pop(name, None)
            if d is not None:
                dst.directory.setdefault(name, set()).update(d)
        finally:
            self._unlock_shard(second)
            self._unlock_shard(first)
            if checking:
                ck.handoff_end()
        closed = False
        with win.lock:
            win.pending.pop(name, None)
            if moved_entry:
                win.entries_moved += 1
                win.bytes_moved += nb
                if pulled:
                    win.pulled += 1
            if win.sealed and not win.pending and not win.closed:
                win.closed = True
                closed = True
        trc = self.tracer
        if telemetry.TRACING and trc.enabled and moved_entry:
            trc.count("migration.entries")
            trc.count("migration.bytes", nb)
        if closed:
            self._close_window(win)

    def _close_window(self, win: MigrationWindow) -> None:
        t_close = time.perf_counter()
        dt = t_close - win.t_open
        m = win.migration
        if m is not None:
            m.bytes_moved = win.bytes_moved
            m.window_s = dt
            m.pulled = win.pulled
        self._note_migration(windows=1, entries_moved=win.entries_moved,
                             bytes_moved=win.bytes_moved, pulled=win.pulled,
                             window_s=dt)
        self._window = None
        trc = self.tracer
        if telemetry.TRACING and trc.enabled:
            trc.add_span("migration", "store.migration_window", win.t_open,
                         t_close, {"entries": win.entries_moved,
                                   "bytes": win.bytes_moved,
                                   "pulled": win.pulled})

    def _note_migration(self, **deltas) -> None:
        with self._mig_lock:
            for key, v in deltas.items():
                self._migration_totals[key] += v

    def migration_totals(self) -> Dict[str, Any]:
        """Cumulative rebalancing cost across this store's lifetime (both
        window and stop-the-world paths), plus the live window state —
        the ``rebalance`` section of ``ft.metrics_payload``."""
        with self._mig_lock:
            out: Dict[str, Any] = dict(self._migration_totals)
        win = self._window
        out["open"] = win is not None and not win.closed
        out["pending"] = win.remaining if win is not None else 0
        return out

    def _migrate(self, new_ring: HashRing, *, added, removed) -> ShardMigration:
        """Move every entry/generation/directory record whose owner changed.

        Caller holds ``_rebalance_lock``.  All involved shard locks are taken
        in sorted id order; the new ring is published before any lock is
        released, so concurrent ops either complete under the old topology or
        observe the new ring when they validate after locking.
        """
        old_ring = self._ring
        ids = sorted(set(old_ring.ids) | set(new_ring.ids))
        shards = [self._shards[i] for i in ids]
        ck = self.checker
        checking = stepcheck.CHECKING and ck.enabled
        if checking:
            ck.rebalance_begin()
        t0 = time.perf_counter()
        for s in shards:
            self._lock_shard(s)
        try:
            moved: Dict[str, Tuple[int, int]] = {}
            epochs: Dict[str, int] = {}
            bytes_moved = 0
            total = sum(len(s.entries) + len(s.cold) for s in shards)
            for s in shards:
                for name in list(s.entries):
                    owner = new_ring.owner(name)
                    if owner == s.id:
                        continue
                    dst = self._shards[owner]
                    e = s.entries.pop(name)
                    dst.entries[name] = e          # epoch rides with the entry
                    nb = e.hot_nbytes or _nbytes(e.value)
                    if self._cold is not None:
                        s.hot_bytes -= e.hot_nbytes
                        dst.hot_bytes += e.hot_nbytes
                    moved[name] = (s.id, owner)
                    epochs[name] = e.epoch
                    bytes_moved += nb
                    if name in s.gen:
                        dst.gen[name] = max(dst.gen.get(name, 0), s.gen.pop(name))
                    if name in s.directory:
                        dst.directory[name] = s.directory.pop(name)
                    s.stats["migrated_out"] += 1
                    s.stats["migrated_bytes"] += nb
                    dst.stats["migrated_in"] += 1
                # cold entries move by index record only — the tier keys
                # payloads by (globally unique) name, so a shard handoff
                # never touches the backend
                for name in list(s.cold):
                    owner = new_ring.owner(name)
                    if owner == s.id:
                        continue
                    dst = self._shards[owner]
                    e = s.cold.pop(name)
                    dst.cold[name] = e
                    moved[name] = (s.id, owner)
                    epochs[name] = e.epoch
                    bytes_moved += e.cold_bytes
                    if name in s.gen:
                        dst.gen[name] = max(dst.gen.get(name, 0), s.gen.pop(name))
                    if name in s.directory:
                        dst.directory[name] = s.directory.pop(name)
                    s.stats["migrated_out"] += 1
                    s.stats["migrated_bytes"] += e.cold_bytes
                    dst.stats["migrated_in"] += 1
                # delete-era generations of names with no live entry follow
                # the ring too: a redeclare after migration must still start
                # strictly past the deleted era
                for name in list(s.gen):
                    owner = new_ring.owner(name)
                    if owner != s.id:
                        dst = self._shards[owner]
                        dst.gen[name] = max(dst.gen.get(name, 0), s.gen.pop(name))
                # defensive: orphan directory records (no entry) follow too
                for name in list(s.directory):
                    owner = new_ring.owner(name)
                    if owner != s.id:
                        self._shards[owner].directory[name] = s.directory.pop(name)
            self._ring = new_ring   # publish while every lock is still held
            window_s = time.perf_counter() - t0
            self._note_migration(windows=1, entries_moved=len(moved),
                                 bytes_moved=bytes_moved, pulled=0,
                                 window_s=window_s)
            return ShardMigration(tuple(added), tuple(removed), moved, epochs,
                                  total, bytes_moved, window_s, 0)
        finally:
            for s in reversed(shards):
                self._unlock_shard(s)
            if checking:
                ck.rebalance_end()

    # -- store-side delete hooks (cache coherence teardown) --------------------

    def add_delete_hook(self, hook: Callable[[str], None], *,
                        weak: bool = False) -> Callable[[str], None]:
        """Register ``hook(name)`` to fire inside :meth:`delete`, under the
        owning shard's lock.  The DSM cache registers its replica/directory
        teardown here, so a *direct* store delete (not via ``Session.delete``)
        also kills every phantom holder.

        ``weak=True`` holds a bound-method hook only weakly: a store outlives
        the sessions rolled over it (FT recovery adopts the surviving store),
        and a strong ref would pin every dead session's cache — and fan
        deletes out to it — for the store's lifetime."""
        self._delete_hooks.append(weakref.WeakMethod(hook) if weak else hook)
        return hook

    def _fire_delete_hooks(self, name: str) -> None:
        """Invoke live hooks; prune weak entries whose cache was collected."""
        dead = []
        for entry in list(self._delete_hooks):
            hook = entry() if isinstance(entry, weakref.WeakMethod) else entry
            if hook is None:
                dead.append(entry)
            else:
                hook(name)
        for entry in dead:
            self._delete_hooks.remove(entry)

    # -- declaration ----------------------------------------------------------

    def _sharding(self, spec: Optional[P]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec if spec is not None else P())

    def _num_words(self, shape, dtype) -> int:
        nbytes = int(np.prod(shape, dtype=np.int64)) * jnp.dtype(dtype).itemsize if shape else jnp.dtype(dtype).itemsize
        return max(1, (nbytes + WORD_BYTES - 1) // WORD_BYTES)

    @staticmethod
    def _fresh_epoch(shard: Shard, name: str) -> int:
        """Starting epoch for a (re-)declared name: strictly above every epoch
        the name has ever had (hot or demoted), so stale replicas can never
        validate."""
        prev = shard.gen.get(name, 0)
        e = shard.entries.get(name) or shard.cold.get(name)
        if e is not None:
            prev = max(prev, e.epoch + 1)
        return prev

    def def_global(self, name: str, value, *, spec: Optional[P] = None) -> str:
        """``DefGlobal(NAME, TYPE)`` — declare a shared variable and set it."""
        value = jnp.asarray(value)
        with self._locked_alloc():
            slot = self._alloc.alloc_field(
                GLOBALS_OBJECT_ID, self._num_words(value.shape, value.dtype))
        placed = self._place(value, spec)
        with self.locked_owner(name) as shard:
            self._install(shard, GlobalEntry(name, slot, self._sharding(spec),
                                             placed,
                                             epoch=self._fresh_epoch(shard, name),
                                             spec=spec))
        return name

    def new_array(self, name: str, shape, dtype=jnp.float32, *, spec: Optional[P] = None) -> str:
        """``NewArray<TYPE>(n)`` — allocate a zeroed shared array."""
        with self._locked_alloc():
            oid = self._alloc.new_object()
            slot = self._alloc.alloc_field(oid, self._num_words(shape, dtype))
        placed = self._place(jnp.zeros(shape, dtype), spec)
        with self.locked_owner(name) as shard:
            self._install(shard, GlobalEntry(name, slot, self._sharding(spec),
                                             placed,
                                             epoch=self._fresh_epoch(shard, name),
                                             spec=spec))
        return name

    def new_object(self, name: str, fields: Dict[str, Any], *, specs: Optional[Dict[str, P]] = None) -> str:
        """``NewObj`` — a shared object: a pytree of fields under one object_id."""
        specs = specs or {}
        placed = {}
        words = 0
        for fname, fval in fields.items():
            fval = jnp.asarray(fval)
            words += self._num_words(fval.shape, fval.dtype)
            placed[fname] = self._place(fval, specs.get(fname))
        with self._locked_alloc():
            oid = self._alloc.new_object()
            slot = self._alloc.alloc_field(oid, words)
        with self.locked_owner(name) as shard:
            self._install(shard, GlobalEntry(name, slot, None, placed,
                                             epoch=self._fresh_epoch(shard, name),
                                             field_specs=dict(specs)))
        return name

    def delete(self, name: str) -> None:
        """``DelArray`` / ``DelObj``.  Records the retired epoch so a later
        re-declaration of the same name starts strictly past it, and fires
        the registered delete hooks (cache replica + directory teardown)
        under the owning shard's lock.  A demoted entry is deleted without
        loading its payload back — only the tier slot is reclaimed."""
        with self.locked_entry(name) as (shard, e):
            if shard.entries.pop(name, None) is not None:
                if self._cold is not None:
                    shard.hot_bytes -= e.hot_nbytes
            elif shard.cold.pop(name, None) is not None:
                self._cold.delete(name)
            shard.gen[name] = max(shard.gen.get(name, 0), e.epoch + 1)
            shard.directory.pop(name, None)
            self._fire_delete_hooks(name)

    # -- access (the DSM-internal-layer Get/Set of Table 1) -------------------

    def _place(self, value, spec: Optional[P]):
        if self.mesh is None:
            return value
        return jax.device_put(value, self._sharding(spec))

    def get(self, name: str, *, owner: Optional[OwnerHandle] = None):
        trc = self.tracer
        tracing = telemetry.TRACING and trc.enabled
        t0 = time.perf_counter() if tracing else 0.0
        with self.locked_entry(name, owner) as (shard, e):
            promoted = self._cold is not None and e.value is None
            if promoted:
                self._promote(shard, e)
            # capture the value before rebalancing the budget: if every older
            # hot entry is non-demotable, _maybe_demote's only victim is the
            # entry being served, and e.value goes back to None under us
            value, sid = e.value, shard.id
            shard.stats["get"] += 1
            shard.stats["bytes_get"] += _nbytes(value)
            shard.stats["transfers"] += self._transfer_count(value)
            if promoted:
                self._maybe_demote(shard)
        if tracing:
            trc.store_op("get", sid, t0, name=name)
        return value

    def set(self, name: str, value, *, bump_epoch: bool = True,
            owner: Optional[OwnerHandle] = None) -> None:
        trc = self.tracer
        tracing = telemetry.TRACING and trc.enabled
        t0 = time.perf_counter() if tracing else 0.0
        with self.locked_entry(name, owner) as (shard, e):
            if self._cold is not None and e.value is None:
                # Set overwrites the whole value: reclaim the tier slot but
                # skip loading the payload it is about to replace
                self._promote(shard, e, load=False)
            if isinstance(e.value, dict) or (e.value is None
                                             and isinstance(value, dict)):
                specs = e.field_specs or {}
                e.value = {k: self._place(jnp.asarray(v), specs.get(k))
                           for k, v in value.items()}
            else:
                value = jnp.asarray(value)
                if e.sharding is not None:
                    value = jax.device_put(value, e.sharding)
                e.value = value
            if bump_epoch:
                e.epoch += 1
            # account bytes before _note_resize: its demotion pass may spill
            # this very entry, and a demoted value reads as zero bytes
            shard.stats["set"] += 1
            shard.stats["bytes_set"] += _nbytes(e.value)
            shard.stats["transfers"] += self._transfer_count(e.value)
            sid = shard.id
            if self._cold is not None:
                self._note_resize(shard, e)
        if tracing:
            trc.store_op("set", sid, t0, name=name)

    def mget(self, names, *, owners=None) -> list:
        """``MGet`` — batched get, one logical round trip *per shard touched*
        (names are grouped by owner, each group read under one lock hold).

        ``owners`` is an optional sequence of :class:`OwnerHandle` (or None)
        aligned with ``names``; current handles skip that name's hash+bisect.
        """
        trc = self.tracer
        tracing = telemetry.TRACING and trc.enabled
        t0 = time.perf_counter() if tracing else 0.0
        names = list(names)
        if owners is not None:
            owners = list(owners)
            if len(owners) != len(names):
                raise ValueError(
                    f"owners must align with names: got {len(owners)} handles "
                    f"for {len(names)} names")
        vals: list = [None] * len(names)
        ring = self._ring
        groups: Dict[int, List[int]] = {}
        for i, n in enumerate(names):
            h = owners[i] if owners is not None else None
            groups.setdefault(self._resolve_owner(ring, n, h), []).append(i)
        for sid, idxs in groups.items():
            shard = self._shards[sid]
            stragglers: List[int] = []
            self._lock_shard(shard)
            try:
                got_bytes = 0
                served = 0
                for i in idxs:
                    e = shard.entries.get(names[i])
                    if e is None:   # migrated (or missing) — retry per name
                        stragglers.append(i)
                        continue
                    vals[i] = e.value
                    got_bytes += _nbytes(e.value)
                    served += 1
                if served:
                    shard.stats["get"] += 1
                    shard.stats["transfers"] += 1
                    shard.stats["bytes_get"] += got_bytes
            finally:
                self._unlock_shard(shard)
            for i in stragglers:
                vals[i] = self.get(names[i])
        if tracing:
            t1 = time.perf_counter()
            trc.add_span("store-op", "store.mget", t0, t1,
                         {"names": len(names), "shards": len(groups)})
            trc.observe("store.mget", (t1 - t0) * 1e6)
        return vals

    def inc(self, name: str, amount=1, *, owner: Optional[OwnerHandle] = None):
        """Atomic increment (Table 1) — skips the cache layer by contract.

        Serialised under the *owning shard's* lock (increments to names on
        different shards proceed concurrently), re-placed with the entry's
        declared spec, and accounted like any other DSM write.
        """
        trc = self.tracer
        tracing = telemetry.TRACING and trc.enabled
        t0 = time.perf_counter() if tracing else 0.0
        with self.locked_entry(name, owner) as (shard, e):
            if self._cold is not None and e.value is None:
                self._promote(shard, e)
            e.value = self._place(jnp.asarray(e.value) + amount, e.spec)
            e.epoch += 1
            # capture before _note_resize: its demotion pass may pick this
            # very entry as the victim and null e.value out
            value, sid = e.value, shard.id
            shard.stats["inc"] += 1
            shard.stats["bytes_set"] += _nbytes(value)
            shard.stats["transfers"] += self._transfer_count(value)
            if self._cold is not None:
                self._note_resize(shard, e)
        if tracing:
            trc.store_op("inc", sid, t0, name=name)
        return value

    def epoch(self, name: str) -> int:
        with self.locked_entry(name) as (_, e):
            return e.epoch

    def address(self, name: str) -> int:
        with self.locked_entry(name) as (_, e):
            return e.slot.address

    def names(self):
        # every shard, not just ring members: during an open remove-window
        # the retired shard still holds its un-pulled entries (an entry
        # lives in exactly one shard dict, so no name appears twice).
        # list() snapshots the dict — add_shard can insert concurrently
        out: List[str] = []
        for shard in list(self._shards.values()):
            with shard.lock:
                out.extend(shard.entries)
                out.extend(shard.cold)
        return out

    # -- stats / introspection -------------------------------------------------

    @property
    def stats(self) -> Dict[str, int]:
        """Aggregate op counters across every shard (retired shards included,
        so counters never run backwards across a rebalance)."""
        total = _fresh_stats()
        for shard in list(self._shards.values()):
            for key, v in shard.stats.items():
                total[key] += v
        return total

    def shard_stats(self) -> Dict[int, Dict[str, Any]]:
        """Per-shard op counters + entry count, keyed by shard id (active
        ring members only)."""
        out: Dict[int, Dict[str, Any]] = {}
        for sid in self._ring.ids:
            shard = self._shards[sid]
            with shard.lock:
                row = dict(shard.stats)
                row["names"] = len(shard.entries) + len(shard.cold)
            out[sid] = row
        return out

    def metrics(self) -> Dict[str, Any]:
        """Aggregate counters under the canonical (normalized) key set —
        :data:`repro.core.telemetry.STORE_METRIC_KEYS`.  The raw ``stats``
        property keeps the legacy singular-verb keys as a deprecated view."""
        return telemetry.normalize_store_stats(self.stats)

    def shard_metrics(self) -> Dict[int, Dict[str, Any]]:
        """Per-shard :meth:`metrics` rows (normalized ``shard_stats``)."""
        return {sid: telemetry.normalize_store_stats(row)
                for sid, row in self.shard_stats().items()}

    @property
    def _entries(self) -> Dict[str, GlobalEntry]:
        """Merged name→entry view across shards (read-only compatibility with
        the flat store; mutate through the store API, not this view)."""
        merged: Dict[str, GlobalEntry] = {}
        for shard in list(self._shards.values()):
            merged.update(shard.cold)
            merged.update(shard.entries)
        return merged

    def _transfer_count(self, value) -> int:
        """How many physical transfers a get/set of `value` costs under the
        current granularity — the quantity Fig. 3 is about."""
        leaves = jax.tree.leaves(value)
        if self.granularity == "coarse":
            return len(leaves)  # one package-aligned bulk transfer per leaf
        # fine-grained: one word-sized KV op per word
        return int(sum(max(1, _nbytes(l) // WORD_BYTES) for l in leaves))
