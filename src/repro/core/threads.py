"""Distributed threads — STEP §4.2, in host form and SPMD form.

**Host form** (the paper's programming model, used by the analytics examples
and the FT drills): :class:`DThread` wraps a ``thread_proc(tid, param)`` entry
function; :class:`DThreadPool` plays the master — it places threads on logical
*nodes*, starts them, joins them, and can kill a node to simulate failure.
State mirrors the paper (``GetState`` → alive/completed, plus ``lost`` after a
simulated node failure).

**SPMD form** (the production path): ``spmd_threads`` adapts the same
``thread_proc`` shape to a ``shard_map`` over the mesh — one logical STEP
thread per mesh position, ``tid = lax.axis_index`` — which is how the
technique scales to a 512-chip multi-pod mesh.  A jitted step's collectives
are the barrier; the accumulator is the communication substrate.
"""

from __future__ import annotations

import threading
import traceback
from enum import Enum
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import axis_size, shard_map


class ThreadState(str, Enum):
    CREATED = "created"
    ALIVE = "alive"
    COMPLETED = "completed"
    FAILED = "failed"    # raised an exception
    LOST = "lost"        # node failure (simulated)


class DThread:
    """Paper API: ``DThread(func, node_id, param)`` with ``GetState()``."""

    def __init__(self, func: Callable, node_id: int, param: Any = None, tid: Optional[int] = None):
        self.func = func
        self.node_id = node_id
        self.param = param
        self.tid = tid
        self.state = ThreadState.CREATED
        self.result: Any = None
        self.error: Optional[BaseException] = None
        self._thread: Optional[threading.Thread] = None
        self._kill_event = threading.Event()

    def start(self) -> None:
        def runner():
            self.state = ThreadState.ALIVE
            try:
                self.result = self.func(self.tid, self.param)
                if self._kill_event.is_set():
                    self.state = ThreadState.LOST
                else:
                    self.state = ThreadState.COMPLETED
            except _NodeKilled:
                self.state = ThreadState.LOST
            except BaseException as e:  # noqa: BLE001 — faithfully record
                self.error = e
                self.state = ThreadState.FAILED
                traceback.print_exc()

        self._thread = threading.Thread(target=runner, daemon=True)
        self._thread.start()

    def join(self, timeout: Optional[float] = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)

    def get_state(self) -> ThreadState:
        return self.state

    GetState = get_state


class _NodeKilled(Exception):
    """Raised inside a thread whose node was failed by the pool."""


class DThreadPool:
    """The master's thread-management role: create/start/join/kill threads.

    ``checkpoint_guard(tid)`` should be called by thread_procs at barrier
    boundaries; it raises inside threads whose node has been killed, which is
    how a node failure manifests to the program (the FT layer then recovers).
    """

    def __init__(self, n_nodes: int, threads_per_node: int):
        self.n_nodes = n_nodes
        self.threads_per_node = threads_per_node
        self.threads: List[DThread] = []
        self._killed_nodes: set[int] = set()

    @property
    def n_threads(self) -> int:
        return self.n_nodes * self.threads_per_node

    def create_threads(self, func: Callable, param: Any = None) -> List[DThread]:
        self.threads = []
        tid = 0
        for node in range(self.n_nodes):
            for _ in range(self.threads_per_node):
                self.threads.append(DThread(func, node, param, tid=tid))
                tid += 1
        return self.threads

    def start_all(self) -> None:
        for t in self.threads:
            if t.node_id not in self._killed_nodes:
                t.start()

    def join_all(self, timeout: Optional[float] = None) -> None:
        for t in self.threads:
            t.join(timeout)

    def kill_node(self, node_id: int) -> List[int]:
        """Simulate a node failure; returns the tids lost."""
        self._killed_nodes.add(node_id)
        lost = []
        for t in self.threads:
            if t.node_id == node_id and t.state in (ThreadState.ALIVE, ThreadState.CREATED):
                t._kill_event.set()
                lost.append(t.tid)
        return lost

    def checkpoint_guard(self, tid: int) -> None:
        t = self.threads[tid]
        if t._kill_event.is_set() or t.node_id in self._killed_nodes:
            raise _NodeKilled(f"node {t.node_id} failed")

    def healthy_nodes(self) -> List[int]:
        return [n for n in range(self.n_nodes) if n not in self._killed_nodes]

    def states(self) -> Dict[int, ThreadState]:
        return {t.tid: t.state for t in self.threads}


# ---------------------------------------------------------------------------
# SPMD adapter
# ---------------------------------------------------------------------------


def spmd_threads(
    thread_proc: Callable,
    mesh: Mesh,
    axis_names: Sequence[str],
    in_specs,
    out_specs,
    check_vma: bool = False,
):
    """Run ``thread_proc(tid, *locals) -> outputs`` as one STEP thread per mesh
    position over ``axis_names``, via ``shard_map``.

    Inside, ``tid`` is the linearised mesh index — the distributed analogue of
    the paper's thread identifier argument.
    """

    def body(*local_args):
        tid = 0
        for name in axis_names:
            tid = tid * axis_size(name) + jax.lax.axis_index(name)
        return thread_proc(tid, *local_args)

    return shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_vma=check_vma)
