"""Sparse accumulate wire format (§5.2): blocked top-k (index, value) pairs.

The paper represents a sparse vector as (index, non-zero element) pairs and
transfers those when they are cheaper than the dense vector.  On TPU we keep
the same decision rule but produce the pairs with a *blocked* top-k so shapes
stay static under jit: ``k`` is the per-contribution budget, spread over
128-lane-friendly blocks (``per_block = ceil(k / nblocks)`` entries selected
per block, no global sort).  When every block's nnz fits its per-block quota
the representation is lossless — exactly the condition under which the auto
mode may select it.

This module is the *dispatching layer* shared by both backends:

* :func:`blocked_topk_sparsify` routes to the Pallas
  :mod:`repro.kernels.topk_compress` kernel by default (interpret-mode
  fallback off-TPU) and keeps the jnp formulation as a tested reference
  (``impl="jnp"``).  Both produce the same :class:`SparsePairs` format.
* :class:`SparsePairs` is the one pair container used by the host
  ``DAddAccumulator`` and the SPMD collective — its static length
  (:func:`pair_capacity`) is what both backends' wire-traffic accounting is
  derived from.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

DEFAULT_BLOCK = 1024


# ---------------------------------------------------------------------------
# Selection layout: one formula, used by the sparsifier, the benefit rule and
# the traffic accounting on BOTH backends — keep them from drifting apart.
# ---------------------------------------------------------------------------


def block_layout(n: int, k: int, block: int = DEFAULT_BLOCK) -> tuple[int, int, int]:
    """``(nblocks, block_eff, per_block)`` of the blocked top-k selection.

    A length-``n`` vector is split into ``nblocks`` blocks of ``block_eff``
    elements; each block contributes its ``per_block`` largest-|x| entries.
    """
    n, k, block = int(n), int(k), int(block)
    if n <= 0:
        raise ValueError(f"vector length must be positive, got {n}")
    if k <= 0:
        raise ValueError(f"top-k budget must be positive, got {k}")
    block_eff = max(1, min(block, n))
    nblocks = -(-n // block_eff)
    per_block = min(block_eff, max(1, -(-k // nblocks)))
    return nblocks, block_eff, per_block


def pair_capacity(n: int, k: int, block: int = DEFAULT_BLOCK) -> int:
    """Static number of (index, value) pairs a budget-``k`` compression of a
    length-``n`` vector puts on the wire (``nblocks * per_block`` ≈ k).

    This is the figure wire-traffic accounting uses on both backends: under
    jit the pair arrays have exactly this length regardless of the data.
    """
    nblocks, _, per_block = block_layout(n, k, block)
    return nblocks * per_block


def default_auto_k(n: int) -> int:
    """Default budget for ``AccumMode.AUTO`` when none was given: ~V/4, so the
    pairs representation (2·capacity elements) stays under half the dense
    vector whenever it is selected.  Auto is lossless by construction, so a
    defaulted budget never changes results — only which wire format wins."""
    return max(1, int(n) // 4)


# ---------------------------------------------------------------------------
# The shared pair format
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclass
class SparsePairs:
    """Blocked top-k compression of one length-``n`` contribution.

    ``idx``/``vals`` have static length :func:`pair_capacity`; positions
    beyond a block's nnz carry ``(0, 0.0)`` and scatter-add as no-ops.
    Iterable as ``(idx, vals)`` for tuple-style call sites.
    """

    idx: jax.Array
    vals: jax.Array
    n: int  # dense vector length

    def tree_flatten(self):
        return (self.idx, self.vals), self.n

    @classmethod
    def tree_unflatten(cls, n, children):
        return cls(children[0], children[1], n)

    def __iter__(self):
        yield self.idx
        yield self.vals

    @property
    def num_pairs(self) -> int:
        """Pairs on the wire — the static capacity, not the data's nnz."""
        return int(self.idx.shape[-1])

    @property
    def wire_elements(self) -> int:
        """Wire cost in vector elements: one index + one value per pair."""
        return 2 * self.num_pairs

    def densify(self) -> jax.Array:
        """Scatter-add the pairs back into a dense length-``n`` vector."""
        return densify(self.idx, self.vals, self.n)


# ---------------------------------------------------------------------------
# Sparsifiers
# ---------------------------------------------------------------------------


def topk_sparsify(x: jax.Array, k: int):
    """(indices, values) of the k largest-magnitude entries of a 1-D x —
    the unblocked (global sort) form, kept for small vectors and tests."""
    _, idx = jax.lax.top_k(jnp.abs(x), k)
    return idx, x[idx]


def _blocked_topk_jnp(x: jax.Array, nblocks: int, block_eff: int, per_block: int):
    """jnp reference path: same selection schedule as the Pallas kernel."""
    n = x.shape[0]
    pad = nblocks * block_eff - n
    xp = jnp.pad(x, (0, pad)).reshape(nblocks, block_eff)
    valid = jnp.arange(nblocks * block_eff).reshape(nblocks, block_eff) < n
    mag = jnp.where(valid, jnp.abs(xp), -1.0)
    _, idx = jax.lax.top_k(mag, per_block)                   # (nblocks, per_block)
    base = (jnp.arange(nblocks) * block_eff)[:, None]
    flat_idx = (idx + base).reshape(-1)
    vals = jnp.take_along_axis(xp, idx, axis=1).reshape(-1)
    ok = jnp.take_along_axis(mag, idx, axis=1).reshape(-1) >= 0
    return flat_idx, jnp.where(ok, vals, jnp.zeros((), x.dtype))


def blocked_topk_sparsify(x: jax.Array, k: int, block: int = DEFAULT_BLOCK, *,
                          impl: str = "pallas") -> SparsePairs:
    """Compress a 1-D ``x`` to :class:`SparsePairs` under budget ``k``.

    ``impl="pallas"`` (default) dispatches to the
    :mod:`repro.kernels.topk_compress` kernel — compiled on TPU, interpret
    mode elsewhere; ``impl="jnp"`` is the pure-jnp reference with the same
    selection schedule.  Lossless iff every block's nnz fits its per-block
    quota (in particular whenever ``nnz(x) <= per_block`` for every block).
    """
    n = x.shape[0]
    nblocks, block_eff, per_block = block_layout(n, k, block)
    if impl == "pallas":
        from repro.kernels.topk_compress.ops import topk_compress
        idx, vals = topk_compress(x, k_per_block=per_block, block_v=block_eff)
    elif impl == "jnp":
        idx, vals = _blocked_topk_jnp(x, nblocks, block_eff, per_block)
    else:
        raise ValueError(f"impl must be pallas|jnp, got {impl!r}")
    # normalise the padded tail: index 0 / value 0 is a harmless scatter-add
    in_range = idx < n
    return SparsePairs(jnp.where(in_range, idx, 0).astype(jnp.int32),
                       jnp.where(in_range, vals, jnp.zeros((), vals.dtype)), n)


def densify(idx: jax.Array, vals: jax.Array, n: int) -> jax.Array:
    """Scatter-add (index, value) pairs into a dense length-n vector."""
    return jnp.zeros((n,), vals.dtype).at[idx.reshape(-1)].add(vals.reshape(-1))


@partial(jax.jit, static_argnums=(1, 2, 3))
def _fused_accumulate_jnp(mat: jax.Array, nblocks: int, block_eff: int,
                          per_block: int) -> jax.Array:
    """jnp reference for the fused kernel: same selection + fold schedule."""
    n, v = mat.shape
    pad = nblocks * block_eff - v
    xp = jnp.pad(mat, ((0, 0), (0, pad))).astype(jnp.float32)
    xp = xp.reshape(n, nblocks, block_eff)
    idx = jnp.broadcast_to(jnp.arange(block_eff), xp.shape)
    valid = (jnp.arange(nblocks * block_eff) < v).reshape(1, nblocks, block_eff)
    mag = jnp.where(valid, jnp.abs(xp), -1.0)
    if per_block < block_eff:
        thr_mag, thr_pos = jax.lax.top_k(mag, per_block)     # ties → lowest idx
        thr_mag = thr_mag[..., -1:]
        thr_idx = thr_pos[..., -1:]
        sel = (mag > thr_mag) | ((mag == thr_mag) & (idx <= thr_idx))
    else:
        sel = jnp.broadcast_to(valid, xp.shape)
    contrib = jnp.where(sel & valid, xp, 0.0)
    acc = contrib[0]
    for t in range(1, n):                     # left-fold: same order as kernel
        acc = acc + contrib[t]
    return acc.reshape(-1)[:v].astype(mat.dtype)


def blocked_topk_accumulate(mat: jax.Array, k: int, block: int = DEFAULT_BLOCK,
                            *, fused: bool = True,
                            impl: str = "pallas") -> jax.Array:
    """Sum of the budget-``k`` blocked top-k compressions of each row of a
    stacked (N, V) round — the accumulator's SPARSE/AUTO reduce.

    ``fused=True`` (default) merges selection with application: one
    :mod:`repro.kernels.accumulate.fused_scatter` launch, no pair arrays or
    dense per-thread intermediates (``impl="jnp"`` keeps the pure-jnp
    reference with the same selection + left-fold schedule).  ``fused=False``
    reproduces the historical compress→densify→add path (one
    :func:`blocked_topk_sparsify` per row, scatter-add of the concatenated
    pairs) — kept as the comparison baseline.  All four routes produce
    bit-exact identical results: selection is block-local with ties broken
    toward the lower index, and the fused left-fold matches the scatter-add's
    per-index association order.
    """
    n_rows, v = mat.shape
    nblocks, block_eff, per_block = block_layout(v, k, block)
    if not fused:
        pairs = [blocked_topk_sparsify(mat[t], k, block, impl=impl)
                 for t in range(n_rows)]
        return densify(jnp.concatenate([p.idx for p in pairs]),
                       jnp.concatenate([p.vals for p in pairs]), v)
    if impl == "pallas":
        from repro.kernels.accumulate.fused_scatter import fused_topk_scatter
        return fused_topk_scatter(mat, per_block=per_block, block_eff=block_eff)
    elif impl == "jnp":
        return _fused_accumulate_jnp(mat, nblocks, block_eff, per_block)
    raise ValueError(f"impl must be pallas|jnp, got {impl!r}")


def nnz(x: jax.Array) -> jax.Array:
    return jnp.sum((x != 0).astype(jnp.int32))


def sparse_beneficial(x: jax.Array, k: int, block: int = DEFAULT_BLOCK) -> jax.Array:
    """Paper's auto rule, blocked-selection aware: pairs win when the blocked
    top-k is lossless (every block's nnz fits its per-block quota) and the
    pairs are smaller than the dense vector (2·capacity < V)."""
    n = x.shape[0]
    nblocks, block_eff, per_block = block_layout(n, k, block)
    pad = nblocks * block_eff - n
    xp = jnp.pad(x, (0, pad)).reshape(nblocks, block_eff)
    per_block_nnz = jnp.sum((xp != 0).astype(jnp.int32), axis=1)
    cheaper = 2 * pair_capacity(n, k, block) < n
    return jnp.logical_and(jnp.all(per_block_nnz <= per_block), cheaper)


@partial(jax.jit, static_argnums=(1, 2))
def _all_beneficial(mat: jax.Array, k: int, block: int) -> jax.Array:
    return jnp.all(jax.vmap(lambda f: sparse_beneficial(f, k, block))(mat))


def sparse_beneficial_batch(vectors, k: int, block: int = DEFAULT_BLOCK) -> jax.Array:
    """The auto rule for a whole accumulator round in ONE jitted call: True
    iff *every* contribution is losslessly compressible AND cheaper.

    The host accumulator's round closes on the driver; evaluating the rule
    per contribution costs O(N) small device syncs per round.  Stacking the
    (same-shape, by the ragged-round contract) contributions and deciding
    under one jit collapses that to a single scalar sync."""
    mat = jnp.stack([jnp.asarray(v).reshape(-1) for v in vectors])
    return _all_beneficial(mat, int(k), int(block))
