"""Sparse vector representation for the accumulator's sparse/auto modes (§5.2).

The paper represents a sparse vector as (index, non-zero element) pairs and
transfers those when ``2 * nnz < V``.  On TPU we keep the same decision rule
but produce the pairs with a (blocked) top-k so shapes stay static under jit:
``k`` is the static per-device budget; when ``nnz <= k`` the representation is
lossless, which is exactly when the auto mode may select it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_sparsify(x: jax.Array, k: int):
    """Return (indices, values) of the k largest-magnitude entries of a 1-D x."""
    _, idx = jax.lax.top_k(jnp.abs(x), k)
    return idx, x[idx]


def blocked_topk_sparsify(x: jax.Array, k: int, block: int = 1024):
    """Per-block top-k — the TPU-friendly variant mirrored by
    :mod:`repro.kernels.topk_compress`.  Selects ceil(k/nblocks) per block so
    selection parallelises over lanes without a global sort.
    """
    n = x.shape[0]
    nblocks = max(1, (n + block - 1) // block)
    per_block = max(1, (k + nblocks - 1) // nblocks)
    pad = nblocks * block - n
    xp = jnp.pad(x, (0, pad)).reshape(nblocks, block)
    _, idx = jax.lax.top_k(jnp.abs(xp), per_block)          # (nblocks, per_block)
    base = (jnp.arange(nblocks) * block)[:, None]
    flat_idx = (idx + base).reshape(-1)
    vals = jnp.take_along_axis(xp, idx, axis=1).reshape(-1)
    # clamp padded positions to index 0 with value 0 (harmless scatter-add)
    valid = flat_idx < n
    return jnp.where(valid, flat_idx, 0), jnp.where(valid, vals, 0.0)


def densify(idx: jax.Array, vals: jax.Array, n: int) -> jax.Array:
    """Scatter-add (index, value) pairs into a dense length-n vector."""
    return jnp.zeros((n,), vals.dtype).at[idx.reshape(-1)].add(vals.reshape(-1))


def nnz(x: jax.Array) -> jax.Array:
    return jnp.sum((x != 0).astype(jnp.int32))


def sparse_beneficial(x: jax.Array, k: int, block: int = 1024) -> jax.Array:
    """Paper's auto rule, blocked-selection aware: pairs win when the blocked
    top-k is lossless (every block's nnz fits its per-block quota) and the
    pairs are smaller than the dense vector (2k < V)."""
    n = x.shape[0]
    nblocks = max(1, (n + block - 1) // block)
    per_block = max(1, (k + nblocks - 1) // nblocks)
    pad = nblocks * block - n
    xp = jnp.pad(x, (0, pad)).reshape(nblocks, block)
    per_block_nnz = jnp.sum((xp != 0).astype(jnp.int32), axis=1)
    return jnp.logical_and(jnp.all(per_block_nnz <= per_block), 2 * k < n)
