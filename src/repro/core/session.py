"""step.Session — the paper's Table 1 as ONE facade over DSM, threads and sync.

STEP's pitch is a single coherent interface: DSM manipulation (DefGlobal /
NewArray / NewObj / Get / Set / Inc / Accumulate), cluster & thread management
(create / start / join / fail), and synchronization (barrier / semaphore /
SSP clock).  This module is that interface.  A :class:`Session` owns the
:class:`~repro.core.dsm.GlobalStore`, the directory-based DSM cache, the sync
controller and the accumulator registry; shared data is declared through it
and handled via typed :class:`SharedRef` handles instead of string-keyed store
access at call sites.

Workloads are written once against the facade::

    sess = Session(backend="host", n_nodes=2, threads_per_node=2)
    grad = sess.new_array("grad", (d,))

    def thread_proc(ctx, xs, ys):          # ctx: tid / guard / iterate
        def step(theta):                   # one synchronous round
            total = grad.accumulate(local_grad(theta, xs, ys))
            return theta + lr * total
        return ctx.iterate(step, jnp.zeros((d,)), iters)

    thetas = sess.run(thread_proc, data=(x, y))

and execute unchanged on either substrate, selected at construction:

* ``backend="host"`` — :class:`HostBackend`: the paper's programming model.
  ``DThreadPool`` threads, blocking ``DAddAccumulator`` rounds, reads served
  through the write-invalidate DSM cache, barrier-based release.
* ``backend="spmd"`` — :class:`SpmdBackend`: one STEP thread per mesh position
  via ``shard_map``.  ``SharedRef.accumulate`` lowers to the reduce-scatter /
  all-gather collective schedule, ``SharedRef.get``/``set`` become the
  per-trace replicated value, and barriers are implicit in the collectives.

The bulk-synchronous contract shared by both backends: within ``thread_proc``,
``ref.set(v)`` must be called with a value that is identical across threads
(all threads re-derive the update from the accumulated total), which is what
makes the host path's N redundant writes and the SPMD path's replicated
update the same program.

Iteration is a framework primitive, not a Python loop: ``ctx.iterate(step,
carry, iters)`` (and the indexed ``ctx.fori``) runs one *logical* loop with
two lowerings — a plain ``ctx.guard()``-per-round loop on the host backend,
and a single ``lax.scan`` on the SPMD backend, so the lowered program (and
compile time) is O(1) in ``iters`` instead of O(iters) unrolled HLO.  The
shared-value dict is threaded through the scan carry, which is what keeps
``SharedRef.get/set/accumulate`` legal inside the step body.
"""

from __future__ import annotations

import threading
import time
import warnings
import weakref
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import obs as stepobs
from repro.check import checker as stepcheck
from repro.core import telemetry
from repro.core.accumulator import AccumMode, DAddAccumulator, accumulate as spmd_accumulate
from repro.core.cache import CacheStats, DSMCache
from repro.core.compat import make_mesh, shard_map
from repro.core.dsm import GlobalStore
from repro.core.sparse import default_auto_k, pair_capacity
from repro.core.sync import DBarrier, DSemaphore, SSPClock
from repro.core.threads import DThreadPool, ThreadState
from repro.data.pipeline import partition_rows


# ---------------------------------------------------------------------------
# Handles
# ---------------------------------------------------------------------------


class SharedRef:
    """Typed handle to one piece of shared data in a session's DSM.

    Table 1's access verbs live here: ``get``/``set``/``inc``/``accumulate``.
    Outside a worker they hit the store directly; inside ``Session.spawn`` they
    are routed through the active backend (cache-validated reads and blocking
    accumulator rounds on the host; traced replicated values and collectives
    under SPMD).
    """

    __slots__ = ("_session", "name", "_hcache")

    def __init__(self, session: "Session", name: str):
        self._session = session
        self.name = name
        self._hcache = None  # memoised OwnerHandle, refreshed on ring bumps

    def _owner(self):
        """This name's memoised :class:`~repro.core.shards.OwnerHandle`.

        Resolved lazily and refreshed (by atomic reference swap — handles are
        immutable, so concurrent readers see either the old or the new handle,
        never a torn one) whenever ``add_shard``/``remove_shard`` bumped the
        ring version.  Every hot ``get``/``set``/``inc`` through this ref then
        skips the per-op blake2b + bisect in the store."""
        store = self._session.store
        handle = self._hcache
        if handle is None or handle.version != store.ring_version:
            handle = store.owner_handle(self.name)
            self._hcache = handle
        return handle

    def get(self):
        """``Get`` — current value (cache-validated inside host workers)."""
        return self._session._read(self.name, owner=self._owner())

    def set(self, value) -> None:
        """``Set`` — write-through + invalidate.  Inside a worker this is the
        bulk-synchronous collective write: every thread passes the identical
        re-derived value."""
        self._session._write(self.name, value, owner=self._owner())

    def inc(self, amount=1):
        """``Inc`` — atomic increment; bypasses the cache layer (§5.1).

        N threads calling ``inc(a)`` advance the value by ``N·a`` on both
        backends.  The *return value's* intermediate is backend-specific:
        the host returns each thread's own post-increment snapshot (atomic
        RMW order), SPMD returns the replicated round total — treat the
        return as "some current value", not a unique ticket."""
        return self._session._inc(self.name, amount, owner=self._owner())

    def accumulate(self, local, *, mode: Optional[AccumMode | str] = None,
                   k: Optional[int] = None):
        """``Accumulate`` — contribute this thread's vector, return the global
        sum.  A synchronization point across all threads (§4.4)."""
        return self._session._accumulate(self.name, local, mode, k)

    def delete(self) -> None:
        """``DelArray`` / ``DelObj`` — also purges cache replicas and
        directory records so a re-declared name can never serve the
        deleted-era value."""
        self._session.delete(self.name)

    @property
    def address(self) -> int:
        """64-bit DSM address (``object_id ++ field_id``)."""
        return self._session.store.address(self.name)

    @property
    def epoch(self) -> int:
        return self._session.store.epoch(self.name)

    @property
    def shard(self) -> int:
        """Owning shard id under the store's consistent-hash ring."""
        return self._session.store.shard_of(self.name)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"SharedRef({self.name!r}, addr=0x{self.address:x})"

    # paper-cased aliases
    Get = get
    Set = set
    Inc = inc
    Accumulate = accumulate


# ---------------------------------------------------------------------------
# Worker contexts (what thread_proc sees)
# ---------------------------------------------------------------------------


class WorkerCtx:
    """One STEP thread's view of the session: identity, sync, ref-op routing,
    and the iteration engine.

    Subclasses plug in the transport (``read``/``write``/``inc``/
    ``accumulate``) and the physical lowering of :meth:`fori`; everything a
    ``thread_proc`` calls is declared here, so workload code is written once
    against this contract and runs on either backend.
    """

    def __init__(self, session: "Session", tid, n_threads: int, node_id):
        self._session = session
        self.tid = tid
        self.n_threads = n_threads
        self.node_id = node_id

    # -- sync ----------------------------------------------------------------

    def guard(self) -> None:
        """Checkpoint boundary: raise inside threads whose node was failed.
        A no-op where node failure is handled below this layer."""
        return None

    def barrier(self, timeout: Optional[float] = None) -> bool:
        return True

    # -- tracing -------------------------------------------------------------

    def span(self, name: str, **args):
        """A user-labelled span (category ``app-round``) on this thread's
        timeline — the hook the analytics apps use to mark one algorithm
        round.  A real span on the host backend; a no-op under SPMD, where
        the step body is traced once and per-round host timestamps would
        lie about device execution."""
        return telemetry.NULL_SPAN

    # -- iteration engine ----------------------------------------------------

    def iterate(self, step: Callable, carry, iters: int):
        """Run ``carry = step(carry)`` for ``iters`` synchronous rounds.

        The canonical per-thread loop: one *logical* construct with two
        physical lowerings (a guarded Python loop on the host backend, one
        ``lax.scan`` under SPMD — O(1) lowered program size in ``iters``).
        ``SharedRef.get/set/accumulate`` are legal inside ``step``; the carry
        must be a pytree of fixed shape/dtype across rounds (or ``None``).
        """
        return self.fori(lambda i, c: step(c), carry, iters)

    def fori(self, step: Callable, carry, iters: int):
        """Indexed variant: ``carry = step(i, carry)`` for i in [0, iters)."""
        raise NotImplementedError

    # -- ref-op routing (transport is backend-specific; `owner` is the ref's
    # memoised OwnerHandle, meaningful only on store-backed transports) -------

    def read(self, name: str, owner=None):
        raise NotImplementedError

    def write(self, name: str, value, owner=None) -> None:
        raise NotImplementedError

    def inc(self, name: str, amount, owner=None):
        raise NotImplementedError

    def accumulate(self, name: str, local, mode: AccumMode, k: Optional[int]):
        raise NotImplementedError


class HostWorkerCtx(WorkerCtx):
    """One DThread's view: cache-validated reads, blocking accumulator rounds,
    and a plain ``guard()``-per-round iteration loop."""

    def __init__(self, session: "Session", backend: "HostBackend", tid: int):
        super().__init__(session, tid, backend.n_threads,
                         tid // backend.pool.threads_per_node)
        self._backend = backend

    def guard(self) -> None:
        """Raise inside threads whose node was failed (checkpoint boundary)."""
        self._backend.pool.checkpoint_guard(self.tid)

    def barrier(self, timeout: Optional[float] = None) -> bool:
        return self._backend.run_barrier.enter(timeout)

    def span(self, name: str, **args):
        trc = self._session.tracer
        if telemetry.TRACING and trc.enabled:
            return trc.span("app-round", name, **args)
        return telemetry.NULL_SPAN

    # -- iteration: the paper's programming model, round by round ------------

    def fori(self, step: Callable, carry, iters: int):
        for i in range(int(iters)):
            self.guard()
            carry = step(i, carry)
        return carry

    # -- ref-op routing ------------------------------------------------------

    def read(self, name: str, owner=None):
        return self._session._cached_read(self.node_id, name, owner=owner)

    def write(self, name: str, value, owner=None) -> None:
        self._session._cached_write(self.node_id, name, value, owner=owner)

    def inc(self, name: str, amount, owner=None):
        # atomicity comes from the owning shard's lock inside store.inc —
        # increments to names on different shards proceed concurrently
        return self._session.cache.atomic_inc(name, amount, owner=owner)

    def accumulate(self, name: str, local, mode: AccumMode, k: Optional[int]):
        accu = self._backend.accumulator(self._session, name, mode, k)
        accu.accumulate(local)
        return self.read(name)


class SpmdWorkerCtx(WorkerCtx):
    """The traced per-mesh-position view: shared refs are replicated values
    threaded through the trace; barriers are the collectives themselves."""

    def __init__(self, session: "Session", backend: "SpmdBackend", tid,
                 values: Dict[str, Any]):
        super().__init__(session, tid, backend.n_threads, tid)
        self._backend = backend
        self.values = values
        self._accum_repeat = 1  # trip-count multiplier for traffic accounting
        # AUTO branch slots: one per auto-accumulate call site, carrying the
        # *device-side* count of rounds that took the sparse branch plus the
        # static per-round costs of either branch.  `join` settles the
        # trace-time dense upper bound against these counts (ROADMAP item).
        self._auto_slots: List[Dict[str, Any]] = []

    # -- iteration: one lax.scan, O(1) lowered size in `iters` ---------------

    def fori(self, step: Callable, carry, iters: int):
        iters = int(iters)
        if iters <= 0:
            return carry
        trc = self._session.tracer
        if telemetry.TRACING and trc.enabled:
            # fori runs at *trace* time under SPMD: account the scan site and
            # its executed trip count (nested loops multiply through
            # _accum_repeat) — per-trip host spans would not exist anyway.
            trc.count("spmd.scan_sites")
            trc.count("spmd.scan_trips", iters * self._accum_repeat)
        # The shared-value dict rides in the scan carry: ref.get/set/accumulate
        # inside `step` read and write the scanned copy, so shared state
        # advances per round exactly as it does on the host backend.
        values0 = jax.tree.map(jnp.asarray, dict(self.values))
        carry0 = jax.tree.map(jnp.asarray, carry)
        slot_meta: List[Dict[str, Any]] = []

        def body(state, i):
            inner_carry, values = state
            outer_values, self.values = self.values, dict(values)
            outer_repeat = self._accum_repeat
            self._accum_repeat = outer_repeat * iters  # nested loops compose
            base = len(self._auto_slots)
            try:
                new_carry = step(i, inner_carry)
                new_values = dict(self.values)
            finally:
                self.values = outer_values
                self._accum_repeat = outer_repeat
            # AUTO branch counters born inside the body ride the scan's
            # stacked outputs; summed below they report how many of the
            # `iters` executions of each call site took the sparse branch
            born = self._auto_slots[base:]
            del self._auto_slots[base:]
            slot_meta[:] = [{k: v for k, v in s.items() if k != "count"}
                            for s in born]
            return (new_carry, new_values), tuple(s["count"] for s in born)

        (carry, values), counts = jax.lax.scan(body, (carry0, values0),
                                               jnp.arange(iters))
        for meta, per_iter in zip(slot_meta, counts):
            self._auto_slots.append(dict(meta, count=jnp.sum(per_iter)))
        self.values.clear()
        self.values.update(values)
        return carry

    # -- ref-op routing (replicated traced values: `owner` has no transport
    # to shortcut and is ignored) --------------------------------------------

    def read(self, name: str, owner=None):
        return self.values[name]

    def write(self, name: str, value, owner=None) -> None:
        self.values[name] = jax.tree.map(jnp.asarray, value)

    def inc(self, name: str, amount, owner=None):
        # `Inc` is per-thread: N threads calling inc(a) must advance the value
        # by N·a, exactly as N atomic increments do on the host backend.  The
        # replicated value is written once per trace, so the per-thread amounts
        # are psum'd over the mesh axis and applied in one replicated update.
        total = jax.lax.psum(jnp.asarray(amount), self._backend.axis)
        self.values[name] = jnp.asarray(self.values[name]) + total
        return self.values[name]

    def accumulate(self, name: str, local, mode: AccumMode, k: Optional[int]):
        vec = local if local.ndim else local[None]   # collectives want rank>=1
        shard = self._session.store.shard_of(name)
        if mode == AccumMode.AUTO:
            # the collective's lax.cond branch is a runtime decision: record a
            # device-side counter (0/1 this execution; ctx.fori sums it across
            # scan rounds) so join() can settle the trace-time dense bound to
            # the branch actually taken, matching host accounting.
            total, took_sparse = spmd_accumulate(vec, self._backend.axis, mode,
                                                 k=k, with_branch=True)
            vec_len = int(local.size)
            k_eff = k if k is not None else default_auto_k(vec_len)
            n = self.n_threads
            self._auto_slots.append({
                "count": took_sparse.astype(jnp.int32),
                "per_sparse": 2 * pair_capacity(vec_len, k_eff) * n + vec_len,
                "per_dense": (n + 1) * vec_len,
                "rounds": self._accum_repeat,
                "shard": shard,
            })
        else:
            total = spmd_accumulate(vec, self._backend.axis, mode, k=k)
        if not local.ndim:
            total = total[0]
        self.values[name] = total
        self._backend.stats.account(mode, self.n_threads, int(local.size), k,
                                    repeat=self._accum_repeat, shard=shard)
        return total


def _warn_at_caller(message: str, category) -> None:
    """Warn with the first stack frame *outside this module* as the location,
    so run/join/lower entry paths all attribute to the user's call site."""
    import sys
    level, frame = 2, sys._getframe(1)
    while frame is not None and frame.f_globals.get("__name__") == __name__:
        frame = frame.f_back
        level += 1
    warnings.warn(message, category, stacklevel=level)


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


@runtime_checkable
class Backend(Protocol):
    """Execution substrate behind a Session: place threads, run them, account
    accumulator traffic.  Two implementations ship: :class:`HostBackend` and
    :class:`SpmdBackend`."""

    kind: str

    @property
    def n_threads(self) -> int: ...

    @property
    def n_nodes(self) -> int: ...

    def spawn(self, session: "Session", thread_proc: Callable,
              data: Sequence, broadcast: Sequence) -> None: ...

    def join(self, session: "Session", timeout: Optional[float]) -> List[Any]: ...

    def wire_traffic(self) -> int: ...


class HostBackend:
    """Today's paper-faithful path: DThreadPool + blocking DAddAccumulator."""

    kind = "host"

    def __init__(self, n_nodes: int = 2, threads_per_node: int = 2, *,
                 fused: bool = True):
        self.pool = DThreadPool(n_nodes, threads_per_node)
        self.run_barrier = DBarrier(self.pool.n_threads)
        # SPARSE/AUTO rounds reduce through the fused sparsify→scatter-add
        # kernel; set False to route new accumulators down the historical
        # compress→densify→add path (bit-exact either way)
        self.fused = fused
        self._accumulators: Dict[tuple, DAddAccumulator] = {}
        self._lock = threading.Lock()

    @property
    def n_threads(self) -> int:
        return self.pool.n_threads

    @property
    def n_nodes(self) -> int:
        return self.pool.n_nodes

    def accumulator(self, session: "Session", name: str,
                    mode: Optional[AccumMode] = None,
                    k: Optional[int] = None) -> DAddAccumulator:
        """Registry: one accumulator per (output ref, mode, k budget), created
        on first use — so per-call mode/budget switches behave the same as on
        the SPMD path.  ``mode=None`` resolves to the ref's sole existing
        accumulator (the common case for post-run inspection), else the
        session default; ``k=None`` resolves to the ref's declared
        ``sparse_k`` budget."""
        with self._lock:
            if mode is None:
                existing = [a for (n, _, _), a in self._accumulators.items()
                            if n == name]
                if len(existing) == 1:
                    return existing[0]
                mode = session.accum_mode
            mode = AccumMode(mode)
            if k is None:
                k = session.sparse_k(name)
            key = (name, mode, k)
            accu = self._accumulators.get(key)
            if accu is None and k is None:
                # budget-less inspection of a ref that accumulated with a
                # per-call k: resolve to the sole (name, mode) accumulator
                # instead of constructing a fresh zero-traffic one (which for
                # SPARSE would even be unconstructible without a budget)
                matches = [a for (n, m, _), a in self._accumulators.items()
                           if n == name and m == mode]
                if len(matches) == 1:
                    return matches[0]
            if accu is None:
                accu = DAddAccumulator(session.store, name, self.n_threads,
                                       self.n_nodes, mode, k=k,
                                       fused=self.fused,
                                       tracer=session.tracer,
                                       checker=session.checker)
                self._accumulators[key] = accu
            return accu

    def spawn(self, session: "Session", thread_proc: Callable,
              data: Sequence, broadcast: Sequence) -> None:
        n = self.n_threads

        def entry(tid: int, _param):
            lo_hi = [partition_rows(a.shape[0], tid, n) for a in data]
            shards = [a[lo:hi] for a, (lo, hi) in zip(data, lo_hi)]
            ctx = HostWorkerCtx(session, self, tid)
            if telemetry.TRACING and session.tracer.enabled:
                # spans from this OS thread land on (node, tid) timelines
                session.tracer.bind_thread(tid, ctx.node_id)
            ck = session.checker
            if stepcheck.CHECKING and ck.enabled:
                # the worker's vector clock starts from the driver's spawn
                # snapshot (the spawn happens-before edge)
                ck.bind_thread(tid, ctx.node_id)
            session._tls.ctx = ctx
            try:
                return thread_proc(ctx, *shards, *broadcast)
            finally:
                session._tls.ctx = None

        self.pool.create_threads(entry)
        self.pool.start_all()

    def join(self, session: "Session", timeout: Optional[float] = None) -> List[Any]:
        self.pool.join_all(timeout)
        # a thread_proc that raised must not dissolve into a None result —
        # surface the first failure (LOST threads are the FT layer's business)
        failed = [t for t in self.pool.threads if t.state is ThreadState.FAILED]
        if failed:
            raise RuntimeError(
                f"{len(failed)} session thread(s) failed; first: tid "
                f"{failed[0].tid} on node {failed[0].node_id}") from failed[0].error
        return [t.result for t in self.pool.threads]

    def wire_traffic(self) -> int:
        with self._lock:
            return sum(a.bytes_transferred for a in self._accumulators.values())


@dataclass
class SpmdTraffic:
    """Per-call traffic accounting for the SPMD accumulator, mirroring the
    host accumulator's cost model.  Accounting happens at trace time, where
    the data is unknown: ``sparse`` is costed at its top-k budget, and
    ``auto`` provisionally at the dense figure — then settled at ``join``
    time against the device-side branch counter each auto call site threads
    through the program (see :meth:`settle_auto`), so ``wire_traffic()``
    reports the branch actually taken, as the host does.

    ``by_shard`` attributes each call site's traffic to the shard owning the
    output ref — the per-shard half of ``Session.shard_stats()``."""

    bytes_transferred: int = 0
    rounds: int = 0
    by_shard: Dict[int, int] = field(default_factory=dict)

    def _charge(self, amount: int, shard: Optional[int]) -> None:
        self.bytes_transferred += amount
        if shard is not None:
            self.by_shard[shard] = self.by_shard.get(shard, 0) + amount

    def settle_auto(self, slot: Dict[str, Any], sparse_rounds: int) -> None:
        """Replace one auto call site's trace-time dense upper bound with the
        cost of the branches actually taken: ``sparse_rounds`` of its
        ``rounds`` executions took the pairs path, the rest went dense."""
        actual = (sparse_rounds * slot["per_sparse"]
                  + (slot["rounds"] - sparse_rounds) * slot["per_dense"])
        self._charge(actual - slot["rounds"] * slot["per_dense"],
                     slot.get("shard"))

    def account(self, mode: AccumMode, n: int, vec_len: int, k: Optional[int],
                *, repeat: int = 1, shard: Optional[int] = None) -> None:
        """Charge one accumulate call site.  ``vec_len`` is the total element
        count of the local contribution (scalars cost 1, like the host
        accumulator).  ``repeat`` multiplies by the trip count when the call
        site sits inside ``ctx.iterate`` — the scan body is traced once but
        executes ``iters`` rounds.

        ``sparse`` is costed from the pair arrays actually shipped: every
        device all-gathers ``pair_capacity(V, k)`` static (index, value)
        pairs, and the densified result is the ``V``-element republish — the
        same ``Σ 2·pairs + V`` figure the host accumulator derives from its
        per-thread :class:`~repro.core.sparse.SparsePairs`, so
        ``wire_traffic()`` agrees across backends for a sparse round."""
        if mode == AccumMode.GATHER_ALL:
            per_round = (2 * n + 1) * vec_len
        elif mode == AccumMode.SPARSE:
            per_round = 2 * pair_capacity(vec_len, k) * n + vec_len
        else:  # REDUCE_SCATTER / HIERARCHICAL / AUTO (dense, settled at join)
            per_round = (n + 1) * vec_len
        self._charge(per_round * repeat, shard)
        self.rounds += repeat


class SpmdBackend:
    """The production path: one STEP thread per mesh position via shard_map.

    ``spawn`` records the program; ``join`` traces ``thread_proc`` once, runs
    it over the mesh, and writes final shared values back into the session's
    store so the driver-side ``ref.get()`` sees the result exactly as it does
    on the host backend.  Iteration written with ``ctx.iterate`` lowers to one
    ``lax.scan`` (O(1) program size in the trip count); a raw Python loop in
    ``thread_proc`` still works but unrolls into the jitted step.
    """

    kind = "spmd"

    def __init__(self, mesh=None, axis: str = "data", n_threads: Optional[int] = None):
        if mesh is None:
            mesh = make_mesh((n_threads or len(jax.devices()),), (axis,))
        if axis not in mesh.axis_names:
            raise ValueError(f"mesh has axes {mesh.axis_names}, no {axis!r}")
        self.mesh = mesh
        self.axis = axis
        self.stats = SpmdTraffic()
        self._pending = None

    @property
    def n_threads(self) -> int:
        return int(self.mesh.shape[self.axis])

    @property
    def n_nodes(self) -> int:
        return self.n_threads

    def spawn(self, session: "Session", thread_proc: Callable,
              data: Sequence, broadcast: Sequence) -> None:
        if self._pending is not None:
            raise RuntimeError("SPMD backend already has a spawned program; join() it first")
        self._pending = (thread_proc, tuple(data), tuple(broadcast))

    def _compile(self, session: "Session", thread_proc: Callable,
                 data: Sequence, broadcast: Sequence):
        """Build the jitted shard_map program for one spawn.

        Returns ``(f, data, names, auto_box)`` — the compiled callable, the
        (possibly trimmed) data arrays, the shared names captured in the
        trace, and the static metadata of every AUTO branch-counter slot (the
        traced counts themselves come out as the program's third output).
        """
        n = self.n_threads
        # shard_map splits evenly: trim ragged rows (the host backend gives the
        # remainder to low tids instead; parity holds whenever n divides rows).
        dropped = [int(a.shape[0] % n) for a in data]
        if any(dropped):
            _warn_at_caller(
                f"SpmdBackend: dropping {sum(dropped)} ragged row(s) "
                f"({dropped} per data array) so shard_map splits "
                f"evenly across {n} threads; pad or trim row counts to a "
                "multiple of n_threads for host/SPMD parity",
                UserWarning)
        data = tuple(a[: (a.shape[0] // n) * n] for a in data)
        names = session.store.names()
        shared0 = {m: session.store.get(m) for m in names}
        auto_box: List[Dict[str, Any]] = []

        def body(*args):
            tid = jax.lax.axis_index(self.axis)
            ctx = SpmdWorkerCtx(session, self, tid, dict(shared0))
            session._tls.ctx = ctx
            try:
                result = thread_proc(ctx, *args)
            finally:
                session._tls.ctx = None
            # the AUTO branch counters leave the program as a third output;
            # their static cost metadata rides out-of-band through auto_box
            auto_box[:] = [{k: v for k, v in s.items() if k != "count"}
                           for s in ctx._auto_slots]
            counts = tuple(s["count"] for s in ctx._auto_slots)
            # stack every leaf along the mesh axis so out_specs is uniform
            return jax.tree.map(lambda x: jnp.asarray(x)[None],
                                (result, ctx.values, counts))

        in_specs = tuple(P(self.axis) for _ in data) + tuple(P() for _ in broadcast)
        f = jax.jit(shard_map(body, mesh=self.mesh, in_specs=in_specs,
                              out_specs=P(self.axis), check_vma=False))
        return f, data, names, auto_box

    def lower(self, session: "Session", thread_proc: Callable,
              data: Sequence, broadcast: Sequence):
        """Trace + lower ``thread_proc`` without running it: the hook for
        compile-cost inspection (``lowered.as_text()`` / ``.compile()``)."""
        f, data, _, _ = self._compile(session, thread_proc, data, broadcast)
        # accounting fires at trace time: inspection must not charge the
        # session's wire-traffic figures, so trace against throwaway stats
        stats, self.stats = self.stats, SpmdTraffic()
        try:
            return f.lower(*data, *broadcast)
        finally:
            self.stats = stats

    def join(self, session: "Session", timeout: Optional[float] = None) -> List[Any]:
        if self._pending is None:
            return []
        thread_proc, data, broadcast = self._pending
        self._pending = None
        n = self.n_threads
        trc = session.tracer
        tracing = telemetry.TRACING and trc.enabled
        wire_before = self.stats.bytes_transferred
        t0 = time.perf_counter() if tracing else 0.0
        f, data, names, auto_box = self._compile(session, thread_proc, data, broadcast)
        if tracing:
            # trace-time counters (scan trips, provisional traffic) landed
            # during _compile; the span brackets trace + jit dispatch setup
            trc.add_span("spmd", "spmd.trace", t0, time.perf_counter(),
                         {"threads": n})
            t1 = time.perf_counter()
        stacked_result, stacked_shared, stacked_counts = f(*data, *broadcast)
        # settle every AUTO call site's trace-time dense bound against the
        # branch counter the device actually accumulated (globally agreed, so
        # replica 0's count is everyone's count)
        for meta, counts in zip(auto_box, stacked_counts):
            self.stats.settle_auto(meta, int(jax.device_get(counts)[0]))
        for m in names:
            session.store.set(m, jax.tree.map(lambda x: x[0], stacked_shared[m]))
        out = [jax.tree.map(lambda x, i=i: x[i], stacked_result) for i in range(n)]
        if tracing:
            # device code can't emit host events mid-program: like AUTO
            # traffic, collective accounting settles once, at join
            trc.add_span("spmd", "spmd.execute", t1, time.perf_counter(),
                         {"threads": n})
            trc.count("spmd.joins")
            trc.count("spmd.collective_elements",
                      self.stats.bytes_transferred - wire_before)
        return out

    def wire_traffic(self) -> int:
        return self.stats.bytes_transferred


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------


class Session:
    """Table 1 as one object: DSM + cluster/thread management + sync.

    Parameters
    ----------
    backend:
        ``"host"`` | ``"spmd"`` | a :class:`Backend` instance.
    n_nodes / threads_per_node:
        Host-backend cluster shape (ignored for SPMD).
    mesh / axis:
        SPMD mesh (defaults to one thread per visible device on ``axis``).
    accum_mode:
        Default :class:`AccumMode` for ``SharedRef.accumulate``.
    store:
        Optionally adopt an existing :class:`GlobalStore` (FT recovery rolls
        a new session onto the surviving store this way).
    shards:
        Number of consistent-hash shards in a freshly built store (ignored
        when adopting ``store``).  ``1`` is the paper's single flat store;
        larger counts let workers touching different shards read/write/inc
        concurrently — there is no session-global cache lock.
    cold_tier / cold_budget:
        ``step.tiers`` knobs for a freshly built store (ignored when
        adopting ``store``): ``cold_tier`` is ``None`` (default, pure
        in-memory), ``"host"`` (pinned host-memory numpy tier), ``"disk"``
        (pickled spill files), or any
        :class:`~repro.core.tiers.ColdTier` instance; ``cold_budget`` caps
        per-shard hot bytes — beyond it, least-recently-used entries demote
        to the cold tier and promote back (epoch-preserving) on access.
    trace:
        ``step.trace`` arming: ``True`` arms a fresh
        :class:`~repro.core.telemetry.Tracer`, an existing tracer is adopted
        as-is (how FT recovery re-arms a replacement session), and the
        default ``None`` leaves tracing *off* — a disabled tracer whose hot
        paths cost one attribute check and allocate nothing.  Inspect via
        ``session.tracer`` / :meth:`metrics`; export with
        ``session.tracer.export(path)``.
    check:
        ``step.check`` arming, same contract as ``trace``: ``True`` arms a
        fresh :class:`~repro.check.Checker` (happens-before race detection,
        lock-order sanitizing, and a spawn-time lint that rejects
        structurally broken programs with
        :class:`~repro.check.CheckError`), an existing checker is adopted
        as-is, and the default ``None`` leaves checking off at one-branch
        hot-path cost.  Inspect via ``session.checker`` / :meth:`findings`;
        export with ``session.checker.export(path)``.
    record:
        ``step.obs`` flight-recorder arming, same contract again: ``True``
        arms a fresh :class:`~repro.obs.FlightRecorder` (a bounded ring of
        recent events, cheap enough to leave on always — the tracer runs in
        *record-only* mode unless ``trace`` armed it fully), an existing
        recorder is adopted as-is (FT recovery re-attaches the dead
        session's recorder), and the default ``None`` leaves recording off.
        Inspect via ``session.recorder``; pair with :meth:`watchdog` for
        anomaly detection and :meth:`openmetrics` for scrape text.  Call
        ``session.recorder.close()`` when done with an armed recorder so
        the module-level tracing flag drops back.
    """

    def __init__(self, backend: Backend | str = "host", *,
                 n_nodes: int = 2, threads_per_node: int = 2,
                 mesh=None, axis: str = "data",
                 store: Optional[GlobalStore] = None,
                 granularity: str = "coarse",
                 shards: int = 1,
                 cold_tier=None,
                 cold_budget: Optional[int] = None,
                 accum_mode: AccumMode | str = AccumMode.REDUCE_SCATTER,
                 cache_capacity: int = 1024,
                 trace: "telemetry.Tracer | bool | None" = None,
                 check: "stepcheck.Checker | bool | None" = None,
                 record: "stepobs.FlightRecorder | bool | None" = None):
        if isinstance(backend, str):
            if backend == "host":
                backend = HostBackend(n_nodes, threads_per_node)
            elif backend == "spmd":
                backend = SpmdBackend(mesh=mesh, axis=axis)
            else:
                raise ValueError(f"backend must be host|spmd, got {backend!r}")
        self.backend = backend
        # step.trace: trace=True arms a fresh tracer; a Tracer instance is
        # adopted as-is (FT recovery re-arms the failed session's tracer);
        # the default is a *disabled* tracer — hot paths see a false
        # `tracer.enabled` behind the module flag and allocate nothing.
        self.tracer = telemetry.as_tracer(trace)
        # step.check mirrors the arming contract: check=True arms a fresh
        # checker; a Checker instance is adopted as-is (FT recovery re-arms
        # the failed session's checker); default is disabled, one branch.
        self.checker = stepcheck.as_checker(check)
        # step.obs: record=True arms the flight recorder — a bounded ring of
        # recent events behind the same tracer; when `trace` didn't arm full
        # tracing the tracer runs record-only (hists/counters accumulate,
        # only slow/lifecycle events materialise, memory stays O(capacity)).
        self.recorder = stepobs.as_recorder(record)
        self.recorder.attach(self.tracer)
        # sync primitives handed out by this session, for the watchdog's
        # live in-flight-wait scan (weak: a dropped barrier unregisters
        # itself; nothing here extends primitive lifetime)
        self._watch_prims: "weakref.WeakSet" = weakref.WeakSet()
        # step.tiers: cold_tier ("host" | "disk" | a ColdTier instance) and
        # cold_budget (per-shard hot bytes before LRU demotion kicks in) are
        # store-construction options — like `shards`, they are ignored when
        # an existing store is adopted (FT recovery keeps its tiering as-is)
        self.store = store if store is not None else GlobalStore(
            granularity=granularity, shards=shards,
            cold_tier=cold_tier, cold_budget=cold_budget)
        self.store.tracer = self.tracer
        self.store.checker = self.checker
        self.accum_mode = AccumMode(accum_mode)
        self.cache = DSMCache(self.store, n_nodes=backend.n_nodes,
                              capacity=cache_capacity)
        self.cache.tracer = self.tracer
        self.cache.checker = self.checker
        if backend.kind == "host":
            backend.run_barrier.tracer = self.tracer
            backend.run_barrier.checker = self.checker
            self._watch_prims.add(backend.run_barrier)
        self._sparse_k: Dict[str, int] = {}  # per-ref default top-k budgets
        self._tls = threading.local()

    # -- Table 1: DSM manipulation --------------------------------------------

    def def_global(self, name: str, value, *, spec=None,
                   sparse_k: Optional[int] = None) -> SharedRef:
        """``DefGlobal`` — declare + initialise a shared variable.

        ``sparse_k`` sets the ref's default top-k budget: any
        ``ref.accumulate(..., mode="sparse"|"auto")`` without an explicit
        ``k`` compresses with this budget on either backend."""
        self.store.def_global(name, value, spec=spec)
        self._set_sparse_k(name, sparse_k,
                           size=None if sparse_k is None
                           else int(jnp.asarray(value).size))
        return SharedRef(self, name)

    def new_array(self, name: str, shape, dtype=jnp.float32, *, spec=None,
                  sparse_k: Optional[int] = None) -> SharedRef:
        """``NewArray`` — allocate a zeroed shared array.  ``sparse_k`` is the
        ref's default top-k budget for sparse/auto accumulates."""
        self.store.new_array(name, shape, dtype, spec=spec)
        self._set_sparse_k(name, sparse_k,
                           size=None if sparse_k is None
                           else int(np.prod(shape, dtype=np.int64)) if shape
                           else 1)
        return SharedRef(self, name)

    def _set_sparse_k(self, name: str, sparse_k: Optional[int],
                      size: Optional[int] = None) -> None:
        self._sparse_k.pop(name, None)  # re-declared names drop the old budget
        if sparse_k is not None:
            if sparse_k < 1:
                raise ValueError(f"sparse_k must be >= 1, got {sparse_k}")
            self._sparse_k[name] = int(sparse_k)
            ck = self.checker
            if stepcheck.CHECKING and ck.enabled and size is not None:
                # declaration-time lint: a budget the blocked pair layout
                # cannot ship is silently lossier than asked
                ck.lint_sparse_budget(name, size, int(sparse_k))

    def sparse_k(self, name: str) -> Optional[int]:
        """The ref's declared default top-k budget (None if unset)."""
        return self._sparse_k.get(name)

    def new_object(self, name: str, fields: Dict[str, Any], *, specs=None) -> SharedRef:
        """``NewObj`` — a shared pytree of fields under one object_id."""
        self.store.new_object(name, fields, specs=specs)
        return SharedRef(self, name)

    def ref(self, name: str) -> SharedRef:
        """Handle to an already-declared name."""
        if name not in self.store.names():
            raise KeyError(name)
        return SharedRef(self, name)

    def names(self) -> List[str]:
        return self.store.names()

    def delete(self, name: str) -> None:
        """``DelArray`` / ``DelObj`` + coherence teardown: every node's cache
        replica and every directory record of the name is purged, so a later
        re-declaration under the same name starts with no stale state.

        The teardown is the store's delete hook (the cache registered
        :meth:`DSMCache.drop` at construction), fired under the owning
        shard's lock — a concurrent worker read of the same name either
        completes before the delete or misses afterwards, never re-populates
        a deleted-era replica."""
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            # advisory directory peek (no lock): a delete while nodes still
            # hold replicas is legal but worth a lint warning — a concurrent
            # reader of the deleted era may be mid-flight
            holders = set(self.store.shard_for(name).directory.get(name, ()))
            if holders:
                ck.check_delete(name, holders)
        self.store.delete(name)
        self._sparse_k.pop(name, None)

    # -- Table 1: cluster & thread management ---------------------------------

    def spawn(self, thread_proc: Callable, *, data: Sequence = (),
              broadcast: Sequence = ()) -> None:
        """Create + start one STEP thread per backend slot.

        ``thread_proc(ctx, *data_shards, *broadcast)`` receives this thread's
        contiguous row-partition of each array in ``data`` and every array in
        ``broadcast`` whole (replicated).
        """
        data = tuple(jnp.asarray(a) for a in data)
        broadcast = tuple(jnp.asarray(b) for b in broadcast)
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            # lint dry run FIRST: a strict checker raises CheckError here —
            # a structurally broken program is rejected before any thread
            # (or any SPMD trace) exists
            ck.lint_spawn(self, thread_proc, data, broadcast)
            ck.on_spawn(self.backend.n_threads)
        self.backend.spawn(self, thread_proc, data, broadcast)

    def join(self, timeout: Optional[float] = None) -> List[Any]:
        """Join all threads; returns per-tid results."""
        try:
            return self.backend.join(self, timeout)
        finally:
            ck = self.checker
            if stepcheck.CHECKING and ck.enabled:
                # the join happens-before edge: the driver's clock absorbs
                # every worker's; the lock sanitizer's wait-for state resets
                ck.after_join()

    def run(self, thread_proc: Callable, *, data: Sequence = (),
            broadcast: Sequence = (), timeout: Optional[float] = None) -> List[Any]:
        """``spawn`` + ``join``."""
        self.spawn(thread_proc, data=data, broadcast=broadcast)
        return self.join(timeout)

    def lower(self, thread_proc: Callable, *, data: Sequence = (),
              broadcast: Sequence = ()):
        """Trace + lower ``thread_proc`` without executing it (SPMD backend).

        Returns the ``jax.stages.Lowered`` for the program ``join`` would run:
        inspect ``.as_text()`` for lowered size (the ``ctx.iterate`` scan path
        is O(1) in ``iters``) or ``.compile()`` for compile cost.
        """
        if self.backend.kind != "spmd":
            raise RuntimeError("Session.lower inspects the traced SPMD program; "
                               "the host backend does not trace thread_proc")
        data = tuple(jnp.asarray(a) for a in data)
        broadcast = tuple(jnp.asarray(b) for b in broadcast)
        if telemetry.TRACING and self.tracer.enabled:
            with self.tracer.span("spmd", "spmd.lower"):
                return self.backend.lower(self, thread_proc, data, broadcast)
        return self.backend.lower(self, thread_proc, data, broadcast)

    def kill_node(self, node_id: int) -> List[int]:
        """Simulate a node failure (host backend); returns lost tids."""
        if self.backend.kind != "host":
            raise RuntimeError("node-failure simulation needs the host backend; "
                               "SPMD recovery goes through ft.elastic_restore")
        return self.backend.pool.kill_node(node_id)

    def healthy_nodes(self) -> List[int]:
        if self.backend.kind != "host":
            return list(range(self.backend.n_nodes))
        return self.backend.pool.healthy_nodes()

    def thread_states(self) -> Dict[int, Any]:
        if self.backend.kind != "host":
            return {}
        return self.backend.pool.states()

    # -- Table 1: synchronization ---------------------------------------------

    def barrier(self, count: Optional[int] = None) -> DBarrier:
        """A counter barrier sized to the session's threads by default.
        Carries the session's tracer: every ``enter`` records a per-thread
        entry→release ``barrier-wait`` span when tracing is armed."""
        b = DBarrier(count or self.backend.n_threads)
        b.tracer = self.tracer
        b.checker = self.checker
        self._watch_prims.add(b)
        return b

    def semaphore(self, count: int = 1) -> DSemaphore:
        s = DSemaphore(count)
        s.tracer = self.tracer
        s.checker = self.checker
        self._watch_prims.add(s)
        return s

    def ssp_clock(self, staleness: int = 0, n_workers: Optional[int] = None) -> SSPClock:
        c = SSPClock(n_workers or self.backend.n_threads, staleness=staleness)
        c.tracer = self.tracer
        c.checker = self.checker
        return c

    # -- accumulator registry / stats -----------------------------------------

    def accumulator(self, name: str, mode: Optional[AccumMode | str] = None):
        """The accumulator behind ``ref.accumulate`` (host backend)."""
        if self.backend.kind != "host":
            return self.backend.stats
        return self.backend.accumulator(self, name,
                                        AccumMode(mode) if mode else None)

    def wire_traffic(self) -> int:
        """Total accumulator wire traffic, in vector elements (paper §5.2)."""
        return self.backend.wire_traffic()

    def findings(self) -> List[Any]:
        """Findings recorded by this session's checker (see ``step.check``):
        race/lock/lint :class:`~repro.check.Finding` rows.  Empty unless the
        session was built with ``check=True`` (or an armed checker)."""
        return self.checker.findings()

    def stats(self) -> Dict[str, Any]:
        """Deprecated view: the original raw-counter triple.  Kept intact for
        existing callers; new code should use :meth:`metrics`, which returns
        the canonical normalized key set plus the tracer snapshot."""
        _warn_at_caller("Session.stats() is deprecated; use Session.metrics() "
                        "for the canonical normalized snapshot",
                        DeprecationWarning)
        # frozen key set: tier/migration counters added later live only in
        # metrics() — this view keeps the pre-tiers shape for old callers
        legacy = ("get", "set", "inc", "bytes_get", "bytes_set",
                  "transfers", "migrated_in", "migrated_out")
        raw = self.store.stats
        return {"store": {k: raw.get(k, 0) for k in legacy},
                "cache": self.cache.stats,
                "wire_traffic": self.wire_traffic()}

    def metrics(self) -> Dict[str, Any]:
        """The unified observability snapshot (supersedes :meth:`stats` /
        :meth:`shard_stats` without breaking them).  Key set pinned by
        :data:`repro.core.telemetry.SESSION_METRIC_KEYS`:

        * ``backend`` — ``"host"`` | ``"spmd"``
        * ``store`` — canonical store counters
          (:data:`~repro.core.telemetry.STORE_METRIC_KEYS`)
        * ``cache`` — canonical coherence counters
          (:data:`~repro.core.telemetry.CACHE_METRIC_KEYS`)
        * ``wire_traffic`` — accumulator elements, host/SPMD comparable
        * ``shards`` — per-shard ``{store, cache, wire_traffic}`` rows with
          the same canonical shapes
        * ``tiers`` — hot/cold tier occupancy + hit/promotion/demotion
          counters (:meth:`ShardedStore.tier_stats`), with a ``migration``
          sub-dict of lifetime rebalance-window totals
          (:meth:`ShardedStore.migration_totals`)
        * ``trace`` — :meth:`Tracer.snapshot` (span counts, counters,
          latency histograms); ``{"enabled": False, ...}`` when unarmed
        """
        shard_rows = {
            sid: {"store": telemetry.normalize_store_stats(row["store"]),
                  "cache": row["cache"].as_dict(),
                  "wire_traffic": row["wire_traffic"]}
            for sid, row in self._shard_rows().items()}
        return {"backend": self.backend.kind,
                "store": telemetry.normalize_store_stats(self.store.stats),
                "cache": self.cache.stats.as_dict(),
                "wire_traffic": self.wire_traffic(),
                "shards": shard_rows,
                "tiers": {**self.store.tier_stats(),
                          "migration": self.store.migration_totals()},
                "trace": self.tracer.snapshot()}

    def openmetrics(self, *, prefix: str = "step",
                    anomalies: Optional[Sequence[Any]] = None) -> str:
        """:meth:`metrics` rendered as OpenMetrics/Prometheus exposition
        text (``step.obs``'s scrape surface).  Pass ``watchdog.anomalies``
        to include the anomaly counters on the same page."""
        return stepobs.openmetrics(self.metrics(), prefix=prefix,
                                   anomalies=anomalies)

    def watchdog(self, **kwargs) -> "stepobs.Watchdog":
        """A :class:`~repro.obs.Watchdog` over this session (not started —
        call ``.start()`` for the daemon thread or drive ``poll_once()``
        yourself).  Detects stalled migration windows, barrier/semaphore
        waits beyond a p99-derived SLO, tier thrash, shard lock-wait
        outliers, and (via ``watch_heartbeats``) dead nodes; each anomaly
        carries a flight-recorder dump when :attr:`recorder` is armed."""
        return stepobs.Watchdog(self, **kwargs)

    def shard_stats(self) -> Dict[int, Dict[str, Any]]:
        """Per-shard view of the session, keyed by shard id: the store's op
        counters (+ entry count + migration counts), the cache's coherence
        counters, and accumulator wire traffic attributed to the shard owning
        each output ref.  Deprecated view — raw counter shapes; the
        normalized per-shard rows live in ``metrics()["shards"]``."""
        _warn_at_caller("Session.shard_stats() is deprecated; use "
                        "Session.metrics()['shards'] for the canonical "
                        "normalized per-shard rows", DeprecationWarning)
        return self._shard_rows()

    def _shard_rows(self) -> Dict[int, Dict[str, Any]]:
        cache_rows = self.cache.shard_stats()
        out: Dict[int, Dict[str, Any]] = {
            sid: {"store": row, "cache": cache_rows.get(sid, CacheStats()),
                  "wire_traffic": 0}
            for sid, row in self.store.shard_stats().items()}
        if self.backend.kind == "host":
            for (name, _, _), accu in self.backend._accumulators.items():
                sid = self.store.shard_of(name)
                if sid in out:
                    out[sid]["wire_traffic"] += accu.bytes_transferred
        else:
            for sid, elems in self.backend.stats.by_shard.items():
                if sid in out:
                    out[sid]["wire_traffic"] += elems
        return out

    # -- ref-op dispatch (driver vs active worker ctx) ------------------------

    def _ctx(self):
        return getattr(self._tls, "ctx", None)

    def _read(self, name: str, owner=None):
        ctx = self._ctx()
        value = (self.store.get(name, owner=owner) if ctx is None
                 else ctx.read(name, owner=owner))
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled and (
                ctx is None or type(ctx) is HostWorkerCtx):
            # race detection sees host/driver accesses only: SPMD refs are
            # traced replicated values (ordered by the collective schedule)
            # and the lint dry run's shadow ctx must stay invisible
            ck.on_access(name, "read", value)
        return value

    def _write(self, name: str, value, owner=None) -> None:
        ctx = self._ctx()
        if ctx is None:
            self.store.set(name, value, owner=owner)
        else:
            ctx.write(name, value, owner=owner)
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled and (
                ctx is None or type(ctx) is HostWorkerCtx):
            ck.on_access(name, "write", value)

    def _inc(self, name: str, amount, owner=None):
        ctx = self._ctx()
        result = (self.store.inc(name, amount, owner=owner) if ctx is None
                  else ctx.inc(name, amount, owner=owner))
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled and (
                ctx is None or type(ctx) is HostWorkerCtx):
            # inc is atomic under the owning shard's lock: inc-inc pairs
            # commute and are never racy; inc vs set/get still is
            ck.on_access(name, "inc", result)
        return result

    def _accumulate(self, name: str, local, mode, k):
        ctx = self._ctx()
        if ctx is None:
            raise RuntimeError(
                "SharedRef.accumulate is a collective across the session's "
                "threads — call it inside a thread_proc run by Session.spawn")
        if k is None:
            k = self._sparse_k.get(name)  # the ref's declared default budget
        return ctx.accumulate(name, jnp.asarray(local),
                              AccumMode(mode) if mode is not None else self.accum_mode, k)

    def _cached_read(self, node_id: int, name: str, owner=None):
        # locking lives in the cache/store layer: the owning shard's lock,
        # not a session-global one — reads of names on different shards
        # proceed concurrently
        return self.cache.read(node_id, name, owner=owner)

    def _cached_write(self, node_id: int, name: str, value, owner=None) -> None:
        self.cache.write(node_id, name, value, owner=owner)

    # paper-cased aliases (Table 1)
    DefGlobal = def_global
    NewArray = new_array
    NewObj = new_object

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Session(backend={self.backend.kind}, "
                f"threads={self.backend.n_threads}, names={self.names()})")


def deprecated_entry(old: str, new: str) -> None:
    """One-liner for the pre-Session entry points kept as shims."""
    warnings.warn(f"{old} is deprecated; use {new}", DeprecationWarning,
                  stacklevel=3)
