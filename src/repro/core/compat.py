"""Version-compatibility shims over the moving parts of the JAX API.

The repo targets current JAX (``jax.shard_map``, ``jax.sharding.AxisType``,
``jax.make_mesh(..., axis_types=...)``) but must also run on older releases
where ``shard_map`` still lives in ``jax.experimental`` (with ``check_rep``
instead of ``check_vma``) and meshes have no axis types.  Everything that
builds a mesh or a shard_map goes through these two helpers.
"""

from __future__ import annotations

from typing import Sequence

import jax

try:  # jax >= 0.5-ish
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    _AxisType = None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` on old.

    ``check_vma`` maps onto the old API's ``check_rep`` (same role: verify
    replication/varying-axis annotations; both default off here because the
    accumulator's collectives produce deliberately replicated outputs).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def axis_size(axis) -> int:
    """Static size of a named mesh axis (or tuple of axes) inside shard_map.

    New JAX exposes ``jax.lax.axis_size``; on older releases ``psum(1, axis)``
    is constant-folded to the same static integer.
    """
    axes = axis if isinstance(axis, (tuple, list)) else (axis,)
    if hasattr(jax.lax, "axis_size"):
        n = 1
        for a in axes:
            n *= jax.lax.axis_size(a)
        return n
    return jax.lax.psum(1, tuple(axes))


def cost_analysis(compiled) -> dict:
    """Normalised ``compiled.cost_analysis()``: one flat dict of metrics.

    Newer JAX returns the dict directly; older releases return a one-element
    list of dicts (one per computation); some backends return ``None`` or
    raise.  Callers always get a plain dict (possibly empty).
    """
    try:
        ca = compiled.cost_analysis()
    except Exception:  # pragma: no cover - backend-dependent
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def make_mesh(shape: Sequence[int], names: Sequence[str], devices=None):
    """``jax.make_mesh`` with Auto axis types where the installed jax has them."""
    shape, names = tuple(shape), tuple(names)
    if _AxisType is not None:
        return jax.make_mesh(shape, names, devices=devices,
                             axis_types=(_AxisType.Auto,) * len(shape))
    return jax.make_mesh(shape, names, devices=devices)
