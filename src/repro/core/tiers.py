"""step.tiers — pluggable cold storage beneath the sharded DSM.

STEP's store (§5.1) assumes every entry fits in per-shard RAM.  The
memory-disaggregated object-store design (PAPERS.md) splits that assumption:
a small *hot* tier absorbs the working set at memory speed while a *cold*
tier — host memory owned by another process, local disk, eventually a
remote object store — holds everything else, with promotion on access.

This module is the cold half.  A :class:`ColdTier` stores opaque *value
payloads* keyed by DSM name; all entry metadata (epoch, delete-era
generation, address slot, placement spec) stays in memory on the owning
:class:`~repro.core.shards.Shard`, so validation and coherence never touch
the cold backend.  Two backends ship:

* :class:`HostMemTier` — an in-process dict of host (numpy) pytrees.  The
  degenerate-but-useful case: entries leave the accelerator/hot dict but
  stay a pointer-chase away, which is what a disaggregated-memory node
  looks like from the store's side.
* :class:`DiskTier` — one pickled host pytree per name under a spill
  directory (content-addressed file names, so DSM names need not be
  filesystem-safe).  Bigger-than-RAM namespaces land here.

Both are thread-safe behind one internal leaf lock (tier calls happen under
the owning shard's lock and never call back into store code).  Payloads are
converted to host numpy on the way in — a demoted value must not pin device
memory, and pickling device arrays would be meaningless anyway.

``resolve_cold_tier`` maps the ``Session(cold_tier=...)`` argument
(``"host" | "disk" | ColdTier instance | None``) onto a backend instance.
"""

from __future__ import annotations

import hashlib
import os
import pickle
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Protocol, runtime_checkable

import jax
import numpy as np


def host_payload(value: Any) -> Any:
    """Convert a store value (jax array or pytree of arrays) to host numpy —
    the representation every cold backend stores."""
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), value)


def payload_nbytes(value: Any) -> int:
    """Size of a host payload in bytes (the unit of tier budgets/stats)."""
    return int(sum(np.asarray(l).nbytes for l in jax.tree.leaves(value)))


def _fresh_tier_stats() -> Dict[str, int]:
    return {"puts": 0, "gets": 0, "deletes": 0, "entries": 0, "bytes": 0}


@runtime_checkable
class ColdTier(Protocol):
    """Where demoted value payloads live.  Keys are DSM names (globally
    unique across the store, so a payload never needs re-keying when its
    entry migrates between shards).  Implementations must be thread-safe
    and must not call back into store/cache code (tier locks are leaves)."""

    kind: str

    def put(self, name: str, value: Any) -> int:
        """Store ``value`` (a host pytree) under ``name``; returns the number
        of bytes now held for the name (replacing any previous payload)."""
        ...

    def get(self, name: str) -> Any:
        """Load the payload for ``name`` (KeyError if absent)."""
        ...

    def delete(self, name: str) -> None:
        """Drop the payload for ``name`` (no-op if absent)."""
        ...

    def stats(self) -> Dict[str, int]:
        """``{"puts", "gets", "deletes", "entries", "bytes"}`` counters."""
        ...

    def close(self) -> None:
        """Release backend resources (spill files, handles)."""
        ...


class HostMemTier:
    """In-process host-memory cold tier: a dict of numpy pytrees."""

    kind = "host"

    def __init__(self):
        self._lock = threading.Lock()
        self._data: Dict[str, Any] = {}
        self._sizes: Dict[str, int] = {}
        self._stats = _fresh_tier_stats()

    def put(self, name: str, value: Any) -> int:
        payload = host_payload(value)
        nb = payload_nbytes(payload)
        with self._lock:
            self._stats["bytes"] += nb - self._sizes.get(name, 0)
            if name not in self._data:
                self._stats["entries"] += 1
            self._data[name] = payload
            self._sizes[name] = nb
            self._stats["puts"] += 1
        return nb

    def get(self, name: str) -> Any:
        with self._lock:
            self._stats["gets"] += 1
            return self._data[name]

    def delete(self, name: str) -> None:
        with self._lock:
            if name in self._data:
                del self._data[name]
                self._stats["entries"] -= 1
                self._stats["bytes"] -= self._sizes.pop(name)
                self._stats["deletes"] += 1

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._stats)

    def close(self) -> None:
        with self._lock:
            self._data.clear()
            self._sizes.clear()

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"HostMemTier(entries={self._stats['entries']})"


class DiskTier:
    """On-disk cold tier: one pickled host pytree per name under ``root``.

    File names are a 160-bit blake2b digest of the full DSM name, so
    arbitrary names map onto the filesystem safely and two distinct live
    names can never share (and silently overwrite) one spill file — the
    64-bit ring hash is too short for that guarantee.  ``root=None`` spills
    into a fresh temporary directory removed on :meth:`close` (and
    best-effort at interpreter exit)."""

    kind = "disk"

    def __init__(self, root: Optional[str] = None):
        self._owns_root = root is None
        self.root = root or tempfile.mkdtemp(prefix="step-cold-")
        os.makedirs(self.root, exist_ok=True)
        self._lock = threading.Lock()
        self._paths: Dict[str, str] = {}
        self._sizes: Dict[str, int] = {}
        self._stats = _fresh_tier_stats()

    def _path(self, name: str) -> str:
        digest = hashlib.blake2b(str(name).encode("utf-8"),
                                 digest_size=20).hexdigest()
        return os.path.join(self.root, f"{digest}.pkl")

    def put(self, name: str, value: Any) -> int:
        payload = host_payload(value)
        nb = payload_nbytes(payload)
        path = self._path(name)
        blob = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
        with self._lock:
            with open(path, "wb") as fh:
                fh.write(blob)
            self._stats["bytes"] += nb - self._sizes.get(name, 0)
            if name not in self._paths:
                self._stats["entries"] += 1
            self._paths[name] = path
            self._sizes[name] = nb
            self._stats["puts"] += 1
        return nb

    def get(self, name: str) -> Any:
        with self._lock:
            path = self._paths[name]
            self._stats["gets"] += 1
            with open(path, "rb") as fh:
                return pickle.load(fh)

    def delete(self, name: str) -> None:
        with self._lock:
            path = self._paths.pop(name, None)
            if path is None:
                return
            self._stats["entries"] -= 1
            self._stats["bytes"] -= self._sizes.pop(name)
            self._stats["deletes"] += 1
            try:
                os.unlink(path)
            except OSError:  # pragma: no cover - already gone
                pass

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._stats)

    def close(self) -> None:
        with self._lock:
            self._paths.clear()
            self._sizes.clear()
            if self._owns_root:
                shutil.rmtree(self.root, ignore_errors=True)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"DiskTier(root={self.root!r}, entries={self._stats['entries']})"


def resolve_cold_tier(cold_tier) -> Optional[ColdTier]:
    """Map the ``cold_tier=`` constructor argument onto a backend: ``None``
    keeps the store single-tier, ``"host"``/``"disk"`` build the bundled
    backends, and any :class:`ColdTier`-shaped object is adopted as-is."""
    if cold_tier is None:
        return None
    if isinstance(cold_tier, str):
        if cold_tier == "host":
            return HostMemTier()
        if cold_tier == "disk":
            return DiskTier()
        raise ValueError(
            f"cold_tier must be None, 'host', 'disk' or a ColdTier instance, "
            f"got {cold_tier!r}")
    if isinstance(cold_tier, ColdTier):
        return cold_tier
    raise TypeError(f"not a ColdTier: {cold_tier!r} (needs put/get/delete/"
                    "stats/close and a kind attribute)")
