"""step.trace — end-to-end tracing & metrics for the DSM, threads and collectives.

You can't control what you can't see: STEP's pitch is fine-grained control
over distributed threads and shared data, and until this module the repo's
only introspection was ``wire_traffic()`` byte counts plus ad-hoc counter
dicts.  ``step.trace`` is the measurement substrate every perf decision is
judged against: a low-overhead, thread-safe event/metric layer threaded
through every hot path —

* **store ops** (`ShardedStore` get/set/inc/mget): spans + per-shard latency
  histograms + shard-lock wait time;
* **DSM cache**: replica hit/miss/invalidation/eviction counters;
* **sync** (`DBarrier` / `DSemaphore` / `SSPClock`): per-thread entry→release
  wait spans, queue depth, clock skew and stall time;
* **accumulator rounds** (`DAddAccumulator`): per-thread round spans, barrier
  wait, compress time, pair counts and the dense-vs-sparse branch taken;
* **SPMD backend**: per-``lax.scan`` trip accounting plus trace/compile/
  execute timing — device code cannot emit host events mid-program, so
  collective counters settle at ``join()`` exactly like AUTO traffic does.

Two access levels:

* ``Session(trace=True)`` arms a :class:`Tracer`; ``session.tracer`` records,
  ``session.metrics()`` snapshots (superseding and wrapping ``stats()`` /
  ``shard_stats()`` without breaking them), and
  ``session.tracer.export("trace.json")`` writes a Chrome-trace /
  Perfetto-loadable JSON where a fit run renders as per-thread timelines of
  store / barrier / accumulate spans.
* **No-op by default**: every instrumented object holds a (disabled) tracer
  and every hot path is guarded by the module-level :data:`TRACING` flag
  first — when no tracer is armed the added cost is one module-attribute
  load and a falsy branch: no dict, no event, no timestamp is allocated.

The recording side is intentionally dumb — append-only event list (bounded,
drops counted), flat counters, fixed-size-sample histograms — so one lock
suffices and recording never calls back into store/sync code (the tracer
lock is a leaf in the locking order).
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional

# ---------------------------------------------------------------------------
# Module-level fast path: TRACING is True iff at least one Tracer is armed.
# Hot paths check `telemetry.TRACING` BEFORE touching their tracer, so the
# disabled-by-default cost is a module attribute load + branch.
# ---------------------------------------------------------------------------

TRACING = False

_armed: set = set()
_armed_lock = threading.Lock()


def _arm(tracer: "Tracer") -> None:
    global TRACING
    with _armed_lock:
        _armed.add(tracer)
        TRACING = True


def _disarm(tracer: "Tracer") -> None:
    global TRACING
    with _armed_lock:
        _armed.discard(tracer)
        TRACING = bool(_armed)


def armed_count() -> int:
    """How many tracers are currently enabled (the leak-check hook: tier-1
    tests must leave this at 0, enforced by an autouse conftest fixture)."""
    with _armed_lock:
        return len(_armed)


def reset() -> int:
    """Disable every armed tracer; returns how many were disabled.  Test
    hygiene only — a leaked enabled tracer would slow (and cross-pollute)
    every later test in the process."""
    with _armed_lock:
        leaked = list(_armed)
    for t in leaked:
        t.disable()
    return len(leaked)


# ---------------------------------------------------------------------------
# Histograms: bounded-sample latency/derived-value distributions
# ---------------------------------------------------------------------------


class Hist:
    """Count/total/max plus a bounded reservoir of observations for
    percentile estimation.  Values are unit-free (store ops record
    microseconds; queue depth and clock skew record plain counts).

    The reservoir is Vitter's Algorithm R over the full observation stream:
    once SAMPLE values are held, the i-th observation replaces a uniformly
    chosen slot with probability SAMPLE/i, so every observation — first or
    last — has equal weight in the quantiles.  (The previous most-recent-ring
    retention made long-run p99 a recency window; pure first-N would bias it
    toward warm-up.)  Randomness comes from a per-hist xorshift64 stream with
    a fixed seed: identical observation sequences give identical quantiles,
    and there is no cross-hist or cross-run jitter to chase in tests.

    ``add`` is called without the tracer lock (see ``Tracer.observe``) and is
    written to be GIL-race-tolerant: concurrent adds may lose an occasional
    increment or reservoir slot (stats-grade undercounting) but can never
    raise or corrupt the sample — every index used is bounded by SAMPLE,
    which ``_sample`` can only grow past, never shrink below."""

    __slots__ = ("count", "total", "max", "_sample", "_rng")
    SAMPLE = 4096
    _SEED = 0x9E3779B97F4A7C15  # any odd non-zero constant works

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.max = 0.0
        self._sample: List[float] = []
        self._rng = self._SEED

    def add(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v > self.max:
            self.max = v
        if len(self._sample) < self.SAMPLE:
            self._sample.append(v)
        else:                       # Algorithm R: keep slot j with p=SAMPLE/i
            x = self._rng
            x ^= (x << 13) & 0xFFFFFFFFFFFFFFFF
            x ^= x >> 7
            x ^= (x << 17) & 0xFFFFFFFFFFFFFFFF
            self._rng = x
            j = x % self.count
            if j < self.SAMPLE:
                self._sample[j] = v

    def snapshot(self) -> Dict[str, float]:
        s = sorted(self._sample)
        q = (lambda p: s[min(len(s) - 1, int(p * len(s)))]) if s else (lambda p: 0.0)
        return {
            "count": self.count,
            "total": self.total,
            "mean": self.total / self.count if self.count else 0.0,
            "p50": q(0.50), "p90": q(0.90), "p99": q(0.99),
            "max": self.max,
        }


# ---------------------------------------------------------------------------
# Ring sink: the flight-recorder backing store (step.obs)
# ---------------------------------------------------------------------------


class RingSink:
    """Fixed-capacity overwrite-oldest event buffer.

    The bounded counterpart of the tracer's unbounded ``_events`` list: a
    :class:`~repro.obs.FlightRecorder` hangs one of these off a tracer
    (``tracer.ring``) so the last ``capacity`` events are always available
    for a post-incident dump, at O(capacity) memory no matter how long the
    session runs.  ``append`` is called under the tracer lock; ``snapshot``
    must be too (the tracer's ``ring_events`` wraps it)."""

    __slots__ = ("capacity", "_buf", "_next", "total")

    def __init__(self, capacity: int = 4096):
        if capacity <= 0:
            raise ValueError(f"ring capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._buf: List[Optional[dict]] = [None] * self.capacity
        self._next = 0
        self.total = 0  # lifetime appends; total - len(self) were overwritten

    def append(self, ev: dict) -> None:
        self._buf[self._next] = ev
        self._next = (self._next + 1) % self.capacity
        self.total += 1

    def __len__(self) -> int:
        return min(self.total, self.capacity)

    def snapshot(self) -> List[dict]:
        """Held events oldest→newest (shallow copies, safe to mutate/json)."""
        if self.total < self.capacity:
            rows = self._buf[:self.total]
        else:
            rows = self._buf[self._next:] + self._buf[:self._next]
        return [dict(e) for e in rows if e is not None]


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------


#: Span categories always materialised into the ring in record-only mode,
#: regardless of duration: rare lifecycle edges (migration windows, SPMD
#: trace/execute) and anomaly breadcrumbs are exactly what a post-incident
#: dump is for, and none of them sit on a per-op hot path.
ALWAYS_RECORD = frozenset({"migration", "anomaly", "spmd", "lifecycle"})


class _SpanCM:
    """Context-manager span: records one complete ('X') event on exit."""

    __slots__ = ("_trc", "cat", "name", "args", "t0")

    def __init__(self, trc: "Tracer", cat: str, name: str, args: Optional[dict]):
        self._trc = trc
        self.cat = cat
        self.name = name
        self.args = args

    def __enter__(self) -> "_SpanCM":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._trc.add_span(self.cat, self.name, self.t0, time.perf_counter(),
                           self.args)


class _NullCM:
    """Reusable no-op context manager (``ctx.span`` when tracing is off or
    the step body is traced rather than executed)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        return None


NULL_SPAN = _NullCM()


class Tracer:
    """Thread-safe structured span/counter/histogram recorder with a
    Chrome-trace (``chrome://tracing`` / Perfetto) exporter.

    Span categories used by the built-in instrumentation:

    ========================  ====================================================
    ``store-op``              every ``ShardedStore`` get/set/inc/mget
    ``barrier-wait``          ``DBarrier.enter`` and the accumulator round barrier
    ``accumulate-round``      one span per thread per accumulator round (name
                              ``accumulate``) + one reduce span per round (name
                              ``accumulate.round``, carrying the branch taken)
    ``sync``                  semaphore acquire waits, SSP stalls
    ``app-round``             workload round boundaries via ``ctx.span(...)``
    ``spmd``                  SPMD trace / compile+execute / lower timing
    ========================  ====================================================

    Recording methods are cheap but not free: callers on hot paths must guard
    with ``telemetry.TRACING and tracer.enabled`` (every built-in call site
    does), so a disabled tracer costs one branch.
    """

    def __init__(self, *, enabled: bool = False, max_events: int = 200_000):
        self.max_events = int(max_events)
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()
        self._events: List[dict] = []
        self.dropped_events = 0
        # step.obs flight-recorder hooks.  `ring` (a RingSink) additionally
        # receives every materialised event.  `record_only` is the armed-
        # recorder mode: counters/hists accumulate as usual, but span events
        # are materialised ONLY into the ring, and only when slow (duration
        # >= slow_us) or in an ALWAYS_RECORD category — the unbounded
        # `_events` list stays empty and fast ops allocate nothing, which is
        # what makes `Session(record=True)` cheap enough to leave on.
        self.ring: Optional[RingSink] = None
        self.record_only = False
        self.slow_us = 1000.0
        self._counters: Dict[str, float] = {}
        self._hists: Dict[str, Hist] = {}
        self._shard_hists: Dict[str, Dict[int, Hist]] = {}
        self._span_counts: Dict[str, int] = {}
        self._threads: Dict[tuple, str] = {}   # (pid, tid) -> display label
        self._tls = threading.local()
        self.enabled = False
        if enabled:
            self.enable()

    # -- arming ---------------------------------------------------------------

    def enable(self) -> "Tracer":
        if not self.enabled:
            self.enabled = True
            _arm(self)
        return self

    def disable(self) -> "Tracer":
        if self.enabled:
            self.enabled = False
            _disarm(self)
        return self

    def __enter__(self) -> "Tracer":
        return self.enable()

    def __exit__(self, *exc) -> None:
        self.disable()

    # -- thread identity ------------------------------------------------------

    def bind_thread(self, tid: int, node_id: int, label: Optional[str] = None) -> None:
        """Attach the calling OS thread to a STEP (tid, node): its spans land
        on that timeline (pid=node, tid=tid) in the exported trace."""
        self._tls.tid = int(tid)
        self._tls.pid = int(node_id)
        with self._lock:
            self._threads[(int(node_id), int(tid))] = label or f"step-thread-{tid}"

    def _ids(self) -> tuple:
        tid = getattr(self._tls, "tid", None)
        if tid is not None:
            return self._tls.pid, tid
        # unbound (driver / helper) threads: a stable per-thread display id
        return 0, 100_000 + (threading.get_ident() % 100_000)

    # -- recording ------------------------------------------------------------

    def now(self) -> float:
        return time.perf_counter()

    def add_span(self, cat: str, name: str, t0: float, t1: float,
                 args: Optional[dict] = None) -> None:
        if (self.record_only and (t1 - t0) * 1e6 < self.slow_us
                and cat not in ALWAYS_RECORD):
            # armed-recorder fast path: fast ops leave no event (their latency
            # still lands in the histograms via observe/store_op/wait_span).
            # Skipping the lock here means `spans_by_category` undercounts
            # fast spans in record-only mode — a documented trade for not
            # serialising every hot op on the tracer lock twice.
            return
        pid, tid = self._ids()
        ev = {"name": name, "cat": cat, "ph": "X",
              "ts": (t0 - self._epoch) * 1e6, "dur": (t1 - t0) * 1e6,
              "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        with self._lock:
            self._span_counts[cat] = self._span_counts.get(cat, 0) + 1
            if self.ring is not None:
                self.ring.append(ev)
            if self.record_only:
                return              # ring only: `_events` must stay bounded
            if len(self._events) < self.max_events:
                self._events.append(ev)
            else:
                self.dropped_events += 1

    def mark(self, cat: str, name: str, **args) -> None:
        """Record an instant ('i') event.  Marks are never filtered by
        ``record_only``/``slow_us`` — they are the lifecycle breadcrumbs
        (window opened, anomaly fired, node died) a flight-recorder dump must
        contain even when every op around them was fast."""
        pid, tid = self._ids()
        ev = {"name": name, "cat": cat, "ph": "i", "s": "p",
              "ts": (time.perf_counter() - self._epoch) * 1e6,
              "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        with self._lock:
            self._span_counts[cat] = self._span_counts.get(cat, 0) + 1
            if self.ring is not None:
                self.ring.append(ev)
            if not self.record_only:
                if len(self._events) < self.max_events:
                    self._events.append(ev)
                else:
                    self.dropped_events += 1

    def count(self, name: str, amount: float = 1) -> None:
        # Lock-free like observe(): a get + set is GIL-atomic per step, and a
        # lost concurrent increment is stats-grade noise.  Counters that must
        # be exact (accumulator rounds, wire elements) are incremented from
        # exactly one thread per round, where no race exists.
        self._counters[name] = self._counters.get(name, 0) + amount

    def observe(self, name: str, value: float, shard: Optional[int] = None) -> None:
        # Deliberately lock-free: observe() fires 2-3× per store op — often
        # while the caller holds a shard lock — and serialising all worker
        # threads on the tracer lock here is what pushed the armed-recorder
        # overhead past its ≤5% budget.  Under the GIL every step below is
        # safe (setdefault is atomic; Hist.add mutates only per-hist state),
        # and a lost `count += 1` race is a benign sub-ppm undercount in a
        # stats-grade histogram, never a crash or a non-monotonic read.
        h = self._hists.get(name)
        if h is None:
            h = self._hists.setdefault(name, Hist())
        h.add(value)
        if shard is not None:
            per = self._shard_hists.get(name)
            if per is None:
                per = self._shard_hists.setdefault(name, {})
            hs = per.get(shard)
            if hs is None:
                hs = per.setdefault(shard, Hist())
            hs.add(value)

    def span(self, cat: str, name: str, **args) -> _SpanCM:
        return _SpanCM(self, cat, name, args or None)

    # fused helpers for the built-in instrumentation (span + histogram in one
    # call, so hot call sites stay one line)

    def store_op(self, op: str, shard: int, t0: float, **args) -> None:
        t1 = time.perf_counter()
        name = "store." + op
        us = (t1 - t0) * 1e6
        # record-only fast ops skip add_span entirely (no args dict, no call)
        if not self.record_only or us >= self.slow_us:
            self.add_span("store-op", name, t0, t1,
                          dict(args, shard=shard) if args else {"shard": shard})
        self.observe(name, us, shard=shard)

    def wait_span(self, cat: str, name: str, t0: float, **args) -> None:
        t1 = time.perf_counter()
        us = (t1 - t0) * 1e6
        if (not self.record_only or us >= self.slow_us
                or cat in ALWAYS_RECORD):
            self.add_span(cat, name, t0, t1, args or None)
        self.observe(name, us)

    # -- introspection --------------------------------------------------------

    def spans(self, cat: Optional[str] = None, name: Optional[str] = None) -> List[dict]:
        """Recorded span events, optionally filtered by category / name."""
        with self._lock:
            evs = list(self._events)
        if cat is not None:
            evs = [e for e in evs if e["cat"] == cat]
        if name is not None:
            evs = [e for e in evs if e["name"] == name]
        return evs

    def counters(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._counters)

    def ring_events(self) -> List[dict]:
        """Events currently held by the attached ring, oldest→newest (empty
        when no recorder ever attached a ring)."""
        with self._lock:
            return self.ring.snapshot() if self.ring is not None else []

    def hist(self, name: str) -> Optional[Dict[str, float]]:
        """One histogram's snapshot (None if never observed) — the watchdog's
        SLO source; cheaper than a full :meth:`snapshot`."""
        with self._lock:
            h = self._hists.get(name)
            return h.snapshot() if h is not None else None

    def shard_hist(self, name: str) -> Dict[int, Dict[str, float]]:
        """Per-shard snapshots of one histogram (empty if never observed)."""
        with self._lock:
            per = self._shard_hists.get(name)
            # list() first: observe() inserts without the lock, and the
            # comprehension runs bytecode (h.snapshot()) between iterations.
            return {sid: h.snapshot() for sid, h in list(per.items())} if per else {}

    def snapshot(self) -> Dict[str, Any]:
        """Structured metrics snapshot: span counts per category, counters,
        and per-op histograms (with rates) — the ``trace`` section of
        ``Session.metrics()`` and the heartbeat payload."""
        elapsed = max(time.perf_counter() - self._epoch, 1e-9)
        with self._lock:
            # Writers (observe/count) skip the lock, so iterate atomic list()
            # copies — a concurrent insert mid-comprehension would otherwise
            # raise "dictionary changed size during iteration".
            ops = {name: h.snapshot() for name, h in list(self._hists.items())}
            for name, snap in ops.items():
                snap["rate_per_s"] = snap["count"] / elapsed
            by_shard = {name: {sid: h.snapshot() for sid, h in list(per.items())}
                        for name, per in list(self._shard_hists.items())}
            return {
                "enabled": self.enabled,
                "record_only": self.record_only,
                "elapsed_s": elapsed,
                "events": len(self._events),
                "dropped_events": self.dropped_events,
                "ring": (None if self.ring is None else
                         {"capacity": self.ring.capacity,
                          "held": len(self.ring),
                          "total": self.ring.total}),
                "spans_by_category": dict(self._span_counts),
                "counters": dict(self._counters),
                "ops": ops,
                "ops_by_shard": by_shard,
            }

    # -- Chrome-trace export ---------------------------------------------------

    def chrome_trace(self) -> Dict[str, Any]:
        """The ``chrome://tracing`` / Perfetto JSON object: every recorded
        span as a complete ('X') event plus thread/process name metadata."""
        with self._lock:
            events = [dict(e) for e in self._events]
            threads = dict(self._threads)
        meta: List[dict] = []
        for pid in sorted({p for p, _ in threads} | {p["pid"] for p in events}):
            meta.append({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                         "args": {"name": f"node{pid}"}})
        for (pid, tid), label in sorted(threads.items()):
            meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                         "tid": tid, "args": {"name": label}})
        return {"traceEvents": meta + events, "displayTimeUnit": "ms",
                "otherData": {"producer": "step.trace",
                              "dropped_events": self.dropped_events}}

    def export(self, path: str) -> str:
        """Write the Chrome-trace JSON to ``path`` (load it in Perfetto or
        ``chrome://tracing`` for per-thread timelines).  Returns ``path``."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Tracer(enabled={self.enabled}, events={len(self._events)}, "
                f"counters={len(self._counters)})")


#: Shared default for instrumented objects constructed outside a Session.
#: Never enable this one directly — arm a fresh ``Tracer`` (or pass
#: ``Session(trace=True)``) so disabling it is scoped to your run.
NULL_TRACER = Tracer(enabled=False)


def as_tracer(trace) -> Tracer:
    """Resolve ``Session(trace=...)``: a :class:`Tracer` is adopted as-is
    (recovery re-arms the dead session's tracer this way), ``True`` arms a
    fresh one, ``None``/``False`` give a fresh *disabled* tracer that can be
    armed later via ``session.tracer.enable()``."""
    if isinstance(trace, Tracer):
        return trace
    return Tracer(enabled=bool(trace))


# ---------------------------------------------------------------------------
# Stats normalization (the unified-key-shape half of step.trace)
# ---------------------------------------------------------------------------

#: Canonical store counter keys (plural nouns, plain ints) — the normalized
#: form of the raw per-shard ``Shard.stats`` / ``ShardedStore.stats`` dicts,
#: whose legacy singular-verb keys remain available as deprecated views.
STORE_METRIC_KEYS = ("gets", "sets", "incs", "bytes_read", "bytes_written",
                     "transfers", "migrated_in", "migrated_out",
                     "migrated_bytes", "hot_hits", "cold_hits",
                     "promotions", "demotions")

_STORE_KEY_MAP = {"get": "gets", "set": "sets", "inc": "incs",
                  "bytes_get": "bytes_read", "bytes_set": "bytes_written",
                  "transfers": "transfers", "migrated_in": "migrated_in",
                  "migrated_out": "migrated_out",
                  "migrated_bytes": "migrated_bytes",
                  "hot_hits": "hot_hits", "cold_hits": "cold_hits",
                  "promotions": "promotions", "demotions": "demotions"}

#: Canonical cache counter keys (``CacheStats.as_dict()``).
CACHE_METRIC_KEYS = ("hits", "misses", "invalidations", "write_messages",
                     "missing_messages", "evictions", "hit_rate")

#: Top-level key set of ``Session.metrics()``.
SESSION_METRIC_KEYS = ("backend", "store", "cache", "wire_traffic", "shards",
                       "tiers", "trace")


def normalize_store_stats(raw: Dict[str, int]) -> Dict[str, Any]:
    """Map a raw store/shard counter dict onto the canonical key set.  Every
    canonical key is present (0 when the source lacks it); a per-shard row's
    ``names`` entry count rides along when the source has one."""
    out: Dict[str, Any] = {new: int(raw.get(old, 0))
                           for old, new in _STORE_KEY_MAP.items()}
    if "names" in raw:
        out["names"] = int(raw["names"])
    return out
