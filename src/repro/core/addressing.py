"""DSM shared-memory address space (paper §5.1), kept for the host directory.

STEP interprets a 64-bit shared-memory address as a high-order 32-bit
``object_id`` plus a low-order 32-bit ``field_id``; the DSM is organised in
32-bit *words*, and coarse-grained mode groups 32 consecutive words into a
*package* stored behind one KV pair, with package-aligned addressing.

On TPU the physical transport is ICI collectives rather than memcached RTTs,
but the layout policy survives: the package becomes a 128-element lane-aligned
tile (the TPU minor-dim tile), and "coarse-grained DSM" becomes fusing pytree
leaves into package-aligned flat buffers so each collective moves few, large,
aligned blocks (see :mod:`repro.core.dsm`).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass

# --- paper constants (§5.1) -------------------------------------------------
WORD_BYTES = 4            # DSM word = 32 bits
PACKAGE_WORDS = 32        # words per coarse-grained package
OBJECT_ID_BITS = 32       # default x in the paper
FIELD_ID_BITS = 64 - OBJECT_ID_BITS
GLOBALS_OBJECT_ID = 0     # virtual object holding all shared variables

# --- TPU adaptation ----------------------------------------------------------
# The TPU native minor-most tile is 128 lanes; a "package" on TPU is therefore
# 128 elements so packed buffers start on lane boundaries and collectives /
# DMA see aligned blocks. (For 4-byte words that is 512B, i.e. 4 paper packages.)
TPU_PACKAGE_ELEMS = 128


def make_address(object_id: int, field_id: int) -> int:
    """Compose the 64-bit DSM address ``object_id ++ field_id``."""
    if not (0 <= object_id < (1 << OBJECT_ID_BITS)):
        raise ValueError(f"object_id out of range: {object_id}")
    if not (0 <= field_id < (1 << FIELD_ID_BITS)):
        raise ValueError(f"field_id out of range: {field_id}")
    return (object_id << FIELD_ID_BITS) | field_id


def split_address(addr: int) -> tuple[int, int]:
    """Inverse of :func:`make_address`."""
    return addr >> FIELD_ID_BITS, addr & ((1 << FIELD_ID_BITS) - 1)


def package_id(addr: int) -> int:
    """Package (coarse block) index of an address — paper: addr words / 32."""
    return addr // PACKAGE_WORDS


def block_address(addr: int) -> int:
    """High-order 59 bits: address of the owning 32-word cache/data block."""
    return addr >> 5


def watcher_node(addr: int, n_nodes: int) -> int:
    """Directory owner for a block: node_id == block_address (mod n)  (§5.1)."""
    return block_address(addr) % n_nodes


def ring_hash(key) -> int:
    """Stable 64-bit ring position of a DSM key (a name or a block address).

    The paper's ``node_id ≡ block_address (mod n)`` assignment reshuffles
    *every* block when ``n`` changes; the sharded store instead places keys on
    a consistent-hash ring, so a shard join/leave moves only the ~1/S of keys
    whose arc changed owner.  ``blake2b`` keeps the placement stable across
    processes (Python's built-in ``hash`` is salted per run), which is what
    lets a recovered session adopt a surviving store without re-hashing it.
    """
    if isinstance(key, int):
        data = key.to_bytes(8, "little", signed=False)
    else:
        data = str(key).encode("utf-8")
    return int.from_bytes(hashlib.blake2b(data, digest_size=8).digest(), "big")


def align_up(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


@dataclass(frozen=True)
class FieldSlot:
    """Directory record: where a named field lives inside the DSM space."""

    object_id: int
    field_id: int
    num_words: int

    @property
    def address(self) -> int:
        return make_address(self.object_id, self.field_id)


class AddressAllocator:
    """Allocates object ids and package-aligned field offsets.

    Coarse-grained mode guarantees package-size-aligned shared-memory
    addresses (paper §5.1); fine-grained mode packs fields densely.
    """

    def __init__(self, coarse: bool = True):
        self.coarse = coarse
        self._next_object = GLOBALS_OBJECT_ID + 1
        self._next_field: dict[int, int] = {GLOBALS_OBJECT_ID: 0}

    def new_object(self) -> int:
        oid = self._next_object
        self._next_object += 1
        self._next_field[oid] = 0
        return oid

    def alloc_field(self, object_id: int, num_words: int) -> FieldSlot:
        cur = self._next_field.setdefault(object_id, 0)
        if self.coarse:
            cur = align_up(cur, PACKAGE_WORDS)
        slot = FieldSlot(object_id, cur, num_words)
        self._next_field[object_id] = cur + num_words
        return slot
