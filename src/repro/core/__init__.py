"""STEP's primary contribution as composable JAX modules.

- :mod:`repro.core.session` - the Table-1 facade: Session / SharedRef / backends
- :mod:`repro.core.dsm` - GlobalStore distributed shared memory (fine/coarse)
- :mod:`repro.core.shards` - consistent-hash sharded store beneath the DSM
- :mod:`repro.core.tiers` - step.tiers: pluggable cold tiers (host mem / disk)
- :mod:`repro.core.accumulator` - DAddAccumulator (SPMD + host forms)
- :mod:`repro.core.cache` - directory-based write-invalidate DSM cache
- :mod:`repro.core.sync` - DBarrier / DSemaphore / SSP clock
- :mod:`repro.core.threads` - DThread pool + shard_map SPMD adapter
- :mod:`repro.core.addressing` - the 64-bit DSM address space
- :mod:`repro.core.telemetry` - step.trace: spans/counters/histograms + export
- :mod:`repro.core.compat` - shims over moving JAX APIs (shard_map, meshes)

Most programs need only :class:`~repro.core.session.Session`: it owns the
store, cache, thread pool, sync controller and accumulator registry, and the
same workload code runs on the host or SPMD backend.
"""

from repro.core import telemetry
from repro.core.accumulator import AccumMode, DAddAccumulator, accumulate, accumulate_scatter, accumulate_tree
from repro.core.addressing import AddressAllocator, make_address, ring_hash, split_address, watcher_node
from repro.core.cache import DSMCache, CacheStats
from repro.core.compat import axis_size, cost_analysis, make_mesh, shard_map
from repro.core.dsm import GlobalStore, PackSpec, pack_spec, pack_tree, unpack_tree
from repro.core.session import Backend, HostBackend, Session, SharedRef, SpmdBackend, WorkerCtx
from repro.core.shards import (
    HashRing,
    MigrationWindow,
    OwnerHandle,
    Shard,
    ShardedStore,
    ShardMigration,
)
from repro.core.sparse import (
    blocked_topk_accumulate,
    blocked_topk_sparsify,
    densify,
    sparse_beneficial,
    sparse_beneficial_batch,
    topk_sparsify,
)
from repro.core.sync import DBarrier, DSemaphore, SSPClock
from repro.core.tiers import ColdTier, DiskTier, HostMemTier
from repro.core.telemetry import NULL_TRACER, Tracer, as_tracer
from repro.core.threads import DThread, DThreadPool, ThreadState, spmd_threads

__all__ = [
    "AccumMode", "DAddAccumulator", "accumulate", "accumulate_scatter", "accumulate_tree",
    "AddressAllocator", "make_address", "ring_hash", "split_address", "watcher_node",
    "DSMCache", "CacheStats",
    "axis_size", "cost_analysis", "make_mesh", "shard_map",
    "GlobalStore", "PackSpec", "pack_spec", "pack_tree", "unpack_tree",
    "Backend", "HostBackend", "Session", "SharedRef", "SpmdBackend", "WorkerCtx",
    "HashRing", "MigrationWindow", "OwnerHandle", "Shard", "ShardedStore", "ShardMigration",
    "ColdTier", "DiskTier", "HostMemTier",
    "blocked_topk_accumulate", "blocked_topk_sparsify", "densify",
    "sparse_beneficial", "sparse_beneficial_batch", "topk_sparsify",
    "DBarrier", "DSemaphore", "SSPClock",
    "telemetry", "Tracer", "NULL_TRACER", "as_tracer",
    "DThread", "DThreadPool", "ThreadState", "spmd_threads",
]
