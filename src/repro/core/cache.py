"""DSM cache layer — STEP §5.1's directory-based write-invalidate cache.

The paper's cache absorbs DSM reads on a hit and invalidates remote copies on
writes through per-block *watcher node* directories.  On a TPU pod the data
plane is ICI, but the control plane survives unchanged: each logical node
keeps a bounded LRU of *replicas* keyed by DSM name, validated by the store's
per-entry epoch; a write bumps the epoch (write-through) and the directory
records which nodes must invalidate.  Hit/miss/invalidate counters make the
paper's throughput argument measurable in tests and benchmarks.

Inside a jitted step the analogous mechanism is the decode KV/SSM-state cache
(models/) and the per-step local parameter replica refreshed by the
accumulator's all-gather phase — see DESIGN.md §2.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Optional, Set

from repro.core.addressing import watcher_node
from repro.core.dsm import GlobalStore


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    invalidations: int = 0
    write_messages: int = 0   # "write" messages to watcher nodes
    missing_messages: int = 0  # "missing" messages to watcher nodes
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class _NodeCache:
    """One node's bounded LRU of (name -> (epoch, value)) replicas."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.blocks: OrderedDict[str, tuple[int, object]] = OrderedDict()

    def get(self, name: str):
        if name in self.blocks:
            self.blocks.move_to_end(name)
            return self.blocks[name]
        return None

    def put(self, name: str, epoch: int, value) -> Optional[str]:
        """Insert a replica; returns the evicted name (LRU) or None.  The
        caller must drop the evicted name from the watcher directory, or the
        node stays listed as a holder forever."""
        evicted = None
        if name not in self.blocks and len(self.blocks) >= self.capacity:
            evicted, _ = self.blocks.popitem(last=False)  # LRU eviction
        self.blocks[name] = (epoch, value)
        self.blocks.move_to_end(name)
        return evicted

    def invalidate(self, name: str) -> bool:
        return self.blocks.pop(name, None) is not None


class DSMCache:
    """Directory-based write-invalidate cache over a :class:`GlobalStore`.

    ``n_nodes`` logical nodes each hold ``capacity`` replicas (paper: 1024
    blocks/node).  The watcher node for a name is derived from its DSM block
    address, exactly as §5.1's ``node_id ≡ block_address (mod n)``.
    """

    def __init__(self, store: GlobalStore, n_nodes: int, capacity: int = 1024):
        self.store = store
        self.n_nodes = n_nodes
        self.caches = [_NodeCache(capacity) for _ in range(n_nodes)]
        # directory[watcher][name] = set of node ids holding a replica
        self.directory: list[Dict[str, Set[int]]] = [dict() for _ in range(n_nodes)]
        self.stats = CacheStats()

    def _watcher(self, name: str) -> int:
        return watcher_node(self.store.address(name), self.n_nodes)

    def _forget_holder(self, node_id: int, name: str) -> None:
        """Remove ``node_id`` from ``name``'s watcher directory (the replica
        is gone).  A name no longer in the store has no derivable watcher, so
        fall back to scanning every directory."""
        try:
            dirs = [self.directory[self._watcher(name)]]
        except KeyError:
            dirs = self.directory
        for d in dirs:
            holders = d.get(name)
            if holders is not None:
                holders.discard(node_id)
                if not holders:
                    del d[name]

    def _note_eviction(self, node_id: int, evicted: Optional[str]) -> None:
        if evicted is None:
            return
        self.stats.evictions += 1
        self._forget_holder(node_id, evicted)

    # -- reads ---------------------------------------------------------------

    def read(self, node_id: int, name: str):
        cached = self.caches[node_id].get(name)
        current_epoch = self.store.epoch(name)
        if cached is not None and cached[0] == current_epoch:
            self.stats.hits += 1
            return cached[1]
        # miss: fetch through the DSM internal layer + tell the watcher
        self.stats.misses += 1
        self.stats.missing_messages += 1
        value = self.store.get(name)
        self._note_eviction(node_id, self.caches[node_id].put(name, current_epoch, value))
        w = self._watcher(name)
        self.directory[w].setdefault(name, set()).add(node_id)
        return value

    # -- writes (write-through + invalidate) ----------------------------------

    def write(self, node_id: int, name: str, value) -> None:
        self.store.set(name, value)                    # write-through
        epoch = self.store.epoch(name)
        w = self._watcher(name)
        self.stats.write_messages += 1
        holders = self.directory[w].get(name, set())
        for holder in list(holders):
            if holder != node_id:
                if self.caches[holder].invalidate(name):
                    self.stats.invalidations += 1
                holders.discard(holder)
        # the writer keeps (updates) its own replica
        self._note_eviction(node_id, self.caches[node_id].put(name, epoch, value))
        holders.add(node_id)
        self.directory[w][name] = holders

    # -- bypass (atomic ops skip the cache, per §5.1) --------------------------

    def atomic_inc(self, name: str, amount=1):
        val = self.store.inc(name, amount)
        # epoch bump means every cached replica is now stale; lazily invalid.
        return val

    # -- teardown (DelArray / DelObj) ------------------------------------------

    def drop(self, name: str) -> None:
        """Purge every node's replica of ``name`` and every directory record —
        the coherence half of a DSM delete.  Without it, a deleted-then-
        re-declared name leaves phantom holders and (pre-generation-epochs)
        could serve the deleted era's value."""
        for c in self.caches:
            c.invalidate(name)
        for d in self.directory:
            d.pop(name, None)
