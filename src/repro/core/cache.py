"""DSM cache layer — STEP §5.1's directory-based write-invalidate cache.

The paper's cache absorbs DSM reads on a hit and invalidates remote copies on
writes through per-block *watcher node* directories.  On a TPU pod the data
plane is ICI, but the control plane survives unchanged: each logical node
keeps a bounded LRU of *replicas* keyed by DSM name, validated by the store's
per-entry epoch; a write bumps the epoch (write-through) and the directory
records which nodes must invalidate.  Hit/miss/invalidate counters make the
paper's throughput argument measurable in tests and benchmarks.

Since ``step.shards`` landed, the directory is **shard-local**: the watcher
for a name is the consistent-hash shard that owns it (the ring plays the role
``node_id ≡ block_address (mod n)`` played in §5.1), the directory record
lives on that :class:`~repro.core.shards.Shard` and is guarded by *its* lock
— so coherence traffic for names on different shards never serialises on a
common lock, and a ring rebalance migrates each record together with its
entry.  Node replica LRUs are guarded by small per-node locks; lock order is
strictly shard → node, and eviction cleanup for a name owned by a *different*
shard happens after the held shard lock is released.

Inside a jitted step the analogous mechanism is the decode KV/SSM-state cache
(models/) and the per-step local parameter replica refreshed by the
accumulator's all-gather phase — see DESIGN.md §2.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.check import checker as stepcheck
from repro.core import telemetry
from repro.core.shards import ShardedStore


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    invalidations: int = 0
    write_messages: int = 0   # "write" messages to watcher nodes
    missing_messages: int = 0  # "missing" messages to watcher nodes
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> Dict[str, float]:
        """Canonical plain-dict view (the ``cache`` rows of
        ``Session.metrics()``); key set pinned by
        :data:`repro.core.telemetry.CACHE_METRIC_KEYS`."""
        return {"hits": self.hits, "misses": self.misses,
                "invalidations": self.invalidations,
                "write_messages": self.write_messages,
                "missing_messages": self.missing_messages,
                "evictions": self.evictions, "hit_rate": self.hit_rate}


class _NodeCache:
    """One node's bounded LRU of (name -> (epoch, value)) replicas.

    Carries its own lock: with a sharded store, threads working on different
    shards may race into the same node's LRU (the replica set is per *node*,
    not per shard)."""

    def __init__(self, node_id: int, capacity: int):
        self.id = node_id
        self.capacity = capacity
        self.blocks: OrderedDict[str, tuple[int, object]] = OrderedDict()
        self._lock = threading.Lock()
        # step.check target: DSMCache propagates the session's checker here
        # so node-lock acquisitions land in the lock-order sanitizer
        self.checker = stepcheck.NULL_CHECKER

    def get(self, name: str):
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            ck.lock_acquired(("node", self.id))
            try:
                return self._get(name)
            finally:
                ck.lock_released(("node", self.id))
        return self._get(name)

    def _get(self, name: str):
        with self._lock:
            if name in self.blocks:
                self.blocks.move_to_end(name)
                return self.blocks[name]
            return None

    def put(self, name: str, epoch: int, value) -> Optional[str]:
        """Insert a replica; returns the evicted name (LRU) or None.  The
        caller must drop the evicted name from the watcher directory, or the
        node stays listed as a holder forever."""
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            ck.lock_acquired(("node", self.id))
            try:
                return self._put(name, epoch, value)
            finally:
                ck.lock_released(("node", self.id))
        return self._put(name, epoch, value)

    def _put(self, name: str, epoch: int, value) -> Optional[str]:
        with self._lock:
            evicted = None
            if name not in self.blocks and len(self.blocks) >= self.capacity:
                evicted, _ = self.blocks.popitem(last=False)  # LRU eviction
            self.blocks[name] = (epoch, value)
            self.blocks.move_to_end(name)
            return evicted

    def invalidate(self, name: str) -> bool:
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            ck.lock_acquired(("node", self.id))
            try:
                return self._invalidate(name)
            finally:
                ck.lock_released(("node", self.id))
        return self._invalidate(name)

    def _invalidate(self, name: str) -> bool:
        with self._lock:
            return self.blocks.pop(name, None) is not None

    def contains(self, name: str) -> bool:
        """Membership without touching LRU order (eviction-cleanup guard)."""
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            ck.lock_acquired(("node", self.id))
            try:
                return self._contains(name)
            finally:
                ck.lock_released(("node", self.id))
        return self._contains(name)

    def _contains(self, name: str) -> bool:
        with self._lock:
            return name in self.blocks


class DSMCache:
    """Directory-based write-invalidate cache over a sharded store.

    ``n_nodes`` logical nodes each hold ``capacity`` replicas (paper: 1024
    blocks/node).  The watcher for a name is its owning shard; the directory
    record lives on that shard, under that shard's lock.  Constructing the
    cache registers a store-side delete hook, so even a *direct*
    ``store.delete(name)`` (bypassing ``Session.delete``) tears down every
    replica and directory holder of the name.
    """

    def __init__(self, store: ShardedStore, n_nodes: int, capacity: int = 1024):
        self.store = store
        self.n_nodes = n_nodes
        self.caches = [_NodeCache(i, capacity) for i in range(n_nodes)]
        self._checker = stepcheck.NULL_CHECKER
        # per-shard coherence counters, aggregated by the `stats` property
        self._stats: Dict[int, CacheStats] = {}
        # weak: the store outlives sessions rolled over it (FT recovery);
        # this cache's teardown hook must die with the cache, not pin it
        store.add_delete_hook(self.drop, weak=True)
        # step.trace target (Session attaches its tracer); coherence events
        # are counters, not spans — timing lives on the store ops beneath
        self.tracer = telemetry.NULL_TRACER

    @property
    def checker(self):
        return self._checker

    @checker.setter
    def checker(self, ck) -> None:
        """Session attaches its checker here; node LRUs share it so their
        lock acquisitions land in the lock-order sanitizer."""
        self._checker = ck
        for c in self.caches:
            c.checker = ck

    def _shard_stats(self, shard_id: int) -> CacheStats:
        return self._stats.setdefault(shard_id, CacheStats())

    @property
    def stats(self) -> CacheStats:
        """Aggregate coherence counters across shards."""
        total = CacheStats()
        for s in self._stats.values():
            total.hits += s.hits
            total.misses += s.misses
            total.invalidations += s.invalidations
            total.write_messages += s.write_messages
            total.missing_messages += s.missing_messages
            total.evictions += s.evictions
        return total

    def shard_stats(self) -> Dict[int, CacheStats]:
        """Per-shard coherence counters, keyed by shard id."""
        return dict(self._stats)

    @property
    def directory(self) -> List[Dict[str, set]]:
        """The shard-local watcher directories (one dict per active shard)."""
        return [self.store._shards[sid].directory
                for sid in self.store.shard_ids()]

    def _forget_holder(self, node_id: int, name: str) -> None:
        """Remove ``node_id`` from ``name``'s shard directory (the replica is
        gone).  Resolves the owner through the ring — a deleted name still
        hashes to a shard, so no directory scan is needed.

        Guarded against the eviction/re-read race: cleanup runs *after* the
        evicting op released its shard lock, so the same node may have
        re-read the name in between.  Re-reads register their holdership
        under this same shard lock, so checking the node's LRU here decides
        atomically — if the replica is back, the holder record must stay."""
        with self.store.locked_owner(name) as shard:
            if self.caches[node_id].contains(name):
                return
            holders = shard.directory.get(name)
            if holders is not None:
                holders.discard(node_id)
                if not holders:
                    del shard.directory[name]

    def _note_eviction(self, node_id: int, evicted: Optional[str]) -> None:
        if evicted is None:
            return
        with self.store.locked_owner(evicted) as shard:
            self._shard_stats(shard.id).evictions += 1
        if telemetry.TRACING and self.tracer.enabled:
            self.tracer.count("cache.evictions")
        self._forget_holder(node_id, evicted)

    # -- reads ---------------------------------------------------------------

    def read(self, node_id: int, name: str, *, owner=None):
        evicted = None
        trc = self.tracer
        tracing = telemetry.TRACING and trc.enabled
        try:
            with self.store.locked_entry(name, owner) as (shard, entry):
                stats = self._shard_stats(shard.id)
                cached = self.caches[node_id].get(name)
                if cached is not None and cached[0] == entry.epoch:
                    stats.hits += 1
                    if tracing:
                        trc.count("cache.replica_hits")
                    return cached[1]
                # miss: fetch through the DSM internal layer + tell the watcher
                stats.misses += 1
                stats.missing_messages += 1
                if tracing:
                    trc.count("cache.replica_misses")
                # re-entrant on the held shard lock; the handle spares the
                # nested op its second ring_hash of the same name
                value = self.store.get(name, owner=owner)
                evicted = self.caches[node_id].put(name, entry.epoch, value)
                shard.directory.setdefault(name, set()).add(node_id)
                return value
        finally:
            # the evicted name may be owned by a different shard: clean up
            # after this shard's lock is released (lock order: one shard at
            # a time, never shard → shard)
            self._note_eviction(node_id, evicted)

    # -- writes (write-through + invalidate) ----------------------------------

    def write(self, node_id: int, name: str, value, *, owner=None) -> None:
        evicted = None
        try:
            with self.store.locked_entry(name, owner) as (shard, entry):
                stats = self._shard_stats(shard.id)
                self.store.set(name, value, owner=owner)       # write-through
                stats.write_messages += 1
                holders = shard.directory.get(name, set())
                invalidated = 0
                for holder in list(holders):
                    if holder != node_id:
                        # the store outlives sessions (FT recovery rolls a
                        # smaller world over it): a holder id beyond this
                        # session's node count is a dead session's record —
                        # there is no replica to invalidate, just drop it
                        if (holder < len(self.caches)
                                and self.caches[holder].invalidate(name)):
                            stats.invalidations += 1
                            invalidated += 1
                        holders.discard(holder)
                # one batched tracer count per write, not one per holder —
                # this runs under the shard lock, where tracer time multiplies
                if invalidated and telemetry.TRACING and self.tracer.enabled:
                    self.tracer.count("cache.invalidations", invalidated)
                # the writer keeps (updates) its own replica
                evicted = self.caches[node_id].put(name, entry.epoch, value)
                holders.add(node_id)
                shard.directory[name] = holders
        finally:
            self._note_eviction(node_id, evicted)

    # -- bypass (atomic ops skip the cache, per §5.1) --------------------------

    def atomic_inc(self, name: str, amount=1, *, owner=None):
        val = self.store.inc(name, amount, owner=owner)
        # epoch bump means every cached replica is now stale; lazily invalid.
        return val

    # -- teardown (DelArray / DelObj) ------------------------------------------

    def drop(self, name: str) -> None:
        """Purge every node's replica of ``name`` and its directory record —
        the coherence half of a DSM delete.  Registered as a store delete
        hook, so it also fires for direct ``store.delete`` calls; without it,
        a deleted-then-re-declared name leaves phantom holders and
        (pre-generation-epochs) could serve the deleted era's value."""
        for c in self.caches:
            c.invalidate(name)
        with self.store.locked_owner(name) as shard:
            shard.directory.pop(name, None)
