"""Distributed thread synchronization — STEP §4.3/§5.3 (+ SSP for async).

The paper's master runs a *sync controller*: barriers are counters that
broadcast "release" when full; semaphores are counters with a FIFO wait queue.
Those semantics are reproduced exactly for the host-side thread pool (the
Pthreads-style programming model).  On the SPMD path a barrier is implicit in
every collective — `sync controller == the collective schedule` — so the SPMD
adapter simply documents the correspondence.

``SSPClock`` adds the bounded-staleness coordination STEP cites from Petuum:
workers may run up to `staleness` iterations ahead of the slowest worker —
this is the straggler-mitigation knob for the training path.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, Optional

from repro.check import checker as stepcheck
from repro.core import telemetry


class DBarrier:
    """Counter-based barrier with the paper's ``Enter(timeout)`` API.

    When a tracer is armed (``barrier.tracer``, attached by
    ``Session.barrier()`` and to the backend's run barrier), every ``enter``
    records a per-thread entry→release span (category ``barrier-wait``) and
    feeds the ``barrier.wait`` latency histogram.

    In-flight waits are tracked regardless of tracing (two dict ops under
    the condition lock per blocked enter): ``oldest_wait_start()`` is how
    the step.obs watchdog sees a straggler *while it is still waiting*, not
    only after the wait lands in the histogram."""

    watch_kind = "barrier"   # step.obs watchdog registry tag

    def __init__(self, count: int):
        self.count = count
        self._cond = threading.Condition()
        self._arrived = 0
        self._generation = 0
        self.entries = 0  # stats: total Enter calls observed by the controller
        self._wait_t0: Dict[int, float] = {}  # thread ident -> wait start
        self.tracer = telemetry.NULL_TRACER
        self.checker = stepcheck.NULL_CHECKER

    def enter(self, timeout: Optional[float] = None) -> bool:
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            if ck.lint_sync(self, "barrier") is not None:
                return True          # lint dry run: recorded, never blocks
            ck.sync_block(self, "barrier")
            ok = False
            try:
                ok = self._enter_traced(timeout)
            finally:
                ck.sync_unblock(self, "barrier", ok)
            return ok
        return self._enter_traced(timeout)

    def _enter_traced(self, timeout: Optional[float] = None) -> bool:
        trc = self.tracer
        if telemetry.TRACING and trc.enabled:
            t0 = time.perf_counter()
            ok = self._enter(timeout)
            trc.wait_span("barrier-wait", "barrier.wait", t0, released=ok)
            return ok
        return self._enter(timeout)

    def _enter(self, timeout: Optional[float] = None) -> bool:
        with self._cond:
            gen = self._generation
            self._arrived += 1
            self.entries += 1
            if self._arrived == self.count:
                # last arrival: "release" broadcast
                self._arrived = 0
                self._generation += 1
                self._cond.notify_all()
                return True
            t = None if (timeout is None or timeout < 0) else timeout
            ident = threading.get_ident()
            self._wait_t0[ident] = time.perf_counter()
            try:
                while gen == self._generation:
                    if not self._cond.wait(timeout=t):
                        return False
                return True
            finally:
                self._wait_t0.pop(ident, None)

    def oldest_wait_start(self) -> Optional[float]:
        """``perf_counter`` timestamp of the longest-blocked in-flight enter
        (None when nobody is waiting) — the watchdog's live-stall probe."""
        with self._cond:
            return min(self._wait_t0.values(), default=None)

    def waiters(self) -> int:
        with self._cond:
            return len(self._wait_t0)

    # paper-cased alias (Enter(int timeout=-1))
    def Enter(self, timeout: float = -1) -> bool:
        return self.enter(None if timeout is None or timeout < 0 else timeout)


class DSemaphore:
    """Counting semaphore with FIFO wakeup, as specified in §5.3.

    Like :class:`DBarrier`, in-flight acquire waits are tracked always
    (``oldest_wait_start()``), so the watchdog can flag a starved acquirer
    before its wait ever completes into the latency histogram."""

    watch_kind = "semaphore"   # step.obs watchdog registry tag

    def __init__(self, count: int):
        if count < 0:
            raise ValueError("semaphore count must be non-negative")
        self._count = count
        self._cond = threading.Condition()
        self._queue: deque[int] = deque()
        self._ticket = 0
        self._wait_t0: Dict[int, float] = {}  # ticket -> wait start
        self.tracer = telemetry.NULL_TRACER
        self.checker = stepcheck.NULL_CHECKER

    def acquire(self, timeout: Optional[float] = None) -> bool:
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            if ck.lint_sync(self, "semaphore") is not None:
                return True          # lint dry run: recorded, never blocks
            ck.sync_block(self, "semaphore")
            ok = False
            try:
                ok = self._acquire_traced(timeout)
            finally:
                ck.sync_unblock(self, "semaphore", ok)
            return ok
        return self._acquire_traced(timeout)

    def _acquire_traced(self, timeout: Optional[float] = None) -> bool:
        trc = self.tracer
        if telemetry.TRACING and trc.enabled:
            t0 = time.perf_counter()
            ok = self._acquire(timeout)
            trc.wait_span("sync", "semaphore.acquire", t0, acquired=ok)
            return ok
        return self._acquire(timeout)

    def _acquire(self, timeout: Optional[float] = None) -> bool:
        trc = self.tracer
        with self._cond:
            ticket = self._ticket
            self._ticket += 1
            self._queue.append(ticket)
            self._wait_t0[ticket] = time.perf_counter()
            if telemetry.TRACING and trc.enabled:
                trc.observe("semaphore.queue_depth", float(len(self._queue)))
            t = None if (timeout is None or timeout < 0) else timeout
            try:
                while not (self._count > 0 and self._queue[0] == ticket):
                    if not self._cond.wait(timeout=t):
                        self._queue.remove(ticket)
                        return False
                self._queue.popleft()
                self._count -= 1
                self._cond.notify_all()
                return True
            finally:
                self._wait_t0.pop(ticket, None)

    def oldest_wait_start(self) -> Optional[float]:
        """``perf_counter`` timestamp of the head-of-queue (longest) in-flight
        acquire, or None when the queue is idle."""
        with self._cond:
            return min(self._wait_t0.values(), default=None)

    def waiters(self) -> int:
        with self._cond:
            return len(self._wait_t0)

    def release(self) -> None:
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            if ck.lint_sync(self, "semaphore") is not None:
                return               # lint dry run: recorded, never mutates
            ck.sem_release(self)     # publish the hand-off edge pre-release
        with self._cond:
            self._count += 1
            self._cond.notify_all()

    # paper-cased aliases
    def Acquire(self, timeout: float = -1) -> bool:
        return self.acquire(None if timeout is None or timeout < 0 else timeout)

    Release = release


class SSPClock:
    """Stale Synchronous Parallel clock (Petuum-style, cited by the paper).

    ``tick(tid)`` advances a worker's clock; ``wait(tid)`` blocks while the
    worker is more than ``staleness`` ticks ahead of the slowest worker.
    ``staleness=0`` degenerates to a barrier (fully synchronous).
    """

    def __init__(self, n_workers: int, staleness: int = 0):
        self.staleness = staleness
        self._clocks: Dict[int, int] = {i: 0 for i in range(n_workers)}
        self._cond = threading.Condition()
        self.block_events = 0
        self.tracer = telemetry.NULL_TRACER
        self.checker = stepcheck.NULL_CHECKER

    def tick(self, tid: int) -> int:
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            if ck.lint_sync(self, "ssp") is not None:
                return self._clocks.get(tid, 0) + 1   # dry run: no mutation
            ck.ssp_tick(self)        # publish the window edge pre-tick
        with self._cond:
            self._clocks[tid] += 1
            self._cond.notify_all()
            return self._clocks[tid]

    def wait(self, tid: int, timeout: Optional[float] = None) -> bool:
        ck = self.checker
        if stepcheck.CHECKING and ck.enabled:
            if ck.lint_sync(self, "ssp") is not None:
                return True          # lint dry run: recorded, never blocks
            ok = self._wait(tid, timeout)
            ck.ssp_wait_done(self, ok)
            return ok
        return self._wait(tid, timeout)

    def _wait(self, tid: int, timeout: Optional[float] = None) -> bool:
        trc = self.tracer
        tracing = telemetry.TRACING and trc.enabled
        t0 = time.perf_counter() if tracing else 0.0
        stalled = False
        with self._cond:
            if tracing:
                trc.observe(
                    "ssp.skew",
                    float(self._clocks[tid] - min(self._clocks.values())))
            while self._clocks[tid] - min(self._clocks.values()) > self.staleness:
                self.block_events += 1
                stalled = True
                if not self._cond.wait(timeout=timeout):
                    if tracing:
                        trc.wait_span("sync", "ssp.stall", t0,
                                      tid=tid, released=False)
                    return False
        if tracing and stalled:
            trc.wait_span("sync", "ssp.stall", t0, tid=tid, released=True)
        return True

    def min_clock(self) -> int:
        with self._cond:
            return min(self._clocks.values())

    def drop_worker(self, tid: int) -> None:
        """Remove a failed worker so survivors are not blocked forever (FT)."""
        with self._cond:
            self._clocks.pop(tid, None)
            self._cond.notify_all()

    def add_worker(self, tid: int, clock: Optional[int] = None) -> None:
        with self._cond:
            self._clocks[tid] = self.min_clock() if clock is None else clock
            self._cond.notify_all()
