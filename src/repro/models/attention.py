"""Attention: GQA/MHA (+ qk-norm, qkv-bias, RoPE), MLA, cross-attention.

Train/prefill paths use a **blocked online-softmax attention** (pure-jnp flash
analogue, lax.scan over KV blocks) so activation memory stays O(T·block)
instead of O(T·S) — the same algorithm the Pallas kernel in
``kernels/flash_attention`` implements with VMEM tiles; set
``attention_impl="pallas"`` to lower through the kernel on TPU.

Decode paths attend a single query step over a KV cache.  MLA decode uses the
*absorbed* formulation (queries projected into the compressed c-space), so the
cache stays at ``kv_lora_rank + rope_dim`` per token — the memory-roofline win
MLA exists for.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, rms_norm, zeros_init


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------


def naive_attention(q, k, v, *, causal: bool, q_offset=0, bias=None):
    """Reference full-materialisation attention (oracle for tests).

    q: (B, T, KH, G, dh); k, v: (B, S, KH, dh).
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("btkgd,bskd->btkgs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if bias is not None:
        scores = scores + bias
    if causal:
        tpos = q_offset + jnp.arange(q.shape[1])
        spos = jnp.arange(k.shape[1])
        mask = tpos[:, None] >= spos[None, :]
        scores = jnp.where(mask[None, :, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("btkgs,bskd->btkgd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def blocked_attention(q, k, v, *, causal: bool, q_offset=0, block_k: int = 512,
                      full_unroll: bool = False):
    """Online-softmax attention, scanning KV blocks (flash-style, pure jnp).

    q: (B, T, KH, G, dk); k: (B, S, KH, dk); v: (B, S, KH, dv)  →  (B, T, KH, G, dv)
    (dk may differ from dv — e.g. MLA's nope+rope keys vs v_head_dim values.)
    """
    B, T, KH, G, dk = q.shape
    dv = v.shape[-1]
    S = k.shape[1]
    scale = 1.0 / math.sqrt(dk)
    nblk = (S + block_k - 1) // block_k
    pad = nblk * block_k - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # (nblk, B, bk, KH, d)
    kb = k.reshape(B, nblk, block_k, KH, dk).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block_k, KH, dv).transpose(1, 0, 2, 3, 4)

    qf = q.astype(jnp.float32)
    tpos = q_offset + jnp.arange(T)

    def body(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        s = jnp.einsum("btkgd,bskd->btkgs", qf, kj.astype(jnp.float32)) * scale
        spos = j * block_k + jnp.arange(block_k)
        valid = spos < S
        if causal:
            mask = (tpos[:, None] >= spos[None, :]) & valid[None, :]
        else:
            mask = jnp.broadcast_to(valid[None, :], (T, block_k))
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("btkgs,bskd->btkgd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, T, KH, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, T, KH, G), jnp.float32)
    a0 = jnp.zeros((B, T, KH, G, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (jnp.arange(nblk), kb, vb),
                                  unroll=nblk if full_unroll else 1)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def _run_attention(q, k, v, *, causal, q_offset=0, impl: str = "blocked", block_k: int = 512,
                   full_unroll: bool = False):
    if impl == "naive":
        return naive_attention(q, k, v, causal=causal, q_offset=q_offset)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal, q_offset=q_offset)
    return blocked_attention(q, k, v, causal=causal, q_offset=q_offset, block_k=block_k,
                             full_unroll=full_unroll)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------


class GQAConfig(NamedTuple):
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    causal: bool = True
    attention_impl: str = "blocked"
    block_k: int = 512
    full_unroll: bool = False  # unroll the KV-block scan (dry-run flop probes)


def init_gqa(key, cfg: GQAConfig, dtype=jnp.float32):
    D, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H, hd), in_axis=0, dtype=dtype),
        "wk": dense_init(ks[1], (D, KH, hd), in_axis=0, dtype=dtype),
        "wv": dense_init(ks[2], (D, KH, hd), in_axis=0, dtype=dtype),
        "wo": dense_init(ks[3], (H, hd, D), in_axis=1, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KH, hd), dtype)
        p["bv"] = jnp.zeros((KH, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _gqa_qkv(p, x, cfg: GQAConfig, positions):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attend(p, x, cfg: GQAConfig, *, positions=None):
    """Full-sequence (train / prefill) self-attention."""
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    q, k, v = _gqa_qkv(p, x, cfg, positions)
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, T, cfg.n_kv_heads, G, cfg.head_dim)
    out = _run_attention(qg, k, v, causal=cfg.causal, impl=cfg.attention_impl,
                         block_k=cfg.block_k, full_unroll=cfg.full_unroll)
    out = out.reshape(B, T, cfg.n_heads, cfg.head_dim)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"])


class KVCache(NamedTuple):
    k: jax.Array  # (B, S, KH, hd)
    v: jax.Array
    # position is tracked by the caller (one scalar for the whole stack)


class QuantKVCache(NamedTuple):
    """int8 KV cache with per-(token, head) scales — halves decode cache reads
    vs bf16 (the §Perf lever for memory-bound decode cells)."""

    k_q: jax.Array    # (B, S, KH, hd) int8
    k_s: jax.Array    # (B, S, KH, 1)  bf16 scale
    v_q: jax.Array
    v_s: jax.Array


def _quantize_i8(x):
    """x (..., hd) → (int8 values, per-(...) scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _dequantize_i8(q, scale):
    return q.astype(jnp.float32) * scale.astype(jnp.float32)


def init_gqa_cache(cfg: GQAConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
                   quantized: bool = False):
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    if quantized:
        sshape = shape[:-1] + (1,)
        return QuantKVCache(jnp.zeros(shape, jnp.int8), jnp.zeros(sshape, jnp.bfloat16),
                            jnp.zeros(shape, jnp.int8), jnp.zeros(sshape, jnp.bfloat16))
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def gqa_decode(p, cache, x_t, cfg: GQAConfig, pos):
    """One-token decode: x_t (B, 1, D), pos scalar — returns (cache', out).

    Accepts either a bf16 :class:`KVCache` or an int8 :class:`QuantKVCache`
    (dequantised on read; new entries quantised on write).
    """
    B = x_t.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1))
    q, k_t, v_t = _gqa_qkv(p, x_t, cfg, positions)
    if isinstance(cache, QuantKVCache):
        kq_t, ks_t = _quantize_i8(k_t)
        vq_t, vs_t = _quantize_i8(v_t)
        upd = lambda buf, val: jax.lax.dynamic_update_slice_in_dim(
            buf, val.astype(buf.dtype), pos, axis=1)
        new_cache = QuantKVCache(upd(cache.k_q, kq_t), upd(cache.k_s, ks_t),
                                 upd(cache.v_q, vq_t), upd(cache.v_s, vs_t))
        k = _dequantize_i8(new_cache.k_q, new_cache.k_s).astype(x_t.dtype)
        v = _dequantize_i8(new_cache.v_q, new_cache.v_s).astype(x_t.dtype)
    else:
        k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_t.astype(cache.k.dtype), pos, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_t.astype(cache.v.dtype), pos, axis=1)
        new_cache = KVCache(k, v)
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, 1, cfg.n_kv_heads, G, cfg.head_dim)
    # mask out cache positions beyond pos via the causal mask with q_offset=pos
    out = naive_attention(qg, k, v, causal=True, q_offset=pos)
    out = out.reshape(B, 1, cfg.n_heads, cfg.head_dim)
    return new_cache, jnp.einsum("bthk,hkd->btd", out, p["wo"])


# ---------------------------------------------------------------------------
# Cross-attention (llama-3.2-vision): queries from text, K/V from vision tokens
# ---------------------------------------------------------------------------


def cross_attend(p, x, kv_embeds, cfg: GQAConfig):
    """x (B,T,D) attends over kv_embeds (B,Sv,D); non-causal, no RoPE."""
    B, T, _ = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_embeds, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_embeds, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, T, cfg.n_kv_heads, G, cfg.head_dim)
    out = _run_attention(qg, k, v, causal=False, impl=cfg.attention_impl,
                         block_k=cfg.block_k, full_unroll=cfg.full_unroll)
    out = out.reshape(B, T, cfg.n_heads, cfg.head_dim)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"])


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (deepseek-v3)
# ---------------------------------------------------------------------------


class MLAConfig(NamedTuple):
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0
    attention_impl: str = "blocked"
    block_k: int = 512
    full_unroll: bool = False


def init_mla(key, cfg: MLAConfig, dtype=jnp.float32):
    D, H = cfg.d_model, cfg.n_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], (D, r_q), in_axis=0, dtype=dtype),
        "q_norm": jnp.ones((r_q,), dtype),
        "w_uq": dense_init(ks[1], (r_q, H, dn + dr), in_axis=0, dtype=dtype),
        "w_dkv": dense_init(ks[2], (D, r_kv), in_axis=0, dtype=dtype),
        "kv_norm": jnp.ones((r_kv,), dtype),
        "w_kr": dense_init(ks[3], (D, dr), in_axis=0, dtype=dtype),
        "w_uk": dense_init(ks[4], (r_kv, H, dn), in_axis=0, dtype=dtype),
        "w_uv": dense_init(ks[5], (r_kv, H, dv), in_axis=0, dtype=dtype),
        "wo": dense_init(ks[6], (H, dv, D), in_axis=1, dtype=dtype),
    }


def _mla_q(p, x, cfg: MLAConfig, positions):
    cq = rms_norm(jnp.einsum("btd,dr->btr", x, p["w_dq"]), p["q_norm"])
    q = jnp.einsum("btr,rhk->bthk", cq, p["w_uq"])
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(p, x, cfg: MLAConfig, positions):
    c_kv = rms_norm(jnp.einsum("btd,dr->btr", x, p["w_dkv"]), p["kv_norm"])
    k_rope = jnp.einsum("btd,dk->btk", x, p["w_kr"])[:, :, None, :]   # shared head
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_attend(p, x, cfg: MLAConfig, *, positions=None):
    """Train/prefill MLA: expand c_kv to per-head K/V and run blocked attention."""
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_ckv(p, x, cfg, positions)
    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["w_uk"])
    v = jnp.einsum("btr,rhk->bthk", c_kv, p["w_uv"])
    H = cfg.n_heads
    q = jnp.concatenate([q_nope, q_rope], axis=-1)                  # (B,T,H,dn+dr)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, T, H, cfg.qk_rope_dim))], axis=-1)
    # treat every head as its own KV group (KH=H, G=1) for the blocked impl
    qg = q[:, :, :, None, :].transpose(0, 1, 2, 3, 4).reshape(B, T, H, 1, cfg.qk_nope_dim + cfg.qk_rope_dim)
    out = _run_attention(qg, k, v, causal=True, impl=cfg.attention_impl,
                         block_k=cfg.block_k, full_unroll=cfg.full_unroll)
    out = out.reshape(B, T, H, cfg.v_head_dim)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"])


class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, S, kv_lora_rank) — the compressed cache
    k_rope: jax.Array  # (B, S, qk_rope_dim)


def init_mla_cache(cfg: MLAConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return MLACache(
        jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    )


def mla_decode(p, cache: MLACache, x_t, cfg: MLAConfig, pos):
    """Absorbed-matrix MLA decode: score/readout directly in c-space.

    scores_h(s) = q_nope_h · (W_uk_h c_s) + q_rope_h · k_rope_s
                = (W_uk_hᵀ q_nope_h) · c_s + q_rope_h · k_rope_s
    out_h       = Σ_s p_h(s) (W_uv_h c_s) = W_uv_h (Σ_s p_h(s) c_s)
    — per-token cache stays (kv_lora_rank + rope_dim).
    """
    B = x_t.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1))
    q_nope, q_rope = _mla_q(p, x_t, cfg, positions)                  # (B,1,H,·)
    c_t, kr_t = _mla_ckv(p, x_t, cfg, positions)                     # (B,1,r), (B,1,dr)
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_t.astype(cache.c_kv.dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, kr_t.astype(cache.k_rope.dtype), pos, axis=1)

    q_c = jnp.einsum("bthk,rhk->bthr", q_nope, p["w_uk"])            # absorbed query
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    s_c = jnp.einsum("bthr,bsr->bths", q_c.astype(jnp.float32), c_kv.astype(jnp.float32))
    s_r = jnp.einsum("bthk,bsk->bths", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
    scores = (s_c + s_r) * scale                                     # (B,1,H,S)
    spos = jnp.arange(c_kv.shape[1])
    scores = jnp.where((spos <= pos)[None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o_c = jnp.einsum("bths,bsr->bthr", w, c_kv.astype(jnp.float32))  # (B,1,H,r)
    out = jnp.einsum("bthr,rhk->bthk", o_c.astype(x_t.dtype), p["w_uv"])
    return MLACache(c_kv, k_rope), jnp.einsum("bthk,hkd->btd", out, p["wo"])
