"""Mamba2 (SSD — state-space duality) blocks, chunked for TPU.

The SSD algorithm splits the sequence into chunks of Q tokens: within a chunk
the token-token interaction is a (masked, decay-weighted) quadratic form that
maps onto the MXU; across chunks only the (H, N, P) state is carried by a
linear recurrence — O(T·Q) work, O(T/Q) sequential steps.  This is the
TPU-native adaptation of the paper-pool's GPU scan: the chunk GEMMs feed the
systolic array, the state recurrence is a tiny lax.scan.
``kernels/ssd_scan`` implements the same schedule as a Pallas kernel with the
state carried in VMEM scratch across the (sequential) chunk grid axis.

Decode carries (conv_state, ssm_state) — O(1) memory and compute per token in
context length, which is why the ``long_500k`` cells run for ssm/hybrid archs.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm


class SSMConfig(NamedTuple):
    d_model: int
    d_state: int = 128          # N
    head_dim: int = 64          # P
    expand: int = 2
    n_groups: int = 1           # G (B/C shared per group)
    conv_kernel: int = 4
    chunk: int = 128            # Q
    ssd_impl: str = "chunked"   # chunked | pallas
    full_unroll: bool = False   # unroll the inter-chunk scan (dry-run flop probes)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init_mamba2(key, cfg: SSMConfig, dtype=jnp.float32):
    D, DI, H, G, N, K = (cfg.d_model, cfg.d_inner, cfg.n_heads,
                         cfg.n_groups, cfg.d_state, cfg.conv_kernel)
    ks = jax.random.split(key, 5)
    d_proj = 2 * DI + 2 * G * N + H      # [z, x, B, C, dt]
    return {
        "in_proj": dense_init(ks[0], (D, d_proj), in_axis=0, dtype=dtype),
        "conv_w": dense_init(ks[1], (K, DI + 2 * G * N), in_axis=0, dtype=dtype),
        "conv_b": jnp.zeros((DI + 2 * G * N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((DI,), dtype),
        "out_proj": dense_init(ks[2], (DI, D), in_axis=0, dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv1d; x (B, T, C), w (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def ssd_chunked(x, dt, A, B, C, *, chunk: int, full_unroll: bool = False):
    """SSD reference: x (b,T,H,P), dt (b,T,H), A (H,), B/C (b,T,G,N) → y, final state.

    Pure-jnp chunked algorithm (oracle for the Pallas kernel).
    """
    b, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    Q = chunk
    nc = T // Q
    rep = H // G

    # expand groups to heads
    Bh = jnp.repeat(B, rep, axis=2)            # (b,T,H,N)
    Ch = jnp.repeat(C, rep, axis=2)

    a = (dt * (-jnp.exp(A))[None, None, :]).astype(jnp.float32)   # log-decay (<0)
    xbar = x * dt[..., None].astype(x.dtype)

    def r(t, shape):  # reshape helper to chunks
        return t.reshape((b, nc, Q) + shape)

    xc, ac = r(xbar, (H, P)), r(a, (H,))
    Bc, Cc = r(Bh, (H, N)), r(Ch, (H, N))

    cum = jnp.cumsum(ac, axis=2)                                   # (b,nc,Q,H)
    # -- intra-chunk (quadratic within chunk, MXU-friendly) --------------------
    li = cum[:, :, :, None, :]                                     # i
    lj = cum[:, :, None, :, :]                                     # j
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    # mask BEFORE exp: the upper triangle has li - lj > 0 and would overflow
    decay = jnp.exp(jnp.where(mask, li - lj, -jnp.inf))            # (b,nc,Q,Q,H)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    scores = scores * decay
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", scores, xc.astype(jnp.float32))

    # -- chunk states -----------------------------------------------------------
    last = cum[:, :, -1:, :]                                        # (b,nc,1,H)
    sdecay = jnp.exp(last - cum)                                    # decay j→chunk end
    S = jnp.einsum("bcjhn,bcjhp->bchnp",
                   (Bc.astype(jnp.float32) * sdecay[..., None]), xc.astype(jnp.float32))

    # -- inter-chunk recurrence ---------------------------------------------------
    total = jnp.exp(last[:, :, 0, :])                               # (b,nc,H)

    def body(h, inp):
        S_c, tot = inp                                              # (b,H,N,P), (b,H)
        h_new = h * tot[:, :, None, None] + S_c
        return h_new, h                                             # emit state *before* chunk

    h0 = jnp.zeros((b, H, N, P), jnp.float32)
    hT, h_prev = jax.lax.scan(body, h0,
                              (S.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
                              unroll=nc if full_unroll else 1)
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                        # (b,nc,H,N,P)

    y_off = jnp.einsum("bcihn,bchnp->bcihp",
                       (Cc.astype(jnp.float32) * jnp.exp(cum)[..., None]), h_prev)
    y = (y_diag + y_off).reshape(b, T, H, P).astype(x.dtype)
    return y, hT


def mamba2_forward(p, x, cfg: SSMConfig):
    """Train/prefill pass. x (B, T, D) → (B, T, D)."""
    B_, T, D = x.shape
    DI, H, G, N, P = cfg.d_inner, cfg.n_heads, cfg.n_groups, cfg.d_state, cfg.head_dim

    proj = x @ p["in_proj"]
    # split: [z (DI), xBC (DI+2GN), dt (H)]
    z = proj[..., :DI]
    xbc = proj[..., DI : 2 * DI + 2 * G * N]
    dt = proj[..., 2 * DI + 2 * G * N :]

    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :DI].reshape(B_, T, H, P)
    Bmat = xbc[..., DI : DI + G * N].reshape(B_, T, G, N)
    Cmat = xbc[..., DI + G * N :].reshape(B_, T, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    if cfg.ssd_impl == "pallas":
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, _ = ssd_ops.ssd(xs, dt, p["A_log"], Bmat, Cmat, chunk=cfg.chunk)
    else:
        y, _ = ssd_chunked(xs, dt, p["A_log"], Bmat, Cmat, chunk=cfg.chunk,
                           full_unroll=cfg.full_unroll)
    y = y + xs * p["D"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B_, T, DI)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"]


class MambaCache(NamedTuple):
    conv: jax.Array  # (B, K-1, DI + 2GN) — last inputs to the causal conv
    ssm: jax.Array   # (B, H, N, P) — the recurrent state


def init_mamba_cache(cfg: SSMConfig, batch: int, dtype=jnp.float32):
    DI, H, G, N, P = cfg.d_inner, cfg.n_heads, cfg.n_groups, cfg.d_state, cfg.head_dim
    return MambaCache(
        jnp.zeros((batch, cfg.conv_kernel - 1, DI + 2 * G * N), dtype),
        jnp.zeros((batch, H, N, P), jnp.float32),
    )


def mamba2_decode(p, cache: MambaCache, x_t, cfg: SSMConfig):
    """One-token decode: O(1) in context length. x_t (B, 1, D)."""
    B_ = x_t.shape[0]
    DI, H, G, N, P = cfg.d_inner, cfg.n_heads, cfg.n_groups, cfg.d_state, cfg.head_dim

    proj = (x_t @ p["in_proj"])[:, 0]                                # (B, d_proj)
    z = proj[..., :DI]
    xbc_t = proj[..., DI : 2 * DI + 2 * G * N]
    dt = proj[..., 2 * DI + 2 * G * N :]

    # conv over [state, new]
    window = jnp.concatenate([cache.conv, xbc_t[:, None, :]], axis=1)  # (B, K, C)
    conv_out = jnp.sum(window * p["conv_w"][None], axis=1) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)
    new_conv = window[:, 1:, :]

    xs = xbc[..., :DI].reshape(B_, H, P)
    Bmat = xbc[..., DI : DI + G * N].reshape(B_, G, N)
    Cmat = xbc[..., DI + G * N :].reshape(B_, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B, H)

    rep = H // G
    Bh = jnp.repeat(Bmat, rep, axis=1)                               # (B,H,N)
    Ch = jnp.repeat(Cmat, rep, axis=1)
    decay = jnp.exp(dt * (-jnp.exp(p["A_log"]))[None, :])            # (B,H)
    dBx = jnp.einsum("bhn,bhp->bhnp", Bh.astype(jnp.float32),
                     (xs * dt[..., None].astype(xs.dtype)).astype(jnp.float32))
    new_ssm = cache.ssm * decay[:, :, None, None] + dBx
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), new_ssm)
    y = y.astype(x_t.dtype) + xs * p["D"][None, :, None].astype(xs.dtype)
    y = y.reshape(B_, 1, DI)
    y = rms_norm(y * jax.nn.silu(z[:, None, :]), p["norm"])
    return MambaCache(new_conv, new_ssm), y @ p["out_proj"]
