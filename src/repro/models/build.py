"""Model assembly: every assigned architecture family from one set of blocks.

Families:
  dense / moe / vlm — decoder transformer (GQA or MLA attention; dense or MoE
      FFN; vlm adds a cross-attention layer closing every superblock, attending
      over stub patch embeddings).
  ssm — Mamba2 (SSD) stack, attention-free.
  hybrid — zamba2: Mamba2 backbone with a weight-shared attention block applied
      after every ``hybrid_period`` mamba layers.
  audio — hubert: encoder-only (non-causal) transformer over stub frame
      embeddings with a per-frame classification head.

All stacks use scan-over-layers (stacked params, small HLO).  The returned
:class:`Model` exposes init / loss_fn / forward / init_cache / decode_step —
the exact surface ``launch/steps.py`` lowers for train and serve cells.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.attention import (
    GQAConfig,
    KVCache,
    MLACache,
    MLAConfig,
    cross_attend,
    gqa_attend,
    gqa_decode,
    init_gqa,
    init_gqa_cache,
    init_mla,
    init_mla_cache,
    mla_attend,
    mla_decode,
)
from repro.models.common import (
    bf16_boundary,
    chunked_softmax_cross_entropy,
    dense_init,
    embed_init,
    layer_norm,
    rms_norm,
    softmax_cross_entropy,
)
from repro.models.ffn import MoEConfig, dense_ffn, init_dense_ffn, init_moe, moe_ffn
from repro.models.mamba import (
    MambaCache,
    SSMConfig,
    init_mamba2,
    init_mamba_cache,
    mamba2_decode,
    mamba2_forward,
)


class Model(NamedTuple):
    cfg: ArchConfig
    init: Callable                    # rng -> params
    loss_fn: Callable                 # (params, batch) -> (loss, metrics)
    forward: Callable                 # (params, batch) -> logits  (prefill path)
    init_cache: Optional[Callable]    # (batch, max_len) -> cache zeros
    decode_step: Optional[Callable]   # (params, cache, tokens(B,1), pos) -> (logits, cache)


# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _gqa_cfg(cfg: ArchConfig, causal=None, n_kv=None) -> GQAConfig:
    return GQAConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=n_kv if n_kv is not None else cfg.n_kv_heads,
        head_dim=cfg.head_dim_actual,
        qk_norm=cfg.qk_norm,
        qkv_bias=cfg.qkv_bias,
        rope_theta=cfg.rope_theta,
        causal=cfg.causal if causal is None else causal,
        attention_impl=cfg.attention_impl,
        block_k=cfg.block_k,
        full_unroll=not cfg.scan_layers,
    )


def _mla_cfg(cfg: ArchConfig) -> MLAConfig:
    return MLAConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        q_lora_rank=cfg.q_lora_rank,
        kv_lora_rank=cfg.kv_lora_rank,
        qk_nope_dim=cfg.qk_nope_dim,
        qk_rope_dim=cfg.qk_rope_dim,
        v_head_dim=cfg.v_head_dim,
        rope_theta=cfg.rope_theta,
        attention_impl=cfg.attention_impl,
        block_k=cfg.block_k,
        full_unroll=not cfg.scan_layers,
    )


def _moe_cfg(cfg: ArchConfig, data_groups: int) -> MoEConfig:
    return MoEConfig(
        d_model=cfg.d_model,
        n_experts=cfg.n_experts,
        top_k=cfg.top_k,
        d_ff_expert=cfg.d_ff_expert,
        n_shared=cfg.n_shared_experts,
        capacity_factor=cfg.capacity_factor,
        impl=cfg.moe_impl,
        aux_loss_weight=cfg.aux_loss_weight,
        data_groups=data_groups,
    )


def _ssm_cfg(cfg: ArchConfig) -> SSMConfig:
    return SSMConfig(
        d_model=cfg.d_model,
        d_state=cfg.ssm_state,
        head_dim=cfg.ssm_head_dim,
        expand=cfg.ssm_expand,
        n_groups=cfg.ssm_groups,
        conv_kernel=4,
        chunk=cfg.ssm_chunk,
        ssd_impl=cfg.ssd_impl,
        # NOTE: the SSD inter-chunk recurrence stays a scan even in flop probes:
        # its body is only the (H,N,P) state update (≈0 FLOPs vs the chunk GEMMs
        # which live OUTSIDE the scan and are fully counted); unrolling nc=256
        # chunks at 32k seq explodes compile time for nothing.
        full_unroll=False,
    )


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def _init_norm(cfg: ArchConfig, dtype):
    if cfg.norm_kind == "layer":
        return {"scale": jnp.ones((cfg.d_model,), dtype), "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.ones((cfg.d_model,), dtype)}


def _norm(x, p, cfg: ArchConfig):
    if cfg.norm_kind == "layer":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


# ---------------------------------------------------------------------------
# transformer blocks (init + train + decode)
# ---------------------------------------------------------------------------


def _init_attn(key, cfg: ArchConfig, dtype, n_kv=None):
    if cfg.attn_kind == "mla":
        return init_mla(key, _mla_cfg(cfg), dtype)
    return init_gqa(key, _gqa_cfg(cfg, n_kv=n_kv), dtype)


def _init_block(key, cfg: ArchConfig, *, ffn: str, data_groups: int, dtype, n_kv=None):
    k1, k2 = jax.random.split(key)
    p = {"norm1": _init_norm(cfg, dtype), "norm2": _init_norm(cfg, dtype),
         "attn": _init_attn(k1, cfg, dtype, n_kv=n_kv)}
    if ffn == "moe":
        p["moe"] = init_moe(k2, _moe_cfg(cfg, data_groups), dtype)
    elif ffn == "dense_wide":
        p["ffn"] = init_dense_ffn(k2, cfg.d_model, cfg.d_ff_dense or cfg.d_ff,
                                  kind=cfg.ffn_kind, bias=cfg.ffn_bias, dtype=dtype)
    else:
        p["ffn"] = init_dense_ffn(k2, cfg.d_model, cfg.d_ff,
                                  kind=cfg.ffn_kind, bias=cfg.ffn_bias, dtype=dtype)
    return p


def _block_fwd(p, x, aux, cfg: ArchConfig, moe_cfg, *, kind: str, vision=None, gqa=None):
    """One transformer block; kind: self | self_moe | self_wide | cross."""
    h = _norm(x, p["norm1"], cfg)
    if kind == "cross":
        a = cross_attend(p["attn"], h, vision, gqa)
    elif cfg.attn_kind == "mla":
        a = mla_attend(p["attn"], h, _mla_cfg(cfg))
    else:
        a = gqa_attend(p["attn"], h, gqa)
    x = x + a
    h = _norm(x, p["norm2"], cfg)
    if kind == "self_moe":
        y, al = moe_ffn(p["moe"], h, moe_cfg)
        aux = aux + al
    elif kind == "self_wide":
        y = dense_ffn(p["ffn"], h, kind=cfg.ffn_kind)
    else:
        y = dense_ffn(p["ffn"], h, kind=cfg.ffn_kind)
    out = x + y
    if cfg.bwd_bf16_boundary:
        out = bf16_boundary(out)          # bf16 TP backward collectives
    if cfg.seq_shard:
        from jax.sharding import PartitionSpec as P
        out = jax.lax.with_sharding_constraint(
            out, P(tuple(cfg.batch_axes), "model", None))  # Megatron-SP boundary
    return out, aux


def _block_decode(p, cache_l, x, pos, cfg: ArchConfig, moe_cfg, *, kind: str, gqa=None):
    h = _norm(x, p["norm1"], cfg)
    if kind == "cross":
        # cross-attention at decode: attend over the cached vision K/V
        a = _cross_decode(p["attn"], cache_l, h, gqa)
        new_cache = cache_l
    elif cfg.attn_kind == "mla":
        new_cache, a = mla_decode(p["attn"], cache_l, h, _mla_cfg(cfg), pos)
    else:
        new_cache, a = gqa_decode(p["attn"], cache_l, h, gqa, pos)
    x = x + a
    h = _norm(x, p["norm2"], cfg)
    if kind == "self_moe":
        y, _ = moe_ffn(p["moe"], h, moe_cfg._replace(data_groups=1, impl="gather" if moe_cfg.impl == "ep" else moe_cfg.impl))
    else:
        y = dense_ffn(p["ffn"], h, kind=cfg.ffn_kind)
    return new_cache, x + y


def _cross_decode(p, cache: KVCache, x_t, gqa: GQAConfig):
    """Decode-time cross-attention: K/V were cached at prefill (non-causal)."""
    from repro.models.attention import naive_attention
    B = x_t.shape[0]
    q = jnp.einsum("btd,dhk->bthk", x_t, p["wq"])
    if gqa.qk_norm:
        q = rms_norm(q, p["q_norm"])
    G = gqa.n_heads // gqa.n_kv_heads
    qg = q.reshape(B, 1, gqa.n_kv_heads, G, gqa.head_dim)
    out = naive_attention(qg, cache.k, cache.v, causal=False)
    out = out.reshape(B, 1, gqa.n_heads, gqa.head_dim)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"])


def _stack_init(init_one: Callable, key, n: int):
    return jax.vmap(init_one)(jax.random.split(key, n))


def _stack_len(stacked) -> int:
    return int(jax.tree.leaves(stacked)[0].shape[0])


def _scan(block, stacked, carry, remat: str, unroll: int = 1, full_unroll: bool = False):
    """Outer layer scans keep unroll=1 (small HLO, fast compiles).  XLA cost
    analysis counts a while body ONCE, so dry-run *probe* compiles set
    ``full_unroll`` (cfg.scan_layers=False) to expose exact per-layer costs.
    *Inner* scans of nested stacks (vlm/hybrid superblocks) are always fully
    unrolled so the outer body's cost is exact per superblock."""

    def body(c, lp):
        return block(lp, c), None

    if remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    if full_unroll:
        unroll = _stack_len(stacked)
    carry, _ = jax.lax.scan(body, carry, stacked, unroll=unroll)
    return carry


def _scan_cache(block, stacked, cache, x, unroll: int = 1, full_unroll: bool = False):
    def body(c, inp):
        lp, lc = inp
        nc, y = block(lp, lc, c)
        return y, nc

    if full_unroll:
        unroll = _stack_len(stacked)
    x, new_cache = jax.lax.scan(body, x, (stacked, cache), unroll=unroll)
    return new_cache, x


# ---------------------------------------------------------------------------
# decoder LM (dense / moe / vlm)
# ---------------------------------------------------------------------------


def build_decoder_lm(cfg: ArchConfig, data_groups: int = 1) -> Model:
    dtype = _dtype(cfg)
    gqa = _gqa_cfg(cfg)
    moe_cfg = _moe_cfg(cfg, data_groups) if cfg.n_experts else None
    V, D = cfg.vocab, cfg.d_model
    is_vlm = cfg.family == "vlm"

    # -- segment structure ---------------------------------------------------
    if is_vlm:
        period = cfg.cross_attn_period
        n_super = cfg.n_layers // period
        seg_plan = [("vlm_super", n_super)]
    else:
        n_dense = cfg.first_dense_layers if cfg.n_experts else cfg.n_layers
        seg_plan = []
        if n_dense:
            kind = "self_wide" if (cfg.n_experts and cfg.d_ff_dense) else "self"
            seg_plan.append((kind, n_dense))
        if cfg.n_experts and cfg.n_layers - n_dense > 0:
            seg_plan.append(("self_moe", cfg.n_layers - n_dense))

    def init(rng):
        keys = jax.random.split(rng, len(seg_plan) + 4)
        params: dict[str, Any] = {
            "embed": {"table": embed_init(keys[0], (V, D), dtype)},
            "final_norm": _init_norm(cfg, dtype),
            "head": {"w": dense_init(keys[1], (D, V), in_axis=0, dtype=dtype)},
        }
        segs = {}
        for i, (kind, n) in enumerate(seg_plan):
            k = keys[2 + i]
            if kind == "vlm_super":
                def init_super(kk):
                    ka, kb = jax.random.split(kk)
                    return {
                        "self": _stack_init(
                            lambda k2: _init_block(k2, cfg, ffn="dense", data_groups=data_groups, dtype=dtype),
                            ka, cfg.cross_attn_period - 1),
                        "cross": _init_block(kb, cfg, ffn="dense", data_groups=data_groups, dtype=dtype),
                    }
                segs[f"seg{i}"] = _stack_init(init_super, k, n)
            else:
                ffn = {"self": "dense", "self_wide": "dense_wide", "self_moe": "moe"}[kind]
                segs[f"seg{i}"] = _stack_init(
                    lambda k2: _init_block(k2, cfg, ffn=ffn, data_groups=data_groups, dtype=dtype), k, n)
        params["segments"] = segs
        if is_vlm and cfg.vision_dim and cfg.vision_dim != D:
            params["vision_proj"] = {"w": dense_init(keys[-1], (cfg.vision_dim, D), in_axis=0, dtype=dtype)}
        if cfg.mtp:
            km = jax.random.split(keys[-2], 2)
            params["mtp"] = {
                "proj": dense_init(km[0], (2 * D, D), in_axis=0, dtype=dtype),
                "block": _init_block(km[1], cfg, ffn="dense" if not cfg.n_experts else "dense_wide",
                                     data_groups=data_groups, dtype=dtype),
                "norm_h": _init_norm(cfg, dtype),
                "norm_e": _init_norm(cfg, dtype),
                "final_norm": _init_norm(cfg, dtype),
            }
        return params

    def _vision_of(params, batch):
        v = batch["vision_embeds"].astype(dtype)
        if "vision_proj" in params:
            v = v @ params["vision_proj"]["w"]
        return v

    def trunk(params, tokens, vision=None):
        x = jnp.take(params["embed"]["table"], tokens, axis=0)
        aux = jnp.zeros((), jnp.float32)
        for i, (kind, _n) in enumerate(seg_plan):
            stacked = params["segments"][f"seg{i}"]
            if kind == "vlm_super":
                def super_fwd(sp, carry):
                    def inner(p_l, c):
                        return _block_fwd(p_l, c[0], c[1], cfg, moe_cfg, kind="self", gqa=gqa)
                    carry = _scan(inner, sp["self"], carry, cfg.remat,
                                  unroll=cfg.cross_attn_period - 1)
                    x2, a2 = _block_fwd(sp["cross"], carry[0], carry[1], cfg, moe_cfg,
                                        kind="cross", vision=vision, gqa=gqa)
                    return (x2, a2)
                x, aux = _scan(super_fwd, stacked, (x, aux), cfg.remat,
                               full_unroll=not cfg.scan_layers)
            else:
                def blk(p_l, c, _kind=kind):
                    return _block_fwd(p_l, c[0], c[1], cfg, moe_cfg, kind=_kind, gqa=gqa)
                x, aux = _scan(blk, stacked, (x, aux), cfg.remat,
                               full_unroll=not cfg.scan_layers)
        return x, aux

    def forward(params, batch):
        vision = _vision_of(params, batch) if is_vlm else None
        x, _ = trunk(params, batch["tokens"], vision)
        x = _norm(x, params["final_norm"], cfg)
        if cfg.prefill_last_only:
            x = x[:, -1:]                 # serving: only next-token logits
        return x @ params["head"]["w"]

    def loss_fn(params, batch):
        vision = _vision_of(params, batch) if is_vlm else None
        h, aux = trunk(params, batch["tokens"], vision)
        x = _norm(h, params["final_norm"], cfg)
        if cfg.chunked_ce:
            loss = chunked_softmax_cross_entropy(
                x, params["head"]["w"], batch["labels"], chunk=cfg.ce_chunk,
                z_loss=cfg.z_loss, full_unroll=not cfg.scan_layers)
        else:
            logits = x @ params["head"]["w"]
            loss = softmax_cross_entropy(logits, batch["labels"], z_loss=cfg.z_loss)
        metrics = {"ce": loss, "aux": aux}
        if cfg.mtp:
            m = params["mtp"]
            emb_next = jnp.take(params["embed"]["table"], batch["labels"], axis=0)
            hcat = jnp.concatenate([_norm(h, m["norm_h"], cfg), _norm(emb_next, m["norm_e"], cfg)], axis=-1)
            hm = hcat @ m["proj"]
            hm, _ = _block_fwd(m["block"], hm, jnp.zeros((), jnp.float32), cfg, moe_cfg,
                               kind="self_wide" if cfg.n_experts else "self", gqa=gqa)
            hm = _norm(hm, m["final_norm"], cfg)
            mtp_logits = hm[:, :-1] @ params["head"]["w"]
            mtp_loss = softmax_cross_entropy(mtp_logits, batch["labels"][:, 1:])
            metrics["mtp"] = mtp_loss
            loss = loss + cfg.mtp_weight * mtp_loss
        return loss + aux, metrics

    # -- decode ----------------------------------------------------------------

    cache_dtype = jnp.bfloat16 if cfg.dtype != "float32" else jnp.float32

    def init_cache(batch, max_len):
        caches = {}
        for i, (kind, n) in enumerate(seg_plan):
            if kind == "vlm_super":
                self_c = jax.vmap(lambda _: jax.vmap(lambda __: init_gqa_cache(gqa, batch, max_len, cache_dtype))(
                    jnp.arange(cfg.cross_attn_period - 1)))(jnp.arange(n))
                cross_c = jax.vmap(lambda _: init_gqa_cache(
                    _gqa_cfg(cfg), batch, cfg.vision_tokens, cache_dtype))(jnp.arange(n))
                caches[f"seg{i}"] = {"self": self_c, "cross": cross_c}
            elif cfg.attn_kind == "mla":
                caches[f"seg{i}"] = jax.vmap(lambda _: init_mla_cache(_mla_cfg(cfg), batch, max_len, cache_dtype))(jnp.arange(n))
            else:
                caches[f"seg{i}"] = jax.vmap(lambda _: init_gqa_cache(
                    gqa, batch, max_len, cache_dtype,
                    quantized=(cfg.kv_cache_dtype == "int8")))(jnp.arange(n))
        return caches

    def decode_step(params, cache, tokens, pos):
        x = jnp.take(params["embed"]["table"], tokens, axis=0)
        new_caches = {}
        for i, (kind, _n) in enumerate(seg_plan):
            stacked = params["segments"][f"seg{i}"]
            if kind == "vlm_super":
                def super_dec(sp, scache, xx):
                    def inner(p_l, c_l, cc):
                        return _block_decode(p_l, c_l, cc, pos, cfg, moe_cfg, kind="self", gqa=gqa)
                    new_self, xx = _scan_cache(inner, sp["self"], scache["self"], xx,
                                               unroll=cfg.cross_attn_period - 1)
                    xx2 = xx + _cross_decode(sp["cross"]["attn"], scache["cross"],
                                             _norm(xx, sp["cross"]["norm1"], cfg), gqa)
                    h2 = _norm(xx2, sp["cross"]["norm2"], cfg)
                    xx2 = xx2 + dense_ffn(sp["cross"]["ffn"], h2, kind=cfg.ffn_kind)
                    return {"self": new_self, "cross": scache["cross"]}, xx2

                def body(c, inp):
                    sp, sc = inp
                    ncache, y = super_dec(sp, sc, c)
                    return y, ncache

                x, nc = jax.lax.scan(body, x, (stacked, cache[f"seg{i}"]),
                                     unroll=_stack_len(stacked) if not cfg.scan_layers else 1)
                new_caches[f"seg{i}"] = nc
            else:
                def blk(p_l, c_l, xx, _kind=kind):
                    return _block_decode(p_l, c_l, xx, pos, cfg, moe_cfg, kind=_kind, gqa=gqa)
                nc, x = _scan_cache(blk, stacked, cache[f"seg{i}"], x,
                                    full_unroll=not cfg.scan_layers)
                new_caches[f"seg{i}"] = nc
        x = _norm(x, params["final_norm"], cfg)
        return x @ params["head"]["w"], new_caches

    return Model(cfg, init, loss_fn, forward, init_cache, decode_step)


# ---------------------------------------------------------------------------
# SSM (mamba2) and hybrid (zamba2)
# ---------------------------------------------------------------------------


def build_ssm(cfg: ArchConfig, data_groups: int = 1) -> Model:
    dtype = _dtype(cfg)
    ssm = _ssm_cfg(cfg)
    V, D = cfg.vocab, cfg.d_model
    hybrid = cfg.family == "hybrid"
    gqa = _gqa_cfg(cfg) if hybrid else None
    period = cfg.hybrid_period if hybrid else 0
    n_super = cfg.n_layers // period if hybrid else 0

    def init_mamba_block(k):
        return {"norm": _init_norm(cfg, dtype), "mamba": init_mamba2(k, ssm, dtype)}

    def init(rng):
        keys = jax.random.split(rng, 6)
        params: dict[str, Any] = {
            "embed": {"table": embed_init(keys[0], (V, D), dtype)},
            "final_norm": _init_norm(cfg, dtype),
            "head": {"w": dense_init(keys[1], (D, V), in_axis=0, dtype=dtype)},
        }
        if hybrid:
            params["segments"] = {
                "mamba": _stack_init(
                    lambda kk: _stack_init(init_mamba_block, kk, period), keys[2], n_super)
            }
            params["shared_block"] = _init_block(keys[3], cfg, ffn="dense",
                                                 data_groups=data_groups, dtype=dtype)
        else:
            params["segments"] = {"mamba": _stack_init(init_mamba_block, keys[2], cfg.n_layers)}
        return params

    def mamba_block(p_l, x):
        return x + mamba2_forward(p_l["mamba"], _norm(x, p_l["norm"], cfg), ssm)

    def trunk(params, tokens):
        x = jnp.take(params["embed"]["table"], tokens, axis=0)
        if hybrid:
            shared = params["shared_block"]

            def super_fwd(sp, c):
                def inner(cc, p_l):
                    return mamba_block(p_l, cc), None
                c, _ = jax.lax.scan(inner, c, sp, unroll=period)
                c2, _ = _block_fwd(shared, c, jnp.zeros((), jnp.float32), cfg, None,
                                   kind="self", gqa=gqa)
                return c2
            x = _scan(super_fwd, params["segments"]["mamba"], x, cfg.remat,
                      full_unroll=not cfg.scan_layers)
        else:
            x = _scan(mamba_block, params["segments"]["mamba"], x, cfg.remat,
                      full_unroll=not cfg.scan_layers)
        return x

    def forward(params, batch):
        x = trunk(params, batch["tokens"])
        return _norm(x, params["final_norm"], cfg) @ params["head"]["w"]

    def loss_fn(params, batch):
        logits = forward(params, batch)
        loss = softmax_cross_entropy(logits, batch["labels"], z_loss=cfg.z_loss)
        return loss, {"ce": loss}

    cache_dtype = jnp.bfloat16 if cfg.dtype != "float32" else jnp.float32

    def init_cache(batch, max_len):
        if hybrid:
            mcache = jax.vmap(lambda _: jax.vmap(lambda __: init_mamba_cache(ssm, batch, dtype))(
                jnp.arange(period)))(jnp.arange(n_super))
            acache = jax.vmap(lambda _: init_gqa_cache(gqa, batch, max_len, cache_dtype))(jnp.arange(n_super))
            return {"mamba": mcache, "attn": acache}
        return {"mamba": jax.vmap(lambda _: init_mamba_cache(ssm, batch, dtype))(jnp.arange(cfg.n_layers))}

    def decode_step(params, cache, tokens, pos):
        x = jnp.take(params["embed"]["table"], tokens, axis=0)

        def mamba_dec(p_l, c_l, xx):
            nc, y = mamba2_decode(p_l["mamba"], c_l, _norm(xx, p_l["norm"], cfg), ssm)
            return nc, xx + y

        if hybrid:
            shared = params["shared_block"]

            def body(c, inp):
                sp, mcache, acache = inp
                new_m, y = _scan_cache(mamba_dec, sp, mcache, c, unroll=period)
                new_a, y = _block_decode(shared, acache, y, pos, cfg, None, kind="self", gqa=gqa)
                return y, (new_m, new_a)

            x, (new_m, new_a) = jax.lax.scan(
                body, x, (params["segments"]["mamba"], cache["mamba"], cache["attn"]),
                unroll=_stack_len(cache["attn"]) if not cfg.scan_layers else 1)
            new_cache = {"mamba": new_m, "attn": new_a}
        else:
            new_m, x = _scan_cache(mamba_dec, params["segments"]["mamba"], cache["mamba"], x,
                                   full_unroll=not cfg.scan_layers)
            new_cache = {"mamba": new_m}
        x = _norm(x, params["final_norm"], cfg)
        return x @ params["head"]["w"], new_cache

    return Model(cfg, init, loss_fn, forward, init_cache, decode_step)


# ---------------------------------------------------------------------------
# audio encoder (hubert)
# ---------------------------------------------------------------------------


def build_audio_encoder(cfg: ArchConfig, data_groups: int = 1) -> Model:
    dtype = _dtype(cfg)
    gqa = _gqa_cfg(cfg, causal=False)
    D = cfg.d_model

    def init(rng):
        keys = jax.random.split(rng, 4)
        return {
            "in_proj": {"w": dense_init(keys[0], (cfg.frame_dim, D), in_axis=0, dtype=dtype)},
            "segments": {"seg0": _stack_init(
                lambda k: _init_block(k, cfg, ffn="dense", data_groups=data_groups, dtype=dtype),
                keys[1], cfg.n_layers)},
            "final_norm": _init_norm(cfg, dtype),
            "head": {"w": dense_init(keys[2], (D, cfg.vocab), in_axis=0, dtype=dtype)},
        }

    def forward(params, batch):
        x = batch["frames"].astype(dtype) @ params["in_proj"]["w"]

        def blk(p_l, c):
            return _block_fwd(p_l, c[0], c[1], cfg, None, kind="self", gqa=gqa)

        x, _ = _scan(blk, params["segments"]["seg0"], (x, jnp.zeros((), jnp.float32)), cfg.remat,
                     full_unroll=not cfg.scan_layers)
        x = _norm(x, params["final_norm"], cfg)
        return x @ params["head"]["w"]

    def loss_fn(params, batch):
        logits = forward(params, batch)
        loss = softmax_cross_entropy(logits, batch["labels"])
        return loss, {"ce": loss}

    return Model(cfg, init, loss_fn, forward, None, None)


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def build_model(cfg: ArchConfig, data_groups: int = 1) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return build_decoder_lm(cfg, data_groups)
    if cfg.family in ("ssm", "hybrid"):
        return build_ssm(cfg, data_groups)
    if cfg.family == "audio":
        return build_audio_encoder(cfg, data_groups)
    raise ValueError(f"unknown family {cfg.family}")
