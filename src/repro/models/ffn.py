"""Feed-forward layers: dense (SwiGLU / GELU) and Mixture-of-Experts.

MoE provides two dispatch implementations:

* ``dense``  — every expert computes every token, combined by gate weight.
  O(E) FLOPs; only for tiny smoke configs and as the correctness oracle.
* ``gather`` — production path: per-data-group argsort routing into capacity-
  bounded per-expert buffers ``(G, E, C, D)``, batched expert GEMMs, scatter
  back.  Sorting happens *within* each data-parallel group (batched sort along
  the local axis), so no global sort network appears in the SPMD lowering, and
  the buffer is sharded over both the data axis (G) and the expert axis (E) —
  the buffer re-shard between the data-local scatter and the expert-sharded
  GEMM is exactly the EP dispatch all-to-all.

Routing: softmax router, top-k with renormalised gates (DeepSeek-style),
capacity factor with token dropping, and the standard load-balancing aux loss.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.compat import axis_size, shard_map
from repro.models.common import dense_init


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------


def init_dense_ffn(key, d_model: int, d_ff: int, *, kind: str = "swiglu",
                   bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        p = {
            "w_gate": dense_init(ks[0], (d_model, d_ff), in_axis=0, dtype=dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), in_axis=0, dtype=dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), in_axis=0, dtype=dtype),
        }
    else:  # gelu MLP (starcoder2 / hubert)
        p = {
            "w_in": dense_init(ks[0], (d_model, d_ff), in_axis=0, dtype=dtype),
            "w_out": dense_init(ks[1], (d_ff, d_model), in_axis=0, dtype=dtype),
        }
        if bias:
            p["b_in"] = jnp.zeros((d_ff,), dtype)
            p["b_out"] = jnp.zeros((d_model,), dtype)
    return p


def dense_ffn(p, x, *, kind: str = "swiglu"):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        return h @ p["w_down"]
    h = x @ p["w_in"]
    if "b_in" in p:
        h = h + p["b_in"]
    h = jax.nn.gelu(h)
    out = h @ p["w_out"]
    if "b_out" in p:
        out = out + p["b_out"]
    return out


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


class MoEConfig(NamedTuple):
    d_model: int
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # always-on shared experts (deepseek)
    capacity_factor: float = 1.25
    impl: str = "gather"         # gather | dense
    aux_loss_weight: float = 0.01
    data_groups: int = 1         # data-parallel groups for group-local routing


def init_moe(key, cfg: MoEConfig, dtype=jnp.float32):
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), in_axis=0, dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (E, D, F), in_axis=1, dtype=dtype),
        "w_up": dense_init(ks[2], (E, D, F), in_axis=1, dtype=dtype),
        "w_down": dense_init(ks[3], (E, F, D), in_axis=1, dtype=dtype),
    }
    if cfg.n_shared:
        p["shared"] = init_dense_ffn(ks[4], D, F * cfg.n_shared, kind="swiglu", dtype=dtype)
    return p


def _router(p, x2d, cfg: MoEConfig):
    """x2d (T, D) -> (gates (T,k), idx (T,k), aux_loss scalar)."""
    logits = (x2d.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # load-balance aux loss: E * sum_e f_e * P_e
    T = x2d.shape[0]
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.zeros((cfg.n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * cfg.top_k)
    aux = cfg.n_experts * jnp.sum(me * ce) * cfg.aux_loss_weight
    return gates, idx, aux


def _moe_dense(p, x2d, gates, idx, cfg: MoEConfig):
    """Oracle: all experts on all tokens, gather the chosen ones."""
    h = jnp.einsum("td,edf->tef", x2d, p["w_gate"])
    u = jnp.einsum("td,edf->tef", x2d, p["w_up"])
    eo = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, p["w_down"])  # (T,E,D)
    sel = jnp.take_along_axis(eo, idx[:, :, None], axis=1)            # (T,k,D)
    return jnp.sum(sel * gates[:, :, None].astype(sel.dtype), axis=1)


def _moe_gather(p, x2d, gates, idx, cfg: MoEConfig):
    """Production dispatch: group-local sort → (G,E,C,D) buffers → batched GEMM."""
    T, D = x2d.shape
    E, k, G = cfg.n_experts, cfg.top_k, max(1, cfg.data_groups)
    Tg = T // G
    C = max(1, int(math.ceil(k * Tg / E * cfg.capacity_factor)))

    xg = x2d.reshape(G, Tg, D)
    eid = idx.reshape(G, Tg * k)                                    # expert of each slot
    gat = gates.reshape(G, Tg * k)

    order = jnp.argsort(eid, axis=-1)                               # group-local sort
    eid_s = jnp.take_along_axis(eid, order, axis=-1)
    tok_s = order // k                                              # source token per slot
    # position of each sorted slot within its expert
    counts = jax.vmap(lambda e: jnp.bincount(e, length=E))(eid)     # (G,E)
    offs = jnp.cumsum(counts, axis=-1) - counts                     # (G,E)
    pos = jnp.arange(Tg * k)[None, :] - jnp.take_along_axis(offs, eid_s, axis=-1)
    keep = pos < C
    pos_c = jnp.clip(pos, 0, C - 1)

    gather_tok = jnp.take_along_axis(xg, tok_s[:, :, None], axis=1)  # (G, Tg*k, D)
    gather_tok = jnp.where(keep[:, :, None], gather_tok, 0)
    buf = jnp.zeros((G, E, C, D), x2d.dtype)
    gi = jnp.broadcast_to(jnp.arange(G)[:, None], (G, Tg * k))
    buf = buf.at[gi, eid_s, pos_c].add(gather_tok)

    h = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    out_buf = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * u, p["w_down"])

    out_slots = out_buf[gi, eid_s, pos_c]                            # (G, Tg*k, D)
    out_slots = jnp.where(keep[:, :, None], out_slots, 0)
    gat_s = jnp.take_along_axis(gat, order, axis=-1)
    out_slots = out_slots * gat_s[:, :, None].astype(out_slots.dtype)
    y = jnp.zeros((G, Tg, D), x2d.dtype).at[gi, tok_s].add(out_slots)
    return y.reshape(T, D)


def moe_ffn(p, x, cfg: MoEConfig):
    """x (B, T, D) -> (y, aux_loss)."""
    B, T, D = x.shape
    x2d = x.reshape(B * T, D)
    if cfg.impl == "ep":
        y, aux = _moe_ep(p, x2d, cfg)
        if cfg.n_shared:
            y = y + dense_ffn(p["shared"], x2d, kind="swiglu")
        return y.reshape(B, T, D), aux
    gates, idx, aux = _router(p, x2d, cfg)
    if cfg.impl == "dense":
        y = _moe_dense(p, x2d, gates, idx, cfg)
    else:
        y = _moe_gather(p, x2d, gates, idx, cfg)
    if cfg.n_shared:
        y = y + dense_ffn(p["shared"], x2d, kind="swiglu")
    return y.reshape(B, T, D), aux


# ---------------------------------------------------------------------------
# EP dispatch: shard_map all-to-all (DeepSeek-style expert parallelism)
# ---------------------------------------------------------------------------


def _moe_ep_local(p_router, w_gate, w_up, w_down, x_m, cfg: MoEConfig, ep_axis: str):
    """Per-device body (inside shard_map): x_m (chunk, D) are THIS device's
    tokens (the model-axis slice); expert weights are this device's E_loc
    experts.  Dispatch = all_to_all of capacity-padded per-expert buffers.
    """
    M = axis_size(ep_axis)
    chunk, D = x_m.shape
    E = cfg.n_experts
    E_loc = E // M
    k = cfg.top_k
    C = max(1, int(math.ceil(k * chunk / E * cfg.capacity_factor)))

    logits = (x_m.astype(jnp.float32) @ p_router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    # --- local capacity-padded buffers, one slot group per GLOBAL expert ----
    eid = idx.reshape(-1)                                     # (chunk·k,)
    gat = gates.reshape(-1)
    order = jnp.argsort(eid)
    eid_s = eid[order]
    tok_s = order // k
    counts = jnp.bincount(eid, length=E)
    offs = jnp.cumsum(counts) - counts
    pos = jnp.arange(chunk * k) - offs[eid_s]
    keep = pos < C
    pos_c = jnp.clip(pos, 0, C - 1)
    sent = jnp.where(keep[:, None], x_m[tok_s], 0)
    buf = jnp.zeros((E, C, D), x_m.dtype).at[eid_s, pos_c].add(sent)

    # --- dispatch: (M, E_loc, C, D) all_to_all over the expert axis ----------
    buf = buf.reshape(M, E_loc, C, D)
    recv = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                              tiled=False)                    # (M, E_loc, C, D)
    toks = recv.reshape(M * E_loc * C, D) if False else recv

    # --- expert GEMMs on my E_loc experts (batch dim = source device × C) ----
    te = toks.transpose(1, 0, 2, 3).reshape(E_loc, M * C, D)  # (E_loc, MC, D)
    h = jnp.einsum("ecd,edf->ecf", te, w_gate)
    u = jnp.einsum("ecd,edf->ecf", te, w_up)
    out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, w_down)
    out = out.reshape(E_loc, M, C, D).transpose(1, 0, 2, 3)   # (M, E_loc, C, D)

    # --- return trip + combine ------------------------------------------------
    back = jax.lax.all_to_all(out, ep_axis, split_axis=0, concat_axis=0,
                              tiled=False).reshape(E, C, D)
    slots = back[eid_s, pos_c]
    slots = jnp.where(keep[:, None], slots, 0) * gat[order][:, None].astype(back.dtype)
    y_m = jnp.zeros((chunk, D), x_m.dtype).at[tok_s].add(slots)

    # load-balance aux (local estimate; mean over devices happens via out spec)
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[eid].add(1.0) / (chunk * k)
    aux = cfg.n_experts * jnp.sum(me * ce) * cfg.aux_loss_weight
    return y_m, aux


def _moe_ep(p, x2d, cfg: MoEConfig):
    """Global entry: shard_map over (data, model); tokens data-sharded and
    model-replicated on entry; each model rank takes its token slice, routes,
    and exchanges with the expert owners via all_to_all.  Requires the ambient
    mesh registered by launch.shardings.set_mesh_axis_sizes."""
    from jax.sharding import PartitionSpec as P

    from repro.launch import shardings as sh

    mesh = sh.CURRENT_MESH
    if mesh is None:
        raise RuntimeError("moe_impl='ep' needs a mesh (launch/steps.build_cell)")
    ep_axis = "model"
    dp = tuple(a for a in mesh.axis_names if a != ep_axis)
    M = int(mesh.shape[ep_axis])
    T, D = x2d.shape

    def body(p_router, w_gate, w_up, w_down, x_loc):
        m = jax.lax.axis_index(ep_axis)
        chunk = x_loc.shape[0] // M
        x_m = jax.lax.dynamic_slice_in_dim(x_loc, m * chunk, chunk)
        y_m, aux = _moe_ep_local(p_router, w_gate, w_up, w_down, x_m, cfg, ep_axis)
        # republish the full token set on every model rank
        y_loc = jax.lax.all_gather(y_m, ep_axis, axis=0, tiled=True)
        aux = jax.lax.pmean(aux, ep_axis)
        return y_loc, aux[None]

    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(ep_axis, None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None), P(dp, None)),
        out_specs=(P(dp, None), P(dp)),
        check_vma=False,
    )(p["router"], p["w_gate"], p["w_up"], p["w_down"], x2d)
    return y, jnp.mean(aux)
