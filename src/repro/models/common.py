"""Shared model building blocks: norms, RoPE, embeddings, init, scan-over-layers.

Models are plain init/apply function pairs over dict pytrees (no framework
dependency); leaf *names* are the contract the sharding rules in
``launch/shardings.py`` pattern-match on.  Layer stacks carry a leading layer
dimension and are executed with ``jax.lax.scan`` (small HLO, fast compiles,
remat-friendly) — the MaxText-style production layout.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


# -- initialisation ------------------------------------------------------------


def dense_init(key, shape, in_axis: int = -2, dtype=jnp.float32):
    """Truncated-normal fan-in init (≈ variance_scaling(1.0))."""
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


# -- norms ----------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# -- rotary embeddings -----------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """Half-rotation RoPE.  x: (..., T, H, D); positions: (..., T)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, D/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., T, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- losses -----------------------------------------------------------------------


def softmax_cross_entropy(logits, labels, *, z_loss: float = 0.0):
    """Mean next-token CE with optional z-loss; logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return jnp.mean(loss)


# -- scan over layers ---------------------------------------------------------------


def stack_layers(init_one: Callable, key, n_layers: int):
    """Initialise a stacked layer pytree: every leaf gets a leading L dim."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_one)(keys)


def scan_blocks(block_fn: Callable, stacked_params, x, *, remat: str = "none",
                unroll: int = 1):
    """x -> block_fn(params_l, x) for l in layers, via lax.scan.

    remat: "none" | "full" (checkpoint each layer — the standard memory/compute
    trade for training long sequences).
    """

    def body(carry, layer_params):
        return block_fn(layer_params, carry), None

    if remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    out, _ = jax.lax.scan(body, x, stacked_params, unroll=unroll)
    return out


def scan_blocks_with_cache(block_fn: Callable, stacked_params, cache, x):
    """Decode-path scan: block_fn(params_l, cache_l, x) -> (new_cache_l, x).

    cache is stacked with a leading layer dim; the updated stack is returned.
    """

    def body(carry, inp):
        layer_params, layer_cache = inp
        new_cache, y = block_fn(layer_params, layer_cache, carry)
        return y, new_cache

    x, new_cache = jax.lax.scan(body, x, (stacked_params, cache))
    return new_cache, x


def abstract_init(init_fn: Callable, *args):
    """Shape-only init: returns ShapeDtypeStructs, zero FLOPs, zero memory."""
    return jax.eval_shape(init_fn, *args)


# -- §Perf levers ----------------------------------------------------------------


@jax.custom_vjp
def bf16_boundary(x):
    """Identity forward; casts the cotangent to bf16 in backward.

    Placed at residual-stream block boundaries it forces the TP backward
    all-reduces (which XLA otherwise runs on the fp32 cotangents produced by
    the fp32-internal norms/softmax) down to bf16 — halving backward
    collective bytes at the cost of bf16 gradient precision across blocks.
    """
    return x


def _bf16_boundary_fwd(x):
    return x, None


def _bf16_boundary_bwd(_res, g):
    return (g.astype(jnp.bfloat16).astype(g.dtype),)


bf16_boundary.defvjp(_bf16_boundary_fwd, _bf16_boundary_bwd)


def chunked_softmax_cross_entropy(hidden, head_w, labels, *, chunk: int = 8192,
                                  z_loss: float = 0.0, full_unroll: bool = False):
    """Streaming CE: never materialises the (B,T,V) logits.

    Scans vocab chunks of the head matmul, carrying the running max /
    log-sum-exp and the label logit — O(B·T·chunk) live memory instead of
    O(B·T·V) fp32.  hidden (B,T,D) bf16, head_w (D,V).
    """
    B, T, D = hidden.shape
    V = head_w.shape[-1]
    nchunks = (V + chunk - 1) // chunk
    pad = nchunks * chunk - V
    wp = jnp.pad(head_w, ((0, 0), (0, pad)))
    wc = wp.reshape(D, nchunks, chunk).transpose(1, 0, 2)          # (nc, D, chunk)

    hf = hidden
    lab = labels

    def body(carry, inp):
        m, lse_acc, label_logit = carry
        ci, w = inp
        logits = (hf @ w).astype(jnp.float32)                       # (B,T,chunk)
        base = ci * chunk
        vpos = base + jnp.arange(chunk)
        logits = jnp.where((vpos < V)[None, None, :], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        lse_acc = lse_acc * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[..., None]), axis=-1)
        in_chunk = jnp.logical_and(lab >= base, lab < base + chunk)
        local = jnp.clip(lab - base, 0, chunk - 1)
        picked = jnp.take_along_axis(logits, local[..., None], axis=-1)[..., 0]
        label_logit = jnp.where(in_chunk, picked, label_logit)
        return (m_new, lse_acc, label_logit), None

    m0 = jnp.full((B, T), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, T), jnp.float32)
    g0 = jnp.zeros((B, T), jnp.float32)
    (m, lse_acc, label_logit), _ = jax.lax.scan(
        body, (m0, l0, g0), (jnp.arange(nchunks), wc),
        unroll=nchunks if full_unroll else 1)
    lse = m + jnp.log(jnp.maximum(lse_acc, 1e-30))
    loss = lse - label_logit
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return jnp.mean(loss)
