from repro.models.build import Model, build_model
from repro.models.common import rms_norm, layer_norm, apply_rope, softmax_cross_entropy

__all__ = ["Model", "build_model", "rms_norm", "layer_norm", "apply_rope",
           "softmax_cross_entropy"]
