"""starcoder2-3b [dense] — 30L d3072 24H GQA kv=2, RoPE, GELU MLP + bias, LayerNorm.

[arXiv:2402.19173; hf].  (4096-token sliding window is a no-op at these shapes
and is not modelled — noted in DESIGN.md.)
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab=49152, head_dim=128,
    ffn_kind="gelu", ffn_bias=True, norm_kind="layer", qkv_bias=True,
    rope_theta=999999.0,
)
