"""deepseek-v3-671b [moe] — 61L d7168 128H MLA, 1 shared + 256 routed top-8, MTP.

[arXiv:2412.19437; hf].  d_ff=2048 is the routed-expert width; the leading 3
dense layers use the published 18432 dense width.  MLA ranks are the published
q_lora 1536 / kv_lora 512 / nope 128 / rope 64 / v 128.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab=129280, head_dim=128,
    attn_kind="mla",
    q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=256, top_k=8, n_shared_experts=1, d_ff_expert=2048,
    first_dense_layers=3, d_ff_dense=18432,
    mtp=True,
    rope_theta=10000.0,
)
