"""Architecture registry: --arch <id> resolves here."""

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec, cell_runnable, smoke_config

from repro.configs.deepseek_v3_671b import CONFIG as _deepseek
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.starcoder2_3b import CONFIG as _starcoder2
from repro.configs.qwen3_4b import CONFIG as _qwen3_4b
from repro.configs.qwen2_72b import CONFIG as _qwen2_72b
from repro.configs.qwen3_1_7b import CONFIG as _qwen3_1_7b
from repro.configs.llama32_vision_90b import CONFIG as _llama_vision
from repro.configs.zamba2_2_7b import CONFIG as _zamba2
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.mamba2_2_7b import CONFIG as _mamba2

ARCHS = {c.name: c for c in [
    _deepseek, _moonshot, _starcoder2, _qwen3_4b, _qwen2_72b,
    _qwen3_1_7b, _llama_vision, _zamba2, _hubert, _mamba2,
]}

def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]

__all__ = ["ARCHS", "SHAPES", "ArchConfig", "ShapeSpec", "cell_runnable",
           "get_arch", "smoke_config"]
