"""Architecture / shape / mesh configuration schema.

Every assigned architecture gets a ``configs/<id>.py`` exporting ``CONFIG``
(the exact published config) — the registry in ``configs/__init__`` resolves
``--arch <id>`` to it.  ``smoke_config`` derives the reduced same-family
variant used by CPU tests; the full configs are only ever touched through
``.lower().compile()`` dry-runs with ShapeDtypeStruct inputs.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None       # default: d_model // n_heads

    # attention flavour
    attn_kind: str = "gqa"               # gqa | mla | none
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_kind: str = "rms"               # rms | layer
    causal: bool = True

    # ffn flavour
    ffn_kind: str = "swiglu"             # swiglu | gelu
    ffn_bias: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0
    d_ff_dense: int = 0                  # width of the leading dense layers
    capacity_factor: float = 1.25
    moe_impl: str = "gather"             # gather | dense
    aux_loss_weight: float = 0.01

    # MLA (deepseek)
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 128
    ssd_impl: str = "chunked"            # chunked | pallas

    # hybrid (zamba2): shared attention block after every `hybrid_period` mamba layers
    hybrid_period: int = 0

    # vision (llama-3.2-vision): cross-attn layer closing every `cross_attn_period`-layer superblock
    cross_attn_period: int = 0
    vision_tokens: int = 1601
    vision_dim: int = 0                  # 0 → d_model (stub patch embeddings)

    # audio (hubert): stub frame embeddings
    frame_dim: int = 0

    # MTP (deepseek multi-token prediction)
    mtp: bool = False
    mtp_weight: float = 0.3

    # implementation switches
    attention_impl: str = "blocked"      # blocked | naive | pallas
    block_k: int = 512
    remat: str = "none"                  # none | full | dots (selective)
    dtype: str = "float32"
    z_loss: float = 0.0
    scan_layers: bool = True

    # ---- beyond-paper performance knobs (§Perf hillclimb) -------------------
    grad_reduce_dtype: str = ""          # "bfloat16" → cast grads before optimizer
                                         # (bf16 DP collectives, fp32 moments kept)
    bwd_bf16_boundary: bool = False      # cast residual-stream cotangents to bf16
                                         # (halves TP backward all-reduce bytes)
    chunked_ce: bool = False             # streaming CE over vocab chunks — never
                                         # materialises the (B,T,V) fp32 logits
    ce_chunk: int = 8192
    seq_shard: bool = False              # Megatron-SP: shard activations over the
                                         # model axis between blocks
    prefill_last_only: bool = False      # serving prefill emits only the last
                                         # position's logits (T× less head work)
    kv_cache_dtype: str = ""             # "int8" → quantized decode KV cache
                                         # (per-token-head scales, half the reads)
    batch_axes: tuple = ("data",)        # set by build_cell from the mesh

    @property
    def head_dim_actual(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_encoder_only(self) -> bool:
        return self.family == "audio"

    @property
    def has_decode(self) -> bool:
        return not self.is_encoder_only

    @property
    def subquadratic(self) -> bool:
        """Whether long_500k applies (SSM/hybrid archs only, per assignment)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_runnable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch × shape) cell."""
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only: no autoregressive decode step exists"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k context needs sub-quadratic attention"
    return True, ""


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests (one step, no NaNs)."""
    kw = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab=256,
        dtype="float32",
        remat="none",
        block_k=64,
    )
    if cfg.family == "moe":
        kw.update(n_experts=8, top_k=2, d_ff_expert=64,
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  first_dense_layers=min(cfg.first_dense_layers, 1),
                  d_ff_dense=128, moe_impl=cfg.moe_impl)
    if cfg.attn_kind == "mla":
        kw.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                  qk_rope_dim=8, v_head_dim=16)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=8, ssm_expand=2, ssm_chunk=8)
    if cfg.family == "hybrid":
        kw.update(n_layers=4, hybrid_period=2, n_kv_heads=4)  # MHA shared block
    if cfg.family == "vlm":
        kw.update(n_layers=4, cross_attn_period=2, vision_tokens=8, vision_dim=32)
    if cfg.family == "audio":
        kw.update(frame_dim=32, vocab=16)
    if cfg.mtp:
        kw.update(mtp=True)
    return cfg.replace(**kw)
