"""llama-3.2-vision-90b [vlm] — 100L d8192 64H GQA kv=8; cross-attn image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified].  Backbone only: the vision
frontend is a STUB — input_specs() provides precomputed patch embeddings
(vision_dim 7680, the published projector width); a cross-attention layer
closes every 5-layer superblock (20 x [4 self + 1 cross] = 100 layers).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    cross_attn_period=5, vision_tokens=1601, vision_dim=7680,
    rope_theta=500000.0,
)
