"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 48L d2048, 64e top-6.

[hf:moonshotai/Moonlight-16B-A3B].  Assignment specifies GQA kv=16 (MHA).
2 shared experts + leading dense layer follow the HF config; expert width 1408.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840, head_dim=128,
    n_experts=64, top_k=6, n_shared_experts=2, d_ff_expert=1408,
    first_dense_layers=1, d_ff_dense=11264,
    rope_theta=50000.0,
)
