"""zamba2-2.7b [hybrid] — 54 Mamba2 layers + weight-shared attention block.

[arXiv:2411.15242; hf].  The shared MHA+FFN block (32 heads, d_ff 10240) is
applied after every 6 mamba layers (9 applications, one weight set) —
zamba2's per-invocation LoRA deltas are not modelled (DESIGN.md).
ssm_state=64 per assignment.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, hybrid_period=6,
)
