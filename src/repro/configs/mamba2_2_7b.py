"""mamba2-2.7b [ssm] — 64L d2560 attention-free SSD. [arXiv:2405.21060; unverified].

state=128, headdim=64, expand=2 (d_inner 5120, 80 heads, 1 group).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280,
    attn_kind="none",
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
)
