"""hubert-xlarge [audio] — encoder-only 48L d1280 16H, per-frame classification.

[arXiv:2106.07447; unverified].  The conv feature extractor is a STUB —
input_specs() provides precomputed frame embeddings (frame_dim 512).  The
encoder uses RoPE in place of hubert's conv positional embedding (DESIGN.md).
No decode shapes: encoder-only.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504, head_dim=80,
    ffn_kind="gelu", ffn_bias=True, norm_kind="layer",
    causal=False, frame_dim=512,
)
