"""qwen3-4b [dense] — 36L d2560 32H GQA kv=8, qk_norm, head_dim 128. [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=9728, vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1000000.0,
)
