"""Partition rules: parameter/activation PartitionSpecs for DP/FSDP/TP/EP.

Rules pattern-match on leaf *paths* (the naming contract of models/) and give
a spec for the **trailing** dims; leading stack dims (layer scan, superblock
nesting) are padded with ``None``.  ``fsdp=True`` additionally shards the
d_model-ish dims over the data axis (required to fit ≥70B param models).

This is the coarse-grained-DSM layout policy of the paper at the parameter
level: each rule decides which mesh axis "owns" which package of each tensor.
"""

from __future__ import annotations

import re
from typing import Any, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.tree import tree_flatten_with_paths


def _rules(fsdp_axis) -> List[Tuple[str, Tuple]]:
    f = fsdp_axis  # None or "data"
    return [
        # embeddings / heads
        (r"embed\.table$", ("model", f)),
        (r"head\.w$", (f, "model")),
        (r"in_proj\.w$", (None, f)),          # audio frontend proj
        (r"vision_proj\.w$", (None, f)),
        # attention (GQA)
        (r"attn\.wq$", (f, "model", None)),
        (r"attn\.wk$", (f, "model", None)),
        (r"attn\.wv$", (f, "model", None)),
        (r"attn\.wo$", ("model", None, f)),
        (r"attn\.b[qkv]$", ("model", None)),
        (r"attn\.[qk]_norm$", (None,)),
        # attention (MLA)
        (r"attn\.w_dq$", (f, None)),
        (r"attn\.w_uq$", (None, "model", None)),
        (r"attn\.w_dkv$", (f, None)),
        (r"attn\.w_kr$", (f, None)),
        (r"attn\.w_uk$", (None, "model", None)),
        (r"attn\.w_uv$", (None, "model", None)),
        (r"attn\.kv_norm$", (None,)),
        # dense ffn
        (r"ffn\.w_gate$", (f, "model")),
        (r"ffn\.w_up$", (f, "model")),
        (r"ffn\.w_down$", ("model", f)),
        (r"ffn\.w_in$", (f, "model")),
        (r"ffn\.w_out$", ("model", f)),
        (r"ffn\.b_in$", ("model",)),
        (r"ffn\.b_out$", (None,)),
        # MoE: experts over the model axis (EP), optional fsdp on d_model dim
        (r"moe\.router$", (f, None)),
        (r"moe\.w_gate$", ("model", f, None)),
        (r"moe\.w_up$", ("model", f, None)),
        (r"moe\.w_down$", ("model", None, f)),
        (r"moe\.shared\.w_gate$", (f, "model")),
        (r"moe\.shared\.w_up$", (f, "model")),
        (r"moe\.shared\.w_down$", ("model", f)),
        # mamba2
        (r"mamba\.in_proj$", (f, "model")),
        (r"mamba\.conv_w$", (None, "model")),
        (r"mamba\.conv_b$", ("model",)),
        (r"mamba\.(A_log|dt_bias|D)$", (None,)),
        (r"mamba\.norm$", ("model",)),
        (r"mamba\.out_proj$", ("model", f)),
        # mtp
        (r"mtp\.proj$", (f, None)),
        # norms and anything small: replicated
        (r"(norm|norm1|norm2|final_norm|norm_h|norm_e)\.(scale|bias)$", None),
    ]


def param_specs(params: Any, *, fsdp: bool = False) -> Any:
    """Pytree of PartitionSpecs matching `params` (works on SDS trees)."""
    rules = _rules("data" if fsdp else None)
    flat = tree_flatten_with_paths(params)
    specs = []
    for path, leaf in flat:
        spec = None
        for pat, trailing in rules:
            if re.search(pat, path):
                if trailing is None:
                    spec = P()
                else:
                    ndim = len(leaf.shape)
                    pad = (None,) * (ndim - len(trailing))
                    dims = pad + tuple(trailing)
                    # drop axes that don't divide the dim size
                    fixed = []
                    for size, ax in zip(leaf.shape, dims):
                        if ax is not None and size % _axis_div(ax) != 0:
                            fixed.append(None)
                        else:
                            fixed.append(ax)
                    spec = P(*fixed)
                break
        if spec is None:
            spec = P()  # replicate by default
        specs.append(spec)
    return jax.tree.unflatten(jax.tree.structure(params), specs)


_AXIS_SIZES = {"model": 16, "data": 16, "pod": 2}
CURRENT_MESH = None  # registered by set_mesh_axis_sizes; used by the EP MoE


def _axis_div(ax) -> int:
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= _AXIS_SIZES.get(a, 1)
        return n
    return _AXIS_SIZES.get(ax, 1)


def set_mesh_axis_sizes(mesh: Mesh) -> None:
    """Record mesh axis sizes so rules can drop non-dividing axes."""
    global _AXIS_SIZES, CURRENT_MESH
    _AXIS_SIZES = {name: int(mesh.shape[name]) for name in mesh.axis_names}
    CURRENT_MESH = mesh


def batch_spec(mesh: Mesh, *, seq_axis=None) -> P:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return P(dp, seq_axis)


def _axis_size_in(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= int(mesh.shape[a])
        return n
    return int(mesh.shape[ax])


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh axes don't divide (e.g. batch=1 decode)."""
    dims = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    fixed = []
    for size, ax in zip(shape, dims):
        fixed.append(ax if (ax is None or size % _axis_size_in(mesh, ax) == 0) else None)
    return P(*fixed)


def sanitize_tree(specs, sds_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s, x: sanitize_spec(s, x.shape, mesh),
        specs, sds_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def cache_specs(cache: Any, mesh: Mesh) -> Any:
    """KV/SSM caches: batch dim over data axes, head-ish dims over model.

    Cache leaves look like (layers..., B, S, KH, hd) / (layers..., B, S, r) /
    mamba conv (L, B, K, C) / ssm (L, B, H, N, P).  We shard the batch dim
    (first dim after the leading stack dims... identified as the dim whose
    size equals the global batch) over data, and any KH/H/C dim over model
    when divisible.  Heuristic by name for robustness.
    """
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    model_n = int(mesh.shape["model"])

    flat = tree_flatten_with_paths(cache)
    specs = []
    for path, leaf in flat:
        nd = len(leaf.shape)
        if path.endswith(".k") or path.endswith(".v") or \
                path.endswith(".k_q") or path.endswith(".v_q") or \
                path.endswith(".k_s") or path.endswith(".v_s"):
            # (..., B, S, KH, hd|1)
            lead = (None,) * (nd - 4)
            kh = leaf.shape[-2]
            specs.append(P(*lead, dp, None, "model" if kh % model_n == 0 else None, None))
        elif path.endswith(".c_kv") or path.endswith(".k_rope"):
            lead = (None,) * (nd - 3)
            specs.append(P(*lead, dp, None, None))
        elif path.endswith(".conv"):
            # (..., B, K, C)
            lead = (None,) * (nd - 3)
            c = leaf.shape[-1]
            specs.append(P(*lead, dp, None, "model" if c % model_n == 0 else None))
        elif path.endswith(".ssm"):
            # (..., B, H, N, P)
            lead = (None,) * (nd - 4)
            h = leaf.shape[-3]
            specs.append(P(*lead, dp, "model" if h % model_n == 0 else None, None, None))
        else:
            specs.append(P())
    return jax.tree.unflatten(jax.tree.structure(cache), specs)


def to_shardings(specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
