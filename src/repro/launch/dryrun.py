import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent, no hardware.

For one (arch × shape × mesh) cell:
    jax.jit(step, in_shardings=…, out_shardings=…).lower(**input_specs)
    .compile()  → memory_analysis() + cost_analysis() + collective schedule

Run one cell per process (device state + compile caches stay isolated):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k --mesh single --out experiments/dryrun

The first two lines of this file force 512 host devices BEFORE any jax import
— do not move them.

Scan-body correction: XLA cost analysis counts a lax.scan/while body ONCE, not
× trip count.  Each single-pod cell therefore also compiles 2–3 reduced-layer
*probes* and linearly extrapolates flops / bytes-accessed / collective bytes
to the real depth (exact for homogeneous layer stacks; inner scans of nested
stacks are fully unrolled so superblock costs are exact).  memory_analysis()
always comes from the full-depth compile.
"""

import argparse
import json
import sys
import time

import jax

from repro.configs import ARCHS, SHAPES, cell_runnable, get_arch
from repro.launch.mesh import HBM_BYTES, make_production_mesh
from repro.launch.roofline import analyse, extract_metrics, save_record
from repro.launch.steps import build_cell

# archs whose params don't fit TP-only at bf16: shard d_model dims over "data"
FSDP_ARCHS = {"deepseek-v3-671b", "qwen2-72b", "llama-3.2-vision-90b"}

_EXTRAP_KEYS = ("flops", "bytes", "coll_bytes", "coll_wire_bytes")


def _probe_plan(cfg):
    """Returns (list of probe override dicts, counts per probe, full counts).

    XLA cost analysis counts a while/scan body once and is CONSTANT in the
    trip count, so probes compile tiny configs with the layer scans fully
    UNROLLED (scan_layers=False): metrics are then affine in the layer counts
    n⃗ (v = base + d⃗·n⃗) and we solve for d⃗ and evaluate at the real n⃗.
    """
    if cfg.family == "moe" and cfg.first_dense_layers:
        probes = [
            {"first_dense_layers": 1, "n_layers": 2, "scan_layers": False},  # (1 dense, 1 moe)
            {"first_dense_layers": 2, "n_layers": 3, "scan_layers": False},  # (2, 1)
            {"first_dense_layers": 1, "n_layers": 3, "scan_layers": False},  # (1, 2)
        ]
        counts = [(1, 1), (2, 1), (1, 2)]
        full = (cfg.first_dense_layers, cfg.n_layers - cfg.first_dense_layers)
        return probes, counts, full
    if cfg.family == "hybrid":
        p = cfg.hybrid_period
        return ([{"n_layers": 1 * p, "scan_layers": False},
                 {"n_layers": 2 * p, "scan_layers": False}], [(1,), (2,)],
                (cfg.n_layers // p,))
    if cfg.family == "vlm":
        p = cfg.cross_attn_period
        return ([{"n_layers": 1 * p, "scan_layers": False},
                 {"n_layers": 2 * p, "scan_layers": False}], [(1,), (2,)],
                (cfg.n_layers // p,))
    lead = cfg.first_dense_layers
    return ([{"n_layers": lead + 1, "scan_layers": False},
             {"n_layers": lead + 2, "scan_layers": False}],
            [(1,), (2,)], (cfg.n_layers - lead,))


def _extrapolate(probe_metrics, counts, full):
    """Solve v = base + Σ d_i·n_i from probe points; evaluate at `full`."""
    import numpy as np

    A = np.array([[1.0] + list(map(float, c)) for c in counts])
    out = {}
    for key in _EXTRAP_KEYS:
        b = np.array([m[key] for m in probe_metrics])
        coef, *_ = np.linalg.lstsq(A, b, rcond=None)
        # per-layer coefficients are physically non-negative; tiny probes can
        # go negative from compile noise — clamp the SLOPE, then re-anchor the
        # base on the largest probe so the result never undershoots it.
        slopes = np.maximum(coef[1:], 0.0)
        base = float(b[-1] - sum(s * n for s, n in zip(slopes, counts[-1])))
        val = base + sum(s * n for s, n in zip(slopes, full))
        out[key] = float(max(val, float(b.max())))
    # per-op collective bytes: scale by the total ratio
    base = probe_metrics[-1]
    ratio = out["coll_bytes"] / base["coll_bytes"] if base["coll_bytes"] else 1.0
    out["coll_by_op"] = {k: v * ratio for k, v in base["coll_by_op"].items()}
    out["coll_counts"] = dict(base["coll_counts"])
    return out


def _compile_cell(cfg, shape, mesh, fsdp):
    t0 = time.time()
    cell = build_cell(cfg, shape, mesh, fsdp=fsdp)
    with mesh:
        compiled = cell.jitted.lower(*cell.args).compile()
    return cell, compiled, time.time() - t0


def run_cell(arch: str, shape_name: str, mesh_name: str, *, variant: str = "baseline",
             out_dir: str = "experiments/dryrun", fsdp=None,
             overrides=None, probes: bool = True, verbose: bool = True):
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_runnable(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "variant": variant, "skipped": True, "reason": reason}
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}__{variant}.skip.json"), "w") as f:
            json.dump(rec, f, indent=1)
        if verbose:
            print(f"SKIP {arch} × {shape_name}: {reason}")
        return None

    # production dtype policy: bf16 params/compute; remat for train
    cfg = cfg.replace(dtype="bfloat16",
                      remat="full" if shape.kind == "train" else "none")
    if fsdp is None:
        fsdp = arch in FSDP_ARCHS
    if overrides:
        cfg = cfg.replace(**overrides)

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_dev = mesh.size
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] devices={n_dev} fsdp={fsdp} "
              f"variant={variant}", flush=True)

    # full-depth compile: the coherence proof + memory analysis
    cell, compiled, t_full = _compile_cell(cfg, shape, mesh, fsdp)
    metrics = extract_metrics(compiled)
    if verbose:
        print(f"  full compile {t_full:.1f}s", flush=True)
        print(" ", compiled.memory_analysis(), flush=True)

    total_t = t_full
    if probes:
        plan, counts, full_counts = _probe_plan(cfg)
        probe_metrics = []
        for ov in plan:
            pcfg = cfg.replace(**ov)
            _, pc, t_p = _compile_cell(pcfg, shape, mesh, fsdp)
            probe_metrics.append(extract_metrics(pc))
            total_t += t_p
            if verbose:
                print(f"  probe {ov} compile {t_p:.1f}s flops={probe_metrics[-1]['flops']:.3e}",
                      flush=True)
        ex = _extrapolate(probe_metrics, counts, full_counts)
        metrics.update(ex)

    rec = analyse(cfg, shape, mesh_name, n_dev, metrics, total_t,
                  cell.param_count, variant=variant)
    if rec.peak_bytes > HBM_BYTES:
        rec.note = (f"peak {rec.peak_bytes/2**30:.1f} GiB > 16 GiB v5e HBM at {n_dev} chips "
                    f"— needs more pods / further sharding (reported honestly)")
    path = save_record(rec, out_dir)
    if verbose:
        print(f"  flops/dev={rec.hlo_flops:.3e} bytes/dev={rec.hlo_bytes:.3e} "
              f"coll/dev={rec.collective_bytes:.3e}", flush=True)
        print(" ", rec.summary(), flush=True)
        print(f"  -> {path}", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--fsdp", default=None, choices=[None, "on", "off"])
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the scan-correction probe compiles (multi-pod proof runs)")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides key=value (e.g. moe_impl=dense)")
    args = ap.parse_args(argv)

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    fsdp = None if args.fsdp is None else (args.fsdp == "on")
    run_cell(args.arch, args.shape, args.mesh, variant=args.variant,
             out_dir=args.out, fsdp=fsdp, overrides=overrides or None,
             probes=not args.no_probes)
    return 0


if __name__ == "__main__":
    sys.exit(main())
