"""Production training driver (CPU-runnable at reduced scale).

Wires the full stack end-to-end: arch config → model → sharded train step →
prefetching synthetic pipeline → async checkpointing → heartbeat-guarded loop
with automatic restore on restart.  On a real pod the same driver runs with
``make_production_mesh()``; here the mesh defaults to whatever devices exist.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_arch, smoke_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import LMDataPipeline, shard_batch
from repro.ft import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.launch import shardings as sh
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models.build import build_model
from repro.optim import adamw, warmup_cosine


def batch_for(cfg, shape, pipeline_step_batch):
    """Adapt the token pipeline batch to the arch family's input dict."""
    b = dict(pipeline_step_batch)
    if cfg.family == "audio":
        rngk = np.random.default_rng(int(np.asarray(b["tokens"])[0, 0]))
        B, T = b["tokens"].shape
        b = {"frames": jnp.asarray(rngk.normal(size=(B, T, cfg.frame_dim)), jnp.float32),
             "labels": jnp.asarray(np.asarray(b["labels"]) % cfg.vocab, dtype=jnp.int32)}
    elif cfg.family == "vlm":
        B = b["tokens"].shape[0]
        rngk = np.random.default_rng(0)
        b["vision_embeds"] = jnp.asarray(
            rngk.normal(size=(B, cfg.vision_tokens, cfg.vision_dim or cfg.d_model)), jnp.float32)
    return b


def train(arch: str, *, smoke: bool = True, steps: int = 50, batch: int = 8,
          seq: int = 128, lr: float = 3e-4, ckpt_dir: str | None = None,
          ckpt_every: int = 20, data: int = 1, model_axis: int = 1,
          log_every: int = 10, seed: int = 0, total_steps: int | None = None):
    cfg = get_arch(arch)
    if smoke:
        cfg = smoke_config(cfg)
    mesh = make_host_mesh(data=data, model=model_axis)
    sh.set_mesh_axis_sizes(mesh)
    model = build_model(cfg, data_groups=data)
    # total_steps fixes the LR schedule independent of this invocation's
    # horizon, so checkpoint-resume reproduces the uninterrupted run exactly
    total = total_steps or steps
    opt = adamw(lr=warmup_cosine(lr, max(1, total // 20), total))

    params = model.init(jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    start_step = 0

    ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        (params, opt_state), extra, start_step = restore_checkpoint(
            ckpt_dir, (params, opt_state))
        start_step += 1
        print(f"[train] restored checkpoint, resuming at step {start_step}")

    p_specs = sh.param_specs(params, fsdp=False)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))

    pipe = LMDataPipeline(batch, seq, cfg.vocab, mesh=None, seed=seed,
                          start_step=start_step)
    losses = []
    t0 = time.time()
    for _ in range(start_step, steps):
        step, raw = pipe.next()
        b = batch_for(cfg, None, raw)
        params, opt_state, loss, metrics = step_fn(params, opt_state, b, step)
        losses.append(float(loss))
        if ckpt and step > 0 and step % ckpt_every == 0:
            ckpt.save(step, (params, opt_state), extra={"loss": float(loss)})
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t0
            print(f"[train] step {step:5d} loss {float(loss):8.4f} "
                  f"({dt / max(1, len(losses)):.3f}s/step)", flush=True)
    if ckpt:
        ckpt.save(steps - 1, (params, opt_state))
        ckpt.wait()
    pipe.close()
    return losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args(argv)
    losses = train(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
                   seq=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir,
                   ckpt_every=args.ckpt_every, data=args.data,
                   model_axis=args.model_axis)
    print(f"[train] first loss {losses[0]:.4f} → last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
