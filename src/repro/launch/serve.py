"""Serving driver: batched prefill + autoregressive decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.models.build import build_model


def serve(arch: str, *, smoke: bool = True, batch: int = 4, prompt_len: int = 32,
          gen: int = 32, seed: int = 0, greedy: bool = True):
    cfg = get_arch(arch)
    if smoke:
        cfg = smoke_config(cfg)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch has no decode path")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, size=(batch, prompt_len)), jnp.int32)
    max_len = prompt_len + gen
    cache = model.init_cache(batch, max_len)

    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    # prefill via repeated decode (cache-exact; a fused prefill kernel is the
    # optimized path — see launch/steps.py prefill cells)
    t0 = time.time()
    logits = None
    for pos in range(prompt_len):
        logits, cache = decode(params, cache, prompts[:, pos:pos + 1], pos)
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t1 = time.time()
    for i in range(gen):
        out_tokens.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok, prompt_len + i)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t1

    toks = np.concatenate(out_tokens, axis=1)
    tok_s = batch * gen / t_decode if t_decode > 0 else float("inf")
    print(f"[serve] prefill {prompt_len} toks in {t_prefill:.2f}s; "
          f"decode {gen} steps × batch {batch}: {t_decode:.2f}s = {tok_s:.1f} tok/s")
    return toks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)
    serve(args.arch, smoke=args.smoke, batch=args.batch,
          prompt_len=args.prompt_len, gen=args.gen)


if __name__ == "__main__":
    main()
