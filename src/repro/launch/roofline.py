"""Roofline terms from compiled dry-run artifacts (TPU v5e constants).

Per (arch × shape × mesh) cell:
    compute term    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
    memory term     = HLO_bytes_per_device / HBM_BW
    collective term = collective_bytes_per_device / ICI_LINK_BW

Sources: ``compiled.cost_analysis()`` ('flops', 'bytes accessed' — both are the
per-device SPMD program's numbers); collective bytes parsed from
``compiled.as_text()`` by :mod:`repro.utils.hlo`.  MODEL_FLOPS uses the
6·N·D (train) / 2·N·D (inference) convention with MoE active-param scaling,
plus the causal-attention term — the "useful compute" yardstick that exposes
remat/dispatch/redundancy waste in the compiled program.
"""

from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass
from typing import Optional

from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch.mesh import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16
from repro.utils.hlo import CollectiveStats, collective_bytes_from_hlo


# ---------------------------------------------------------------------------
# "useful" model FLOPs
# ---------------------------------------------------------------------------


def active_param_count(cfg: ArchConfig, total_params: int, moe_params: int) -> float:
    """Params touched per token: scale routed experts by top_k/E."""
    if cfg.n_experts:
        return (total_params - moe_params) + moe_params * cfg.top_k / cfg.n_experts
    return float(total_params)


def matmul_param_count(cfg: ArchConfig) -> tuple[float, float]:
    """(total matmul params excl. embed-lookup, routed-expert matmul params).

    Analytic (independent of init) so the roofline doesn't need live trees.
    """
    D, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    hd = cfg.head_dim_actual
    H, KH = cfg.n_heads, cfg.n_kv_heads

    attn = 0.0
    if cfg.attn_kind == "gqa":
        attn = D * hd * (H + 2 * KH) + H * hd * D
    elif cfg.attn_kind == "mla":
        attn = (D * cfg.q_lora_rank + cfg.q_lora_rank * H * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                + D * cfg.kv_lora_rank + D * cfg.qk_rope_dim
                + cfg.kv_lora_rank * H * (cfg.qk_nope_dim + cfg.v_head_dim)
                + H * cfg.v_head_dim * D)

    def ffn_params(width):
        return (3 if cfg.ffn_kind == "swiglu" else 2) * D * width

    moe_routed = 0.0
    if cfg.family in ("ssm", "hybrid"):
        ssm_dproj = 2 * (cfg.ssm_expand * D) + 2 * cfg.ssm_groups * cfg.ssm_state * 2  # rough
        d_inner = cfg.ssm_expand * D
        n_heads_ssm = d_inner // cfg.ssm_head_dim
        mamba = D * (2 * d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + n_heads_ssm) + d_inner * D
        if cfg.family == "hybrid":
            n_super = L // cfg.hybrid_period
            shared = attn + ffn_params(cfg.d_ff)
            total = L * mamba + n_super * shared + D * V  # shared block *computes* n_super times
        else:
            total = L * mamba + D * V
        return total, 0.0

    if cfg.n_experts:
        n_dense = cfg.first_dense_layers
        n_moe = L - n_dense
        moe_routed = n_moe * cfg.n_experts * 3 * D * cfg.d_ff_expert
        shared = n_moe * cfg.n_shared_experts * 3 * D * cfg.d_ff_expert
        router = n_moe * D * cfg.n_experts
        dense = n_dense * ffn_params(cfg.d_ff_dense or cfg.d_ff)
        total = L * attn + moe_routed + shared + router + dense + D * V
        if cfg.mtp:
            total += 2 * D * D + attn + ffn_params(cfg.d_ff_dense or cfg.d_ff)
        return total, moe_routed

    if cfg.family == "vlm":
        total = L * (attn + ffn_params(cfg.d_ff)) + D * V
        if cfg.vision_dim and cfg.vision_dim != D:
            total += cfg.vision_dim * D
        return total, 0.0

    total = L * (attn + ffn_params(cfg.d_ff)) + D * V
    if cfg.family == "audio":
        total += cfg.frame_dim * D
    return total, 0.0


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Ideal (causal-aware) model FLOPs for this cell, whole batch, all devices."""
    total, routed = matmul_param_count(cfg)
    n_active = active_param_count(cfg, total, routed)
    B, T = shape.global_batch, shape.seq_len
    # per-head score/readout widths (MLA keys are nope+rope, values v_head_dim)
    if cfg.attn_kind == "mla":
        dk, dv = cfg.qk_nope_dim + cfg.qk_rope_dim, cfg.v_head_dim
    else:
        dk = dv = cfg.head_dim_actual
    kv_width = dk + dv
    L_attn = cfg.n_layers if cfg.family not in ("ssm", "hybrid") else (
        cfg.n_layers // cfg.hybrid_period if cfg.family == "hybrid" else 0)

    if shape.kind == "train":
        flops = 6.0 * n_active * B * T
        # causal attention fwd+bwd: 3 × 2·(dk+dv)·T·S·H, halved for causality
        flops += 3.0 * L_attn * B * T * T * cfg.n_heads * kv_width
        if cfg.family in ("ssm", "hybrid"):
            d_inner = cfg.ssm_expand * cfg.d_model
            flops += 3 * 2.0 * cfg.n_layers * B * T * cfg.ssm_chunk * d_inner  # SSD intra-chunk
        return flops
    if shape.kind == "prefill":
        flops = 2.0 * n_active * B * T
        flops += 1.0 * L_attn * B * T * T * cfg.n_heads * kv_width  # causal fwd
        return flops
    # decode: one token per sequence, full-cache attention reads
    flops = 2.0 * n_active * B
    flops += 2.0 * L_attn * B * T * cfg.n_heads * kv_width
    return flops


# ---------------------------------------------------------------------------
# record
# ---------------------------------------------------------------------------


@dataclass
class RooflineRecord:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    # raw per-device numbers
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_by_op: dict
    # memory analysis (per device)
    arg_bytes: float
    out_bytes: float
    temp_bytes: float
    peak_bytes: float
    # derived
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_total: float
    useful_ratio: float
    param_count: int
    compile_s: float
    variant: str = "baseline"
    note: str = ""

    def summary(self) -> str:
        return (f"{self.arch:>24s} {self.shape:<12s} {self.mesh:<6s} "
                f"C={self.compute_s*1e3:9.3f}ms M={self.memory_s*1e3:9.3f}ms "
                f"X={self.collective_s*1e3:9.3f}ms -> {self.bottleneck:<10s} "
                f"useful={self.useful_ratio:6.3f} peak={self.peak_bytes/2**30:7.2f}GiB")


def extract_metrics(compiled) -> dict:
    """Pull (per-device) flops / bytes / collective stats / memory from a
    compiled artifact.  NOTE: XLA cost analysis counts a while/scan body ONCE,
    not × trip-count — the dry-run corrects via probe extrapolation."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax returns one dict per device
        ca = ca[0] if ca else {}
    coll = collective_bytes_from_hlo(compiled.as_text())
    ma = compiled.memory_analysis()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": coll.total_bytes,
        "coll_wire_bytes": coll.total_wire_bytes,
        "coll_by_op": dict(coll.bytes_by_op),
        "coll_counts": dict(coll.count_by_op),
        "arg_bytes": float(getattr(ma, "argument_size_in_bytes", 0)),
        "out_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
        "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
        "alias_bytes": float(getattr(ma, "alias_size_in_bytes", 0)),
    }


def analyse(cfg: ArchConfig, shape: ShapeSpec, mesh_name: str, n_devices: int,
            metrics: dict, compile_s: float, param_count: int,
            variant: str = "baseline", note: str = "") -> RooflineRecord:
    flops = metrics["flops"]
    nbytes = metrics["bytes"]
    arg_b, out_b = metrics["arg_bytes"], metrics["out_bytes"]
    tmp_b, alias_b = metrics["temp_bytes"], metrics["alias_bytes"]
    peak = arg_b + out_b + tmp_b - alias_b

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = nbytes / HBM_BW
    coll_s = metrics["coll_bytes"] / ICI_LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful = (mf / n_devices) / flops if flops else 0.0
    return RooflineRecord(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, n_devices=n_devices,
        hlo_flops=flops, hlo_bytes=nbytes,
        collective_bytes=metrics["coll_bytes"], collective_by_op=metrics["coll_by_op"],
        arg_bytes=arg_b, out_bytes=out_b, temp_bytes=tmp_b, peak_bytes=peak,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bottleneck=bottleneck, model_flops_total=mf, useful_ratio=useful,
        param_count=param_count, compile_s=compile_s, variant=variant, note=note,
    )


def save_record(rec: RooflineRecord, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{rec.arch}__{rec.shape}__{rec.mesh}__{rec.variant}.json")
    with open(path, "w") as f:
        json.dump(asdict(rec), f, indent=1)
    return path


def load_records(out_dir: str):
    recs = []
    if not os.path.isdir(out_dir):
        return recs
    for fn in sorted(os.listdir(out_dir)):
        if fn.endswith(".json"):
            with open(os.path.join(out_dir, fn)) as f:
                recs.append(RooflineRecord(**json.load(f)))
    return recs
