"""Step builders: train_step / prefill_step / decode_step with shardings.

``build_cell`` assembles everything a dry-run or a real run needs for one
(arch × shape × mesh) cell: the jitted step with in/out shardings and the
ShapeDtypeStruct inputs (never allocating).  The same builders drive the real
CPU-scale training/serving drivers.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch import shardings as sh
from repro.models.build import Model, build_model
from repro.optim import Optimizer, adamw, apply_updates


def abstract_params(model: Model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def input_specs(cfg: ArchConfig, shape: ShapeSpec, model: Model):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, T = shape.global_batch, shape.seq_len
    f32, bf16, i32 = jnp.float32, jnp.bfloat16, jnp.int32
    act = bf16 if cfg.dtype != "float32" else f32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            batch = {"frames": jax.ShapeDtypeStruct((B, T, cfg.frame_dim), act),
                     "labels": jax.ShapeDtypeStruct((B, T), i32)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((B, T), i32),
                     "labels": jax.ShapeDtypeStruct((B, T), i32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.vision_dim or cfg.d_model), act)
        if shape.kind == "prefill":
            batch.pop("labels")
        return batch
    # decode: one new token against a seq_len-deep cache
    cache = jax.eval_shape(lambda: model.init_cache(B, T))
    batch = {"cache": cache,
             "tokens": jax.ShapeDtypeStruct((B, 1), i32),
             "pos": jax.ShapeDtypeStruct((), i32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.vision_dim or cfg.d_model), act)
    return batch


def batch_shard_specs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh, model: Model,
                      batch_sds) -> Any:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if shape.kind in ("train", "prefill"):
        specs = {k: P(dp, *([None] * (len(v.shape) - 1))) for k, v in batch_sds.items()}
        return sh.sanitize_tree(specs, batch_sds, mesh)
    specs = {"cache": sh.cache_specs(batch_sds["cache"], mesh),
             "tokens": P(dp, None),
             "pos": P()}
    if "vision_embeds" in batch_sds:
        specs["vision_embeds"] = P(dp, None, None)
    return sh.sanitize_tree(specs, batch_sds, mesh)


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------


def make_train_step(model: Model, opt: Optimizer, *, clip_norm: Optional[float] = 1.0):
    from repro.optim import clip_by_global_norm
    grad_dtype = getattr(model.cfg, "grad_reduce_dtype", "") or None

    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(params, batch)
        if grad_dtype:
            # paper-beyond: reduce DP gradients in bf16 (half the wire bytes);
            # optimizer moments stay fp32.
            grads = jax.tree.map(lambda g: g.astype(grad_dtype), grads)
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            metrics = dict(metrics, grad_norm=gnorm)
        updates, opt_state = opt.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        return params, opt_state, loss, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.forward(params, batch)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, batch):
        logits, cache = model.decode_step(params, batch["cache"], batch["tokens"], batch["pos"])
        return logits, cache

    return decode_step


# ---------------------------------------------------------------------------
# cell assembly
# ---------------------------------------------------------------------------


@dataclass
class Cell:
    cfg: ArchConfig
    shape: ShapeSpec
    mesh: Mesh
    jitted: Any          # jax.stages.Wrapped — call .lower(*cell.args)
    args: tuple          # SDS args for lower()
    param_count: int
    param_bytes: int


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh, *,
               fsdp: bool = False, opt: Optional[Optimizer] = None) -> Cell:
    """Assemble the jitted step + SDS inputs for one (arch × shape × mesh)."""
    from repro.utils.tree import tree_bytes, tree_count

    sh.set_mesh_axis_sizes(mesh)
    dp_axes_cfg = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dp = 1
    for ax in dp_axes_cfg:
        dp *= int(mesh.shape[ax])
    cfg = cfg.replace(batch_axes=dp_axes_cfg)
    model = build_model(cfg, data_groups=dp)

    params_sds = abstract_params(model)
    p_specs = sh.param_specs(params_sds, fsdp=fsdp)
    p_shard = sh.to_shardings(p_specs, mesh)

    batch_sds = input_specs(cfg, shape, model)
    b_specs = batch_shard_specs(cfg, shape, mesh, model, batch_sds)
    b_shard = sh.to_shardings(b_specs, mesh)

    dp_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    repl = NamedSharding(mesh, P())

    vocab_ax = "model" if cfg.vocab % int(mesh.shape["model"]) == 0 else None
    B, T = shape.global_batch, shape.seq_len
    out_T = T if (shape.kind == "prefill" and not cfg.prefill_last_only) else 1
    logits_spec = sh.sanitize_spec(P(dp_axes, None, vocab_ax),
                                   (B, out_T, cfg.vocab), mesh)

    if shape.kind == "train":
        opt = opt or adamw(lr=3e-4)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        o_specs = sh.param_specs(opt_sds, fsdp=fsdp) if not isinstance(opt_sds, tuple) or opt_sds else ()
        o_shard = sh.to_shardings(o_specs, mesh) if o_specs != () else ()
        step_fn = make_train_step(model, opt)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, o_shard, b_shard, repl),
            out_shardings=(p_shard, o_shard, repl, repl),
            donate_argnums=(0, 1),
        )
        args = (params_sds, opt_sds, batch_sds, jax.ShapeDtypeStruct((), jnp.int32))
    elif shape.kind == "prefill":
        step_fn = make_prefill_step(model)
        logits_shard = NamedSharding(mesh, logits_spec)
        jitted = jax.jit(step_fn, in_shardings=(p_shard, b_shard),
                         out_shardings=logits_shard)
        args = (params_sds, batch_sds)
    else:  # decode
        step_fn = make_decode_step(model)
        logits_shard = NamedSharding(mesh, logits_spec)
        cache_out = sh.to_shardings(b_specs["cache"], mesh)
        jitted = jax.jit(step_fn, in_shardings=(p_shard, b_shard),
                         out_shardings=(logits_shard, cache_out),
                         donate_argnums=(1,))  # cache is updated in place
        args = (params_sds, batch_sds)

    return Cell(cfg, shape, mesh, jitted, args,
                tree_count(params_sds), tree_bytes(params_sds))
