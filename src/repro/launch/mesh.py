"""Mesh construction. Functions only — importing this module never touches
jax device state (required so tests/benches see 1 device while the dry-run
sees its 512 forced host devices).

Production target: TPU v5e pods, 256 chips (16×16) per pod; the multi-pod
mesh prepends a "pod" axis (2×16×16 = 512 chips).  The axis contract:

  pod   — data parallel across pods (DCI)
  data  — data parallel / FSDP / ZeRO shard axis within a pod (ICI)
  model — tensor/expert parallel axis (ICI)
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

from repro.core.compat import make_mesh


def _mk(shape: Sequence[int], names: Sequence[str], devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    n = 1
    for s in shape:
        n *= s
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {tuple(shape)} needs {n} devices, have {len(devices)} — "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count "
            "before any jax import")
    return make_mesh(shape, names, devices=devices[:n])


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: Optional[int] = None) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    if pod:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))


def data_axes(mesh: Mesh) -> tuple:
    """Axes the batch is sharded over (pod folds into data parallel)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_degree(mesh: Mesh) -> int:
    d = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        d *= mesh.shape["pod"]
    return d


# Hardware constants for the roofline (TPU v5e, per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_LINK_BW = 50e9             # bytes/s per link
HBM_BYTES = 16 * 2**30         # 16 GiB per chip
