"""Launch layer: meshes, sharding rules, step builders, dry-run, roofline, drivers.

NOTE: do not import repro.launch.dryrun from here — it mutates XLA_FLAGS at
import time and must only ever run as its own process.
"""

from repro.launch.mesh import (
    HBM_BW,
    HBM_BYTES,
    ICI_LINK_BW,
    PEAK_FLOPS_BF16,
    data_axes,
    dp_degree,
    make_host_mesh,
    make_production_mesh,
)

__all__ = [
    "HBM_BW", "HBM_BYTES", "ICI_LINK_BW", "PEAK_FLOPS_BF16",
    "data_axes", "dp_degree", "make_host_mesh", "make_production_mesh",
]
