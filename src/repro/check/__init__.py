"""step.check — happens-before race detection, lock-order sanitizing, and a
spawn-time lint pass for STEP programs.

Armed per session via ``Session(check=True)`` (or an explicit
:class:`Checker`); disabled by default with a one-branch hot-path cost, the
same contract as :mod:`repro.trace`.

``lint`` is deliberately not imported here: it pulls in ``repro.core`` and
``repro.data`` lazily from inside the checker, keeping this package importable
from the core modules that embed the hooks.
"""

from repro.check.checker import (CHECKING, Checker, NULL_CHECKER, armed_count,
                                 as_checker, reset)
from repro.check.findings import CheckError, Finding

__all__ = ["CHECKING", "CheckError", "Checker", "Finding", "NULL_CHECKER",
           "armed_count", "as_checker", "reset"]
