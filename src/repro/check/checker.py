"""step.check — the correctness-analysis facade, armed like the tracer.

``Session(check=True)`` arms a :class:`Checker`; every instrumented hot path
in ``session.py`` / ``sync.py`` / ``shards.py`` / ``cache.py`` /
``accumulator.py`` guards its hook with the module-level :data:`CHECKING`
flag first, exactly like ``telemetry.TRACING`` — when no checker is armed the
added cost is one module-attribute load and a falsy branch, and nothing is
allocated.

The checker multiplexes three layers over one findings model
(:mod:`repro.check.findings`):

* :mod:`repro.check.races` — vector-clock happens-before race detection over
  ``SharedRef`` get/set/inc on the host backend;
* :mod:`repro.check.locks` — the shard→node/alloc lock-order sanitizer plus
  wait-for-cycle (deadlock) detection across DBarrier/DSemaphore;
* :mod:`repro.check.lint` — the spawn-time dry run that rejects structurally
  broken programs (barrier arity, ragged accumulates, host sync under SPMD)
  before any thread starts.

The checker's lock is a leaf in the locking order: hook bodies never call
back into store/sync code.  Thread identity (STEP tid, held-lock stack, the
lint-dry-run flag) lives in thread-locals, so per-thread state needs no lock
at all.
"""

from __future__ import annotations

import json
import threading
from typing import Any, Dict, List, Optional

from repro.check.findings import CheckError, Finding, call_site
from repro.check.locks import LockSanitizer, check_order
from repro.check.races import DRIVER, RaceDetector, snapshot_value

# ---------------------------------------------------------------------------
# Module-level fast path: CHECKING is True iff at least one Checker is armed.
# Hot paths check `stepcheck.CHECKING` BEFORE touching their checker, so the
# disabled-by-default cost is a module attribute load + branch.
# ---------------------------------------------------------------------------

CHECKING = False

_armed: set = set()
_armed_lock = threading.Lock()


def _arm(checker: "Checker") -> None:
    global CHECKING
    with _armed_lock:
        _armed.add(checker)
        CHECKING = True


def _disarm(checker: "Checker") -> None:
    global CHECKING
    with _armed_lock:
        _armed.discard(checker)
        CHECKING = bool(_armed)


def armed_count() -> int:
    """How many checkers are currently enabled (the leak-check hook: tier-1
    tests must leave this at 0, enforced by an autouse conftest fixture)."""
    with _armed_lock:
        return len(_armed)


def reset() -> int:
    """Disable every armed checker; returns how many were disabled."""
    with _armed_lock:
        leaked = list(_armed)
    for c in leaked:
        c.disable()
    return len(leaked)


class Checker:
    """One session's correctness analyses behind one findings list.

    ``strict=True`` (the default) makes error-severity *lint* findings raise
    :class:`CheckError` from ``Session.spawn`` — the program is rejected
    before any thread runs.  Race and lock findings are dynamic and only
    recorded (the run that produced them has already happened).
    """

    def __init__(self, enabled: bool = False, *, strict: bool = True,
                 max_findings: int = 1000):
        self.enabled = False
        self.strict = strict
        self.max_findings = max_findings
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._races = RaceDetector()
        self._locks = LockSanitizer()
        self._findings: List[Finding] = []
        self._seen: set = set()
        self.dropped = 0
        self._bound: set = set()      # live worker tids (bind → join window)
        self._expected = 0            # spawn cohort size (spawn → join window)
        if enabled:
            self.enable()

    # -- arming ---------------------------------------------------------------

    def enable(self) -> "Checker":
        if not self.enabled:
            self.enabled = True
            _arm(self)
        return self

    def disable(self) -> "Checker":
        if self.enabled:
            self.enabled = False
            _disarm(self)
        return self

    def __enter__(self) -> "Checker":
        return self.enable()

    def __exit__(self, *exc) -> None:
        self.disable()

    # -- identity -------------------------------------------------------------

    def _tid(self):
        return getattr(self._tls, "tid", DRIVER)

    def bind_thread(self, tid, node_id: int = 0) -> None:
        """Attach the calling OS thread to a STEP tid (HostBackend spawn)."""
        self._tls.tid = tid
        with self._lock:
            self._bound.add(tid)
            self._races.bind(tid)

    # -- findings -------------------------------------------------------------

    def _emit(self, finding: Finding) -> None:
        """Record one finding (checker lock held); dedupes and caps."""
        key = finding.key()
        if key in self._seen:
            return
        if len(self._findings) >= self.max_findings:
            self.dropped += 1
            return
        self._seen.add(key)
        self._findings.append(finding)

    def record(self, finding: Finding) -> None:
        with self._lock:
            self._emit(finding)

    def findings(self) -> List[Finding]:
        with self._lock:
            return list(self._findings)

    @property
    def benign_replicated(self) -> int:
        """Equal-value unordered write pairs suppressed as the sanctioned
        bulk-synchronous replicated-set idiom (session.py contract)."""
        with self._lock:
            return self._races.benign_replicated

    def report(self) -> Dict[str, Any]:
        with self._lock:
            per_layer: Dict[str, int] = {}
            per_severity: Dict[str, int] = {}
            for f in self._findings:
                per_layer[f.layer] = per_layer.get(f.layer, 0) + 1
                per_severity[f.severity] = per_severity.get(f.severity, 0) + 1
            return {"findings": [f.as_dict() for f in self._findings],
                    "count": len(self._findings),
                    "by_layer": per_layer,
                    "by_severity": per_severity,
                    "benign_replicated_writes": self._races.benign_replicated,
                    "dropped": self.dropped}

    def export(self, path: str) -> str:
        with open(path, "w") as fh:
            json.dump(self.report(), fh, indent=2)
        return path

    # -- spawn / join edges (session.py hooks) --------------------------------

    def on_spawn(self, n_threads: int) -> None:
        with self._lock:
            self._expected = n_threads
            self._races.on_spawn(self._tid())

    def after_join(self) -> None:
        with self._lock:
            self._races.after_join(self._tid(), tuple(self._bound))
            self._bound.clear()
            self._expected = 0
            self._locks.clear()

    def _live(self) -> set:
        """The deadlock detector's live set (checker lock held): the bound
        worker tids — but only once the whole spawn cohort has bound.  While
        threads are still launching, "every live thread is parked" is a
        startup race, not starvation, so the set is empty (which disables
        the starvation rule but keeps genuine wait-cycle detection)."""
        if len(self._bound) < self._expected:
            return set()
        return set(self._bound)

    # -- SharedRef accesses (session.py hooks, host/driver only) --------------

    def on_access(self, name: str, kind: str, value) -> None:
        if getattr(self._tls, "lint", None) is not None:
            return                      # dry run: structure only, no races
        site = call_site()
        snap = snapshot_value(value)
        tid = self._tid()
        with self._lock:
            for slug, other_tid, other_site, other_kind in \
                    self._races.record_access(tid, name, kind, site, snap):
                a, b = sorted([f"{kind} by {tid} at {site}",
                               f"{other_kind} by {other_tid} at {other_site}"])
                self._emit(Finding(
                    "race", slug, "error",
                    f"unsynchronized {slug} on {name!r}: {a} vs {b} — no "
                    "happens-before edge orders them and the values differ",
                    name=name,
                    sites=tuple(sorted({site, other_site})),
                    tids=tuple(sorted({tid, other_tid}, key=str))))

    # -- sync hooks (sync.py) -------------------------------------------------

    def lint_sync(self, obj, kind: str) -> Optional[bool]:
        """Absorb a sync-primitive call under the lint dry run: record the
        reach, block on nothing, mutate nothing.  Returns None in real runs
        (the caller proceeds normally)."""
        run = getattr(self._tls, "lint", None)
        if run is None:
            return None
        run.reach_sync(kind, obj, self._tls.lint_tid)
        return True

    def _begin_lint(self, run, tid) -> None:
        self._tls.lint = run
        self._tls.lint_tid = tid

    def _end_lint(self) -> None:
        self._tls.lint = None
        self._tls.lint_tid = None

    def sync_block(self, obj, kind: str) -> None:
        """About to block on a barrier/semaphore: publish the happens-before
        edge source (barriers only) and scan the wait-for graph."""
        tid = self._tid()
        key = (kind, id(obj))
        with self._lock:
            if kind == "barrier":
                self._races.publish(tid, key)
            for slug, message, tids in self._locks.block(
                    tid, kind, key, obj, self._live()):
                self._emit(Finding("lock", slug, "error", message, tids=tids))

    def sync_unblock(self, obj, kind: str, ok: bool) -> None:
        tid = self._tid()
        key = (kind, id(obj))
        with self._lock:
            self._locks.unblock(tid)
            if ok:
                if kind == "semaphore":
                    self._locks.sem_acquired(tid, key)
                self._races.join_pending(tid, key)

    def sem_release(self, obj) -> None:
        tid = self._tid()
        key = ("semaphore", id(obj))
        with self._lock:
            self._races.publish(tid, key)
            self._locks.sem_released(tid, key)

    def ssp_tick(self, obj) -> None:
        with self._lock:
            self._races.publish(self._tid(), ("ssp", id(obj)))

    def ssp_wait_done(self, obj, ok: bool) -> None:
        if ok:
            with self._lock:
                self._races.join_pending(self._tid(), ("ssp", id(obj)))

    # -- accumulator round hooks (accumulator.py) -----------------------------

    def acc_begin(self, obj) -> int:
        """Publish this thread's clock into the round edge; returns the
        publish-time epoch the collective write is recorded at."""
        with self._lock:
            return self._races.publish(self._tid(), ("accumulate", id(obj)))

    def acc_done(self, obj, output_name: str, token: int) -> None:
        tid = self._tid()
        with self._lock:
            self._races.join_pending(tid, ("accumulate", id(obj)))
            self._races.record_collective_write(tid, output_name, token,
                                                "accumulate-round")

    # -- internal lock hooks (shards.py / cache.py) ---------------------------

    def _held(self) -> list:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    def lock_acquired(self, key) -> None:
        held = self._held()
        violation = check_order(held, key,
                                getattr(self._tls, "rebalance", False),
                                getattr(self._tls, "handoff", False))
        if violation is not None:
            slug, message = violation
            site = call_site()
            with self._lock:
                self._emit(Finding("lock", slug, "error",
                                   f"{message} (at {site})",
                                   sites=(site,), tids=(self._tid(),)))
        held.append(tuple(key))

    def lock_released(self, key) -> None:
        held = self._held()
        key = tuple(key)
        for i in range(len(held) - 1, -1, -1):
            if held[i] == key:
                del held[i]
                return

    def rebalance_begin(self) -> None:
        self._tls.rebalance = True

    def rebalance_end(self) -> None:
        self._tls.rebalance = False

    def handoff_begin(self) -> None:
        """Arm the arc-handoff exemption for the calling thread: a migration
        window may hold exactly one sorted pair of shard locks."""
        self._tls.handoff = True

    def handoff_end(self) -> None:
        self._tls.handoff = False

    # -- lint entry points (session.py hooks) ---------------------------------

    def lint_spawn(self, session, thread_proc, data, broadcast) -> None:
        """The spawn-time dry run; raises :class:`CheckError` under strict
        mode when it finds error-severity hazards."""
        from repro.check.lint import run_lint

        found = run_lint(self, session, thread_proc, data, broadcast)
        errors = [f for f in found if f.severity == "error"]
        with self._lock:
            for f in found:
                self._emit(f)
        if self.strict and errors:
            raise CheckError(errors)

    def lint_sparse_budget(self, name: str, size: int, k: int) -> None:
        """Declaration-time sparse budget check (new_array/def_global)."""
        from repro.check.lint import check_sparse_budget

        with self._lock:
            for f in check_sparse_budget(name, size, k):
                self._emit(f)

    def check_delete(self, name: str, holders) -> None:
        """``delete`` of a name whose replicas are still live on nodes."""
        site = call_site()
        with self._lock:
            self._emit(Finding(
                "lint", "delete-live-replicas", "warning",
                f"delete({name!r}) at {site} with live cache replicas on "
                f"node(s) {sorted(holders)} — replicas and directory records "
                "are purged, but a concurrent reader of the deleted era may "
                "be mid-flight", name=name, sites=(site,)))


NULL_CHECKER = Checker(enabled=False)


def as_checker(check) -> Checker:
    """Resolve ``Session(check=...)``: a :class:`Checker` is adopted as-is
    (recovery re-arms the dead session's checker this way), ``True`` arms a
    fresh one, ``None``/``False`` give a fresh *disabled* checker that can be
    armed later via ``session.checker.enable()``."""
    if isinstance(check, Checker):
        return check
    return Checker(enabled=bool(check))
