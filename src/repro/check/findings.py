"""Shared findings/report model for step.check.

All three analysis layers (races / locks / lint) report through one shape: a
:class:`Finding` names the layer that produced it, a stable ``kind`` slug, a
severity, the DSM name involved (when there is one), the source locations of
the offending accesses, and the STEP thread ids.  The checker dedupes on
``Finding.key()`` so a racy loop reports each distinct (kind, name, sites)
pair once, not once per iteration.
"""

from __future__ import annotations

import os
import sys
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

#: severity levels, in increasing order of badness
SEVERITIES = ("warning", "error")

#: the analysis layer a finding came from
LAYERS = ("race", "lock", "lint")


class CheckError(RuntimeError):
    """Raised by a strict checker when the lint pass finds error-severity
    hazards at spawn time — before any thread has started running."""

    def __init__(self, findings):
        self.findings = list(findings)
        lines = "\n".join(f"  - {f.message}" for f in self.findings)
        super().__init__(
            f"step.check rejected the program ({len(self.findings)} "
            f"error finding(s)):\n{lines}")


@dataclass(frozen=True)
class Finding:
    """One correctness hazard, in the shape shared by all three layers."""

    layer: str                       # "race" | "lock" | "lint"
    kind: str                        # stable slug, e.g. "write-write"
    severity: str                    # "warning" | "error"
    message: str                     # human-readable, names both sites
    name: Optional[str] = None       # DSM name involved, if any
    sites: Tuple[str, ...] = ()      # "file:line" source locations
    tids: Tuple[Any, ...] = ()       # STEP thread ids involved

    def key(self) -> tuple:
        """Dedupe identity: the same hazard found again (another loop
        iteration, another round) collapses onto one finding."""
        return (self.layer, self.kind, self.name, self.sites, self.tids)

    def as_dict(self) -> Dict[str, Any]:
        return {"layer": self.layer, "kind": self.kind,
                "severity": self.severity, "message": self.message,
                "name": self.name, "sites": list(self.sites),
                "tids": [str(t) for t in self.tids]}


_INTERNAL = (os.sep + os.path.join("repro", "core") + os.sep,
             os.sep + os.path.join("repro", "check") + os.sep)


def call_site(extra_skip: int = 0) -> str:
    """The first stack frame *outside* repro.core/repro.check, as
    ``file:line`` — the access site a finding should point the user at.

    Hooks sit inside the framework, so the interesting frame is the caller's
    ``ref.get()`` / ``barrier.enter()`` line in user code (or a test).  Falls
    back to the outermost frame when every frame is internal (e.g. an
    accumulator round closing deep inside the framework)."""
    frame = sys._getframe(2 + extra_skip)
    last = None
    while frame is not None:
        fn = frame.f_code.co_filename
        last = f"{fn}:{frame.f_lineno}"
        if not any(part in fn for part in _INTERNAL):
            return last
        frame = frame.f_back
    return last or "<unknown>"
