"""Happens-before race detection for the host backend (step.check layer 1).

Classic vector-clock analysis, FastTrack-style: every STEP thread (plus the
driver) carries a vector clock; synchronization primitives add edges by
publishing the sender's clock into a per-object *pending* clock and joining
it into the receiver's.  The edges modelled:

* **spawn / join** — workers start from the driver's clock at ``spawn``; the
  driver joins every worker's clock at ``join``.
* **DBarrier release** — every ``enter`` publishes before blocking and joins
  the merged pending clock on release, so accesses before the barrier order
  against accesses after it in *every* thread.
* **DSemaphore hand-off** — ``release`` publishes, a successful ``acquire``
  joins (the critical-section transfer edge).
* **SSPClock window** — ``tick`` publishes, a successful ``wait`` joins the
  merged ticks.  This over-approximates the bounded-staleness ordering
  (deliberately: step.check must not false-positive on the sync the user
  *does* have; truly unsynchronized accesses still have no edge at all).
* **accumulator round** — each thread publishes at the top of ``accumulate``
  and joins when the round barrier releases; the collective store write is
  recorded at each thread's publish-time clock, which every peer dominates
  after the join.

Per DSM name, the last write and last read *per thread* are kept (program
order makes earlier accesses redundant).  An access pair is racy when neither
clock dominates the other.

One refinement keeps the paper's §4.5 idiom clean: the session's
bulk-synchronous contract says an in-worker ``ref.set(v)`` passes a value
identical across threads (every thread re-derives the same update from the
accumulated total).  A candidate pair whose values compare equal is therefore
counted as a *benign replicated write* instead of a race — an unordered pair
carrying identical bits cannot change any observable value.  A *read* racing
such a write earns the exemption only when the reading thread holds its own
program-ordered copy of the same bits (it participated in the replicated
set); otherwise observing the "right" value is luck, not safety.  Accesses
with differing values (the actual bug class) are always reported.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

DRIVER = "driver"


def snapshot_value(value) -> Optional[Tuple[np.ndarray, ...]]:
    """Host copy of a pytree's leaves, for the replicated-write comparison."""
    try:
        return tuple(np.asarray(leaf) for leaf in jax.tree.leaves(value))
    except Exception:
        return None


def values_equal(a, b) -> bool:
    if a is None or b is None:
        return False
    if len(a) != len(b):
        return False
    return all(x.shape == y.shape and x.dtype == y.dtype and np.array_equal(x, y)
               for x, y in zip(a, b))


class _Access:
    """Last access of one kind by one thread to one name."""

    __slots__ = ("clock", "site", "value", "kind")

    def __init__(self, clock: int, site: str, value, kind: str):
        self.clock = clock
        self.site = site
        self.value = value
        self.kind = kind


class RaceDetector:
    """Vector clocks + per-name access history.  Not thread-safe on its own:
    the owning :class:`~repro.check.checker.Checker` serialises every call
    under its (leaf) lock."""

    def __init__(self):
        self._vc: Dict[Any, Dict[Any, int]] = {}
        self._pending: Dict[tuple, Dict[Any, int]] = {}
        self._spawn_vc: Optional[Dict[Any, int]] = None
        self._writes: Dict[str, Dict[Any, _Access]] = {}
        self._reads: Dict[str, Dict[Any, _Access]] = {}
        self.benign_replicated = 0   # equal-value pairs suppressed (§4.5 idiom)

    # -- clocks ---------------------------------------------------------------

    def _clock(self, tid) -> Dict[Any, int]:
        vc = self._vc.get(tid)
        if vc is None:
            vc = self._vc[tid] = {tid: 1}
        return vc

    def _bump(self, tid) -> None:
        vc = self._clock(tid)
        vc[tid] = vc.get(tid, 0) + 1

    @staticmethod
    def _merge(dst: Dict[Any, int], src: Dict[Any, int]) -> None:
        for t, c in src.items():
            if c > dst.get(t, 0):
                dst[t] = c

    # -- spawn / join edges ---------------------------------------------------

    def on_spawn(self, driver_tid=DRIVER) -> None:
        self._spawn_vc = dict(self._clock(driver_tid))
        self._bump(driver_tid)

    def bind(self, tid) -> None:
        vc = dict(self._spawn_vc) if self._spawn_vc is not None else {}
        vc[tid] = vc.get(tid, 0) + 1
        self._vc[tid] = vc

    def after_join(self, driver_tid, worker_tids) -> None:
        dst = self._clock(driver_tid)
        for tid in worker_tids:
            src = self._vc.get(tid)
            if src is not None:
                self._merge(dst, src)
        self._bump(driver_tid)

    # -- sync edges -----------------------------------------------------------

    def publish(self, tid, key: tuple) -> int:
        """Merge ``tid``'s clock into the object's pending clock; returns the
        thread's own component (the epoch a collective write is recorded at)."""
        vc = self._clock(tid)
        pending = self._pending.setdefault(key, {})
        self._merge(pending, vc)
        return vc[tid]

    def join_pending(self, tid, key: tuple) -> None:
        pending = self._pending.get(key)
        if pending:
            self._merge(self._clock(tid), pending)
        self._bump(tid)

    # -- accesses -------------------------------------------------------------

    def record_collective_write(self, tid, name: str, clock: int, site: str) -> None:
        """The accumulator's round output write, at the thread's publish-time
        epoch — dominated by every peer's clock after the round join, so the
        N per-thread records never race each other."""
        self._writes.setdefault(name, {})[tid] = _Access(clock, site, None,
                                                         "accumulate")

    def record_access(self, tid, name: str, kind: str, site: str, value):
        """Record a ``get``/``set``/``inc`` and return the race pairs it forms:
        a list of ``(kind_slug, other_tid, other_site, other_kind)`` tuples."""
        vc = self._clock(tid)
        races = []

        def unordered(other: _Access, other_tid) -> bool:
            return other_tid != tid and vc.get(other_tid, 0) < other.clock

        writes = self._writes.setdefault(name, {})
        reads = self._reads.setdefault(name, {})
        if kind == "read":
            # the replicated-read exemption needs the reader to have written
            # the same bits itself (program-ordered): then every unordered
            # copy of the value is interchangeable and the read is schedule-
            # independent.  A reader with no own copy is racy even when it
            # *happened* to observe the written bits — another schedule
            # reads the old value.
            own = writes.get(tid)
            for u, acc in writes.items():
                if unordered(acc, u):
                    if (values_equal(value, acc.value) and own is not None
                            and values_equal(own.value, acc.value)):
                        self.benign_replicated += 1
                    else:
                        races.append(("read-write", u, acc.site, acc.kind))
            reads[tid] = _Access(vc[tid], site, value, kind)
        else:  # "write" | "inc"
            for u, acc in writes.items():
                if not unordered(acc, u):
                    continue
                if kind == "inc" and acc.kind == "inc":
                    continue     # atomic increments commute (store-serialised)
                if values_equal(value, acc.value):
                    self.benign_replicated += 1
                else:
                    races.append(("write-write", u, acc.site, acc.kind))
            for u, acc in reads.items():
                if not unordered(acc, u):
                    continue
                if values_equal(value, acc.value):
                    self.benign_replicated += 1
                else:
                    races.append(("read-write", u, acc.site, acc.kind))
            writes[tid] = _Access(vc[tid], site, value, kind)
        return races
