"""Lock-order sanitizer + wait-for cycle detection (step.check layer 2).

The repo's internal locking invariants have so far lived only in docstrings
(`shards.py` / `cache.py`): the order is strictly **shard → node-cache**, the
rebalancer takes every involved shard lock in **sorted id** order, and the
allocator lock never nests with either.  This module turns those comments
into runtime assertions: every shard/node/alloc acquisition is checked
against the calling thread's held-lock stack.

Lock keys are ``("shard", id)`` / ``("node", id)`` / ``("alloc", 0)``.  Shard
locks are RLocks (the cache composes store ops while holding one), so a
re-acquisition of the *same* shard is always legal.

The second half watches user-level sync: which semaphores each STEP thread
holds and what every blocked thread is waiting on.  A wait-for graph over the
*blocked* threads (barrier waiters point at the threads that have not arrived;
semaphore waiters point at the holders) is searched for cycles on every
block — the "thread parked on barrier X while holding semaphore Y that the
missing thread needs" deadlock.  A barrier no remaining live thread can ever
fill (arity > live threads, everyone already parked) is reported as starved.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Set, Tuple

LockKey = Tuple[str, int]


def check_order(held: List[LockKey], key: LockKey, rebalance: bool,
                handoff: bool = False) -> Optional[Tuple[str, str]]:
    """Validate acquiring ``key`` while holding ``held`` (oldest first).
    Returns ``(kind_slug, message)`` on a violation, else None.  Pure
    function — the caller owns all state.

    ``rebalance`` is the stop-the-world exemption (any number of shard locks,
    sorted).  ``handoff`` is the incremental arc-handoff exemption: exactly
    one *pair* of shard locks, sorted — a migration window moves one entry at
    a time, so a third shard lock under the handoff flag is a bug."""
    domain, ident = key
    if domain == "shard":
        for hd, hi in held:
            if hd == "node":
                return ("lock-order-inversion",
                        f"shard {ident} lock requested while holding node "
                        f"{hi} lock — documented order is shard → node")
            if hd == "alloc":
                return ("lock-order-inversion",
                        f"shard {ident} lock requested under the allocator "
                        "lock — the alloc lock must not nest")
            if hd == "shard" and hi != ident:
                if not rebalance and not handoff:
                    return ("shard-shard-nesting",
                            f"shard {ident} lock requested while holding "
                            f"shard {hi} — only the rebalancer or an arc "
                            "handoff may hold two shards, in sorted id order")
                if hi > ident:
                    return ("rebalance-unsorted" if rebalance
                            else "handoff-unsorted",
                            f"{'rebalance' if rebalance else 'arc handoff'} "
                            f"acquired shard {ident} after shard {hi} — "
                            "shard locks must be taken in sorted id order")
                if handoff and not rebalance:
                    others = {i for d, i in held if d == "shard" and i != ident}
                    if len(others) >= 2:
                        return ("handoff-pair-overflow",
                                f"arc handoff requested shard {ident} while "
                                f"already holding shards {sorted(others)} — "
                                "a handoff moves one entry under exactly two "
                                "shard locks")
    elif domain == "node":
        for hd, hi in held:
            if hd == "node" and hi != ident:
                return ("lock-order-inversion",
                        f"node {ident} lock requested while holding node "
                        f"{hi} — node locks never nest")
            if hd == "alloc":
                return ("lock-order-inversion",
                        f"node {ident} lock requested under the allocator "
                        "lock — the alloc lock must not nest")
    elif domain == "alloc":
        if held:
            return ("lock-order-inversion",
                    f"allocator lock requested while holding {held[-1]} — "
                    "the alloc lock is a leaf and must be taken bare")
    return None


class LockSanitizer:
    """Wait-for graph over user sync primitives.  Held-lock stacks live in
    the checker's thread-locals; this class owns only cross-thread state and,
    like the race detector, runs under the checker's leaf lock."""

    def __init__(self):
        # semaphore key -> STEP tids currently holding a permit
        self._holders: Dict[tuple, Set[Any]] = {}
        # STEP tid -> (kind, key, obj) it is currently blocked on
        self._blocked: Dict[Any, Tuple[str, tuple, Any]] = {}

    def clear(self) -> None:
        self._holders.clear()
        self._blocked.clear()

    def sem_acquired(self, tid, key: tuple) -> None:
        self._holders.setdefault(key, set()).add(tid)

    def sem_released(self, tid, key: tuple) -> None:
        holders = self._holders.get(key)
        if not holders:
            return
        if tid in holders:
            holders.discard(tid)
        else:           # §5.3 allows releases from a non-holder thread
            holders.pop()

    def held_semaphores(self, tid) -> List[tuple]:
        return [key for key, holders in self._holders.items() if tid in holders]

    def block(self, tid, kind: str, key: tuple, obj,
              live: Set[Any]) -> List[Tuple[str, str, Tuple[Any, ...]]]:
        """Register ``tid`` as blocked and scan for deadlock.  Returns
        ``(kind_slug, message, tids)`` findings."""
        self._blocked[tid] = (kind, key, obj)
        return self._detect(live)

    def unblock(self, tid) -> None:
        self._blocked.pop(tid, None)

    # -- deadlock detection ---------------------------------------------------

    def _waiters(self, key: tuple) -> Set[Any]:
        return {t for t, (_, kk, _) in self._blocked.items() if kk == key}

    def _detect(self, live: Set[Any]) -> List[Tuple[str, str, Tuple[Any, ...]]]:
        out: List[Tuple[str, str, Tuple[Any, ...]]] = []
        # starved barrier: every live thread is already parked on it, yet the
        # arity still isn't met — no thread remains that could fill it
        for kind, key, obj in self._blocked.values():
            if kind != "barrier":
                continue
            waiters = self._waiters(key)
            count = getattr(obj, "count", len(waiters))
            if live and waiters >= live and len(waiters) < count:
                out.append((
                    "starved-barrier",
                    f"barrier (count={count}) has every live thread parked "
                    f"but only {len(waiters)} arrival(s) — it can never "
                    "release", tuple(sorted(waiters, key=str))))
        # fixed point over "can this thread ever proceed": any non-blocked
        # participant can; a semaphore waiter can when a permit is free or
        # ANY holder can proceed (OR-wait: one release suffices); a barrier
        # waiter can when the arity is met or EVERY missing live thread can
        # still arrive (AND-wait).  Whatever never gets marked is deadlocked.
        blocked = set(self._blocked)
        participants = set(live) | blocked
        for holders in self._holders.values():
            participants |= holders
        can = participants - blocked
        changed = True
        while changed:
            changed = False
            for tid in blocked - can:
                kind, key, obj = self._blocked[tid]
                if kind == "semaphore":
                    holders = set(self._holders.get(key, ())) - {tid}
                    ok = (getattr(obj, "_count", 0) > 0 or not holders
                          or bool(holders & can))
                else:
                    waiters = self._waiters(key)
                    missing = (live - waiters) if live else set()
                    count = getattr(obj, "count", len(waiters))
                    ok = (len(waiters) >= count
                          or (bool(missing) and missing <= can))
                if ok:
                    can.add(tid)
                    changed = True
        dead = blocked - can
        # a single stuck thread is ambiguous (an unbound helper thread could
        # still release it); two or more waiting on each other is a deadlock
        if len(dead) >= 2:
            parts = []
            for t in sorted(dead, key=str):
                kind, _, _ = self._blocked[t]
                held = self.held_semaphores(t)
                held_s = f" holding semaphore(s) {held}" if held else ""
                parts.append(f"thread {t} blocked on {kind}{held_s}")
            out.append(("wait-cycle",
                        "deadlock cycle: " + "; ".join(parts),
                        tuple(sorted(dead, key=str))))
        return out
