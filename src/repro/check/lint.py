"""Trace-time lint over thread procs (step.check layer 3).

``Session.spawn`` with an armed checker runs every thread proc once against a
:class:`LintCtx` **before any real thread starts**: reads come from a shadow
copy of the store, writes/incs stay in the shadow, ``accumulate`` records the
call (weighted by the enclosing ``ctx.iterate`` trip count) and returns the
local contribution as a shape-correct proxy, and sync primitives are absorbed
by the checker's lint hooks (recorded, never blocked on, never mutated).
Nothing escapes into the store, the sync objects or the real thread pool.

What the dry run catches, at check time instead of as a runtime hang or a
mid-round ``ValueError``:

* ``barrier-arity`` — a ``DBarrier`` reached by a set of threads that does
  not match its ``count`` (the classic everyone-waits-forever bug);
* ``ragged-accumulate`` — per-name accumulate call counts or contribution
  shapes that diverge across threads (would strand a round);
* ``spmd-host-sync`` — ``DBarrier``/``DSemaphore``/``SSPClock`` reached
  under SPMD lowering, where they are host-side Python effects the traced
  program cannot honour;
* ``sparse-overbudget`` — a declared or per-call top-k budget exceeding the
  blocked layout's :func:`~repro.core.sparse.pair_capacity` (silently lossier
  than asked);
* ``lint-trace-error`` (warning) — the proc raised under the dry run, so the
  structural checks for that thread are incomplete.

A strict checker (the default) raises :class:`~repro.check.findings.CheckError`
from ``spawn`` when any error-severity lint finding exists.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.check.findings import Finding, call_site


class LintRun:
    """Everything one dry run of a spawn records, across all thread ids."""

    def __init__(self):
        # sync kind -> id(obj) -> (obj, tids that reached it, first site)
        self.sync: Dict[str, Dict[int, Tuple[Any, Set[Any], str]]] = {}
        # name -> tid -> trip-weighted accumulate call count
        self.acc_counts: Dict[str, Dict[Any, int]] = {}
        # name -> set of contribution shapes seen
        self.acc_shapes: Dict[str, Set[tuple]] = {}
        # (name, size, k) sparse budgets referenced by accumulate calls
        self.sparse: Dict[str, Tuple[int, int]] = {}
        self.trace_errors: List[Tuple[Any, str]] = []

    def reach_sync(self, kind: str, obj, tid) -> None:
        slot = self.sync.setdefault(kind, {}).get(id(obj))
        if slot is None:
            self.sync[kind][id(obj)] = (obj, {tid}, call_site(extra_skip=1))
        else:
            slot[1].add(tid)


class LintCtx:
    """Duck-typed WorkerCtx substitute for the dry run.  Mirrors the ctx
    surface the analytics apps use: tid/n_threads/node_id, guard/barrier/span,
    iterate/fori, and the read/write/inc/accumulate transport — all against
    shadow state."""

    def __init__(self, session, checker, run: LintRun, tid, n_threads: int,
                 node_id, values: Dict[str, Any]):
        self._session = session
        self._checker = checker
        self._run = run
        self.tid = tid
        self.n_threads = n_threads
        self.node_id = node_id
        self.values = values
        self._repeat = 1

    # -- sync / tracing surface (no-ops under the dry run) -------------------

    def guard(self) -> None:
        return None

    def barrier(self, timeout: Optional[float] = None) -> bool:
        return True

    def span(self, name: str, **args):
        from repro.core import telemetry
        return telemetry.NULL_SPAN

    # -- iteration: run the body once, weight records by the trip count ------

    def iterate(self, step: Callable, carry, iters: int):
        return self.fori(lambda i, c: step(c), carry, iters)

    def fori(self, step: Callable, carry, iters: int):
        iters = int(iters)
        if iters <= 0:
            return carry
        outer = self._repeat
        self._repeat = outer * iters
        try:
            return step(0, carry)
        finally:
            self._repeat = outer

    # -- shadow transport (owner handles have nothing to shortcut here) ------

    def read(self, name: str, owner=None):
        return self.values[name]

    def write(self, name: str, value, owner=None) -> None:
        self.values[name] = value

    def inc(self, name: str, amount, owner=None):
        self.values[name] = self.values[name] + amount
        return self.values[name]

    def accumulate(self, name: str, local, mode, k: Optional[int]):
        counts = self._run.acc_counts.setdefault(name, {})
        counts[self.tid] = counts.get(self.tid, 0) + self._repeat
        self._run.acc_shapes.setdefault(name, set()).add(tuple(local.shape))
        mode_s = getattr(mode, "value", str(mode))
        if mode_s in ("sparse", "auto") and k is not None:
            self._run.sparse[name] = (int(local.size), int(k))
        self.values[name] = local
        return local


def run_lint(checker, session, thread_proc: Callable, data: Sequence,
             broadcast: Sequence) -> List[Finding]:
    """Dry-run ``thread_proc`` once per thread id and evaluate the structural
    checks.  Called from ``Session.spawn`` (through the checker) before the
    backend spawns anything."""
    from repro.data.pipeline import partition_rows

    backend = session.backend
    n = backend.n_threads
    kind = backend.kind
    tpn = getattr(getattr(backend, "pool", None), "threads_per_node", 1)
    shared0 = {m: session.store.get(m) for m in session.store.names()}
    run = LintRun()
    for tid in range(n):
        if kind == "host":
            lo_hi = [partition_rows(a.shape[0], tid, n) for a in data]
        else:   # SPMD trims ragged rows and splits evenly
            lo_hi = [((a.shape[0] // n) * tid, (a.shape[0] // n) * (tid + 1))
                     for a in data]
        shards = [a[lo:hi] for a, (lo, hi) in zip(data, lo_hi)]
        node_id = tid // tpn if kind == "host" else tid
        ctx = LintCtx(session, checker, run, tid, n, node_id, dict(shared0))
        prev = getattr(session._tls, "ctx", None)
        session._tls.ctx = ctx
        checker._begin_lint(run, tid)
        try:
            thread_proc(ctx, *shards, *broadcast)
        except Exception as exc:
            run.trace_errors.append((tid, f"{type(exc).__name__}: {exc}"))
        finally:
            checker._end_lint()
            session._tls.ctx = prev
    return evaluate(run, n_threads=n, backend_kind=kind)


def evaluate(run: LintRun, *, n_threads: int, backend_kind: str) -> List[Finding]:
    findings: List[Finding] = []

    if backend_kind == "spmd":
        for slots in run.sync.values():
            for _, (obj, tids, site) in slots.items():
                findings.append(Finding(
                    "lint", "spmd-host-sync", "error",
                    f"host-only sync primitive {type(obj).__name__} reached "
                    f"under SPMD lowering at {site} (thread ids {sorted(tids, key=str)}) "
                    "— barriers are implicit in the collectives; host "
                    "barriers/semaphores/SSP clocks are Python-side effects "
                    "the traced program cannot honour",
                    sites=(site,), tids=tuple(sorted(tids, key=str))))
    else:
        for _, (obj, tids, site) in run.sync.get("barrier", {}).items():
            count = getattr(obj, "count", None)
            if count is not None and len(tids) != count:
                findings.append(Finding(
                    "lint", "barrier-arity", "error",
                    f"DBarrier(count={count}) at {site} is reached by "
                    f"{len(tids)} of {n_threads} spawned thread(s) "
                    f"{sorted(tids, key=str)} — arity must match the threads "
                    "that enter it or the program deadlocks",
                    sites=(site,), tids=tuple(sorted(tids, key=str))))

    for name, counts in run.acc_counts.items():
        per_tid = [counts.get(tid, 0) for tid in range(n_threads)]
        if len(set(per_tid)) > 1:
            findings.append(Finding(
                "lint", "ragged-accumulate", "error",
                f"accumulate({name!r}) call counts diverge across threads "
                f"({dict(enumerate(per_tid))}) — every round blocks for all "
                f"{n_threads} contributions, so the program strands mid-round",
                name=name, tids=tuple(range(n_threads))))
        shapes = run.acc_shapes.get(name, set())
        if len(shapes) > 1:
            findings.append(Finding(
                "lint", "ragged-accumulate", "error",
                f"accumulate({name!r}) contribution shapes diverge across "
                f"threads ({sorted(shapes)}) — a round would abort with the "
                "runtime ragged-contribution ValueError",
                name=name))

    for name, (size, k) in run.sparse.items():
        findings.extend(check_sparse_budget(name, size, k))

    for tid, err in run.trace_errors:
        findings.append(Finding(
            "lint", "lint-trace-error", "warning",
            f"thread proc raised under the lint dry run for tid {tid}: {err} "
            "— structural checks for this thread are incomplete",
            tids=(tid,)))
    return findings


def check_sparse_budget(name: str, size: int, k: int) -> List[Finding]:
    """Flag a top-k budget the blocked pair layout cannot actually ship."""
    from repro.core.sparse import pair_capacity

    try:
        cap = pair_capacity(size, k)
    except (ValueError, ZeroDivisionError):
        return []
    if k > cap:
        return [Finding(
            "lint", "sparse-overbudget", "warning",
            f"sparse budget k={k} for {name!r} (length {size}) exceeds "
            f"pair_capacity={cap} — the blocked top-k layout ships at most "
            f"{cap} pairs, so compression is silently lossier than asked",
            name=name)]
    return []
