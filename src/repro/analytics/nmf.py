"""NMF (paper §6.6) on the Session facade: R ≈ P·Q, globally shared Q.

Multiplicative updates (Lee–Seung).  With rows partitioned across threads,
P's update is thread-local; Q's update needs two global reductions —
numer = PᵀR (k×m) and gram = PᵀP (k×k) — which is precisely an accumulator
workload (the paper keeps the factorized matrices in DSM).  One
``thread_proc`` serves both the host and SPMD backends.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AccumMode, Session
from repro.core.session import SpmdBackend, deprecated_entry

_EPS = 1e-9


@jax.jit
def _update_p(p, q, r):
    """P ← P ⊙ (RQᵀ) / (PQQᵀ)."""
    return p * (r @ q.T) / (p @ (q @ q.T) + _EPS)


@jax.jit
def _q_partials(p, r):
    return p.T @ r, p.T @ p            # numer (k,m), gram (k,k)


def frob_loss(r, p, q) -> float:
    return float(np.linalg.norm(np.asarray(r) - np.asarray(p) @ np.asarray(q)) ** 2 / r.shape[0])


def fit_reference(r, k: int, iters: int = 10, seed: int = 0):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(np.abs(rng.normal(size=(r.shape[0], k))).astype(np.float32))
    q = jnp.asarray(np.abs(rng.normal(size=(k, r.shape[1]))).astype(np.float32))
    rj = jnp.asarray(r)
    for _ in range(iters):
        p = _update_p(p, q, rj)
        numer, gram = _q_partials(p, rj)
        q = q * numer / (gram @ q + _EPS)
    return np.asarray(p), np.asarray(q)


def fit(r, k: int, *, iters: int = 10, seed: int = 0,
        mode: Optional[AccumMode | str] = None,
        session: Optional[Session] = None, backend: str = "host",
        n_nodes: int = 2, threads_per_node: int = 2, mesh=None):
    """Lee–Seung updates through the Table-1 facade; backend-agnostic.

    Returns ``(p, q, session)``.
    """
    sess = session or Session(backend=backend, n_nodes=n_nodes,
                              threads_per_node=threads_per_node, mesh=mesh)
    rng = np.random.default_rng(seed)
    n, m = r.shape
    # same init stream as fit_reference (P then Q) so trajectories match exactly
    p_full0 = np.abs(rng.normal(size=(n, k))).astype(np.float32)
    q0 = np.abs(rng.normal(size=(k, m))).astype(np.float32)
    Q = sess.def_global("Q", jnp.asarray(q0))
    q_partials = sess.new_array("q_partials", (k * m + k * k,))

    def thread_proc(ctx, r_loc, p_loc):
        def step(p):                        # thread-local P rides in the carry
            with ctx.span("nmf.round"):
                q = Q.get()
                p = _update_p(p, q, r_loc)
                numer, gram = _q_partials(p, r_loc)
                flat = q_partials.accumulate(
                    jnp.concatenate([numer.reshape(-1), gram.reshape(-1)]), mode=mode)
                numer_g = flat[: k * m].reshape(k, m)
                gram_g = flat[k * m:].reshape(k, k)
                Q.set(q * numer_g / (gram_g @ q + _EPS))
            return p
        return ctx.iterate(step, p_loc, iters)

    ps = sess.run(thread_proc, data=(jnp.asarray(r), jnp.asarray(p_full0)))
    p_full = np.concatenate([np.asarray(p) for p in ps], axis=0)
    return p_full, np.asarray(Q.get()), sess


# ---------------------------------------------------------------------------
# Deprecated pre-Session entry points
# ---------------------------------------------------------------------------


def fit_threads(r, k: int, *, n_nodes: int = 2, threads_per_node: int = 2,
                iters: int = 10, seed: int = 0,
                mode: AccumMode | str = AccumMode.REDUCE_SCATTER,
                store=None):
    """Deprecated shim: ``fit(backend="host")`` with the old return tuple."""
    deprecated_entry("nmf.fit_threads", 'nmf.fit(backend="host")')
    sess = Session(backend="host", n_nodes=n_nodes,
                   threads_per_node=threads_per_node, store=store,
                   accum_mode=mode)
    p, q, sess = fit(r, k, iters=iters, seed=seed, mode=mode, session=sess)
    return p, q, sess.store, sess.accumulator("q_partials")


def fit_spmd(r, k: int, mesh, *, iters: int = 10, seed: int = 0,
             mode: AccumMode | str = AccumMode.REDUCE_SCATTER):
    """Deprecated shim: ``fit(backend="spmd")``."""
    deprecated_entry("nmf.fit_spmd", 'nmf.fit(backend="spmd")')
    sess = Session(backend=SpmdBackend(mesh=mesh))
    p, q, _ = fit(r, k, iters=iters, seed=seed, mode=mode, session=sess)
    return p, q
