"""NMF (paper §6.6): R ≈ P·Q with row-partitioned R/P and globally shared Q.

Multiplicative updates (Lee–Seung).  With rows partitioned across threads,
P's update is thread-local; Q's update needs two global reductions —
numer = PᵀR (k×m) and gram = PᵀP (k×k) — which is precisely a
DAddAccumulator workload (the paper keeps the factorized matrices in DSM).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AccumMode, DAddAccumulator, GlobalStore, accumulate
from repro.core.threads import DThreadPool
from repro.data.pipeline import partition_rows

_EPS = 1e-9


@jax.jit
def _update_p(p, q, r):
    """P ← P ⊙ (RQᵀ) / (PQQᵀ)."""
    return p * (r @ q.T) / (p @ (q @ q.T) + _EPS)


@jax.jit
def _q_partials(p, r):
    return p.T @ r, p.T @ p            # numer (k,m), gram (k,k)


def frob_loss(r, p, q) -> float:
    return float(np.linalg.norm(np.asarray(r) - np.asarray(p) @ np.asarray(q)) ** 2 / r.shape[0])


def fit_reference(r, k: int, iters: int = 10, seed: int = 0):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(np.abs(rng.normal(size=(r.shape[0], k))).astype(np.float32))
    q = jnp.asarray(np.abs(rng.normal(size=(k, r.shape[1]))).astype(np.float32))
    rj = jnp.asarray(r)
    for _ in range(iters):
        p = _update_p(p, q, rj)
        numer, gram = _q_partials(p, rj)
        q = q * numer / (gram @ q + _EPS)
    return np.asarray(p), np.asarray(q)


def fit_threads(r, k: int, *, n_nodes: int = 2, threads_per_node: int = 2,
                iters: int = 10, seed: int = 0,
                mode: AccumMode | str = AccumMode.REDUCE_SCATTER,
                store=None):
    store = store or GlobalStore()
    rng = np.random.default_rng(seed)
    n, m = r.shape
    # same init stream as fit_reference (P then Q) so trajectories match exactly
    p_full0 = np.abs(rng.normal(size=(n, k))).astype(np.float32)
    q0 = np.abs(rng.normal(size=(k, m))).astype(np.float32)
    store.def_global("Q", jnp.asarray(q0))
    store.new_array("q_partials", (k * m + k * k,))
    pool = DThreadPool(n_nodes, threads_per_node)
    accu = DAddAccumulator(store, "q_partials", pool.n_threads, n_nodes, mode)
    rj = jnp.asarray(r)
    results = {}

    def slave_proc(tid, _param):
        lo, hi = partition_rows(n, tid, pool.n_threads)
        r_loc = rj[lo:hi]
        p_loc = jnp.asarray(p_full0[lo:hi])
        for _ in range(iters):
            pool.checkpoint_guard(tid)
            q = store.get("Q")
            p_loc = _update_p(p_loc, q, r_loc)
            numer, gram = _q_partials(p_loc, r_loc)
            accu.accumulate(jnp.concatenate([numer.reshape(-1), gram.reshape(-1)]))
            if tid == 0:
                flat = store.get("q_partials")
                numer_g = flat[: k * m].reshape(k, m)
                gram_g = flat[k * m:].reshape(k, k)
                store.set("Q", q * numer_g / (gram_g @ q + _EPS))
            accu._barrier.wait()
        results[tid] = p_loc
        return p_loc

    pool.create_threads(slave_proc)
    pool.start_all()
    pool.join_all()
    p_full = np.concatenate([np.asarray(results[t]) for t in sorted(results)], axis=0)
    return p_full, np.asarray(store.get("Q")), store, accu


def fit_spmd(r, k: int, mesh, *, iters: int = 10, seed: int = 0,
             mode: AccumMode | str = AccumMode.REDUCE_SCATTER):
    from jax.sharding import PartitionSpec as P

    rng = np.random.default_rng(seed)
    n, m = r.shape
    n_threads = mesh.shape["data"]
    per = n // n_threads
    rj = jnp.asarray(r[: per * n_threads])
    # same init stream as fit_reference (P then Q)
    p0 = jnp.asarray(np.abs(rng.normal(size=(n, k))).astype(np.float32)[: per * n_threads])
    q0 = jnp.asarray(np.abs(rng.normal(size=(k, m))).astype(np.float32))

    def thread_proc(r_loc, p_loc, q0r):
        def body(carry, _):
            p, q = carry
            p = _update_p(p, q, r_loc)
            numer, gram = _q_partials(p, r_loc)
            flat = accumulate(jnp.concatenate([numer.reshape(-1), gram.reshape(-1)]),
                              "data", mode)
            numer_g = flat[: k * m].reshape(k, m)
            gram_g = flat[k * m:].reshape(k, k)
            return (p, q * numer_g / (gram_g @ q + _EPS)), None

        (p, q), _ = jax.lax.scan(body, (p_loc, q0r[0]), None, length=iters)
        return p, q[None]

    f = jax.jit(jax.shard_map(
        thread_proc, mesh=mesh,
        in_specs=(P("data", None), P("data", None), P(None, None, None)),
        out_specs=(P("data", None), P("data", None, None)), check_vma=False))
    p, q = f(rj, p0, q0[None])
    return np.asarray(p), np.asarray(q[0])
