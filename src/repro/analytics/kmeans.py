"""K-means (paper §6.5): Lloyd iterations over partitioned points.

Per iteration, each thread assigns its points to the nearest center (the
``kmeans_assign`` Pallas kernel is the TPU hot loop), builds per-cluster
partial sums + counts, and ships them through the accumulator — the shared
centers in DSM are then ``sum / count``.  Exactly the Petuum/paper algorithm,
with the accumulator replacing the parameter server.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AccumMode, DAddAccumulator, GlobalStore, accumulate
from repro.core.threads import DThreadPool
from repro.data.pipeline import partition_rows


@jax.jit
def _assign(points, centers):
    d2 = (jnp.sum(points**2, axis=1, keepdims=True)
          - 2.0 * points @ centers.T + jnp.sum(centers**2, axis=1)[None])
    return jnp.argmin(d2, axis=1), jnp.min(d2, axis=1)


def _partials(points, assign, k):
    onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)      # (n, k)
    sums = onehot.T @ points                                    # (k, d)
    counts = jnp.sum(onehot, axis=0)                            # (k,)
    return sums, counts


def inertia(points, centers) -> float:
    _, d = _assign(jnp.asarray(points), jnp.asarray(centers))
    return float(jnp.sum(d))


def fit_reference(x, k: int, iters: int = 10, seed: int = 0):
    rng = np.random.default_rng(seed)
    centers = jnp.asarray(x[rng.choice(x.shape[0], k, replace=False)])
    xj = jnp.asarray(x)
    for _ in range(iters):
        a, _ = _assign(xj, centers)
        sums, counts = _partials(xj, a, k)
        centers = sums / jnp.maximum(counts[:, None], 1.0)
    return np.asarray(centers)


def fit_threads(x, k: int, *, n_nodes: int = 2, threads_per_node: int = 2,
                iters: int = 10, seed: int = 0,
                mode: AccumMode | str = AccumMode.REDUCE_SCATTER,
                use_kernel: bool = False):
    """Paper programming model: threads + DSM centers + accumulator."""
    store = GlobalStore()
    rng = np.random.default_rng(seed)
    d = x.shape[1]
    init_centers = x[rng.choice(x.shape[0], k, replace=False)]
    store.def_global("centers", jnp.asarray(init_centers))
    store.new_array("partials", (k * (d + 1),))
    pool = DThreadPool(n_nodes, threads_per_node)
    accu = DAddAccumulator(store, "partials", pool.n_threads, n_nodes, mode)
    xj = jnp.asarray(x)

    def slave_proc(tid, _param):
        lo, hi = partition_rows(x.shape[0], tid, pool.n_threads)
        pts = xj[lo:hi]
        for _ in range(iters):
            pool.checkpoint_guard(tid)
            centers = store.get("centers")
            if use_kernel:
                from repro.kernels.kmeans_assign.ops import kmeans_assign
                a, _dist = kmeans_assign(pts, centers)
            else:
                a, _dist = _assign(pts, centers)
            sums, counts = _partials(pts, a, k)
            accu.accumulate(jnp.concatenate([sums.reshape(-1), counts]))
            if tid == 0:  # one thread applies the center update (§4.5 pattern)
                flat = store.get("partials")
                sums_g = flat[: k * d].reshape(k, d)
                counts_g = flat[k * d:]
                store.set("centers", sums_g / jnp.maximum(counts_g[:, None], 1.0))
            accu._barrier.wait()  # everyone sees the new centers next iter
        return True

    pool.create_threads(slave_proc)
    pool.start_all()
    pool.join_all()
    return np.asarray(store.get("centers")), store, accu


def fit_spmd(x, k: int, mesh, *, iters: int = 10, seed: int = 0,
             mode: AccumMode | str = AccumMode.REDUCE_SCATTER):
    from jax.sharding import PartitionSpec as P

    rng = np.random.default_rng(seed)
    init_centers = jnp.asarray(x[rng.choice(x.shape[0], k, replace=False)])
    n_threads = mesh.shape["data"]
    per = x.shape[0] // n_threads
    xj = jnp.asarray(x[: per * n_threads])
    d = x.shape[1]

    def thread_proc(pts, centers0):
        def body(centers, _):
            a, _dist = _assign(pts, centers)
            sums, counts = _partials(pts, a, k)
            flat = accumulate(jnp.concatenate([sums.reshape(-1), counts]), "data", mode)
            sums_g = flat[: k * d].reshape(k, d)
            counts_g = flat[k * d:]
            return sums_g / jnp.maximum(counts_g[:, None], 1.0), None

        centers, _ = jax.lax.scan(body, centers0[0], None, length=iters)
        return centers[None]

    f = jax.jit(jax.shard_map(
        thread_proc, mesh=mesh,
        in_specs=(P("data", None), P(None, None, None)),
        out_specs=P("data", None, None), check_vma=False))
    reps = f(xj, init_centers[None])
    return np.asarray(reps[0])
