"""K-means (paper §6.5) on the Session facade: Lloyd iterations, shared centers.

Per iteration, each thread assigns its points to the nearest center (the
``kmeans_assign`` Pallas kernel is the TPU hot loop), builds per-cluster
partial sums + counts, and ships them through the accumulator — the shared
centers in DSM are then ``sum / count``.  One ``thread_proc`` serves both the
host backend (DThreadPool + DAddAccumulator, the paper's programming model)
and the SPMD backend (shard_map over a mesh, the production path).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AccumMode, Session
from repro.core.session import SpmdBackend, deprecated_entry


@jax.jit
def _assign(points, centers):
    d2 = (jnp.sum(points**2, axis=1, keepdims=True)
          - 2.0 * points @ centers.T + jnp.sum(centers**2, axis=1)[None])
    return jnp.argmin(d2, axis=1), jnp.min(d2, axis=1)


def _partials(points, assign, k):
    onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)      # (n, k)
    sums = onehot.T @ points                                    # (k, d)
    counts = jnp.sum(onehot, axis=0)                            # (k,)
    return sums, counts


def inertia(points, centers) -> float:
    _, d = _assign(jnp.asarray(points), jnp.asarray(centers))
    return float(jnp.sum(d))


def fit_reference(x, k: int, iters: int = 10, seed: int = 0):
    rng = np.random.default_rng(seed)
    centers = jnp.asarray(x[rng.choice(x.shape[0], k, replace=False)])
    xj = jnp.asarray(x)
    for _ in range(iters):
        a, _ = _assign(xj, centers)
        sums, counts = _partials(xj, a, k)
        centers = sums / jnp.maximum(counts[:, None], 1.0)
    return np.asarray(centers)


def fit(x, k: int, *, iters: int = 10, seed: int = 0,
        mode: Optional[AccumMode | str] = None, use_kernel: bool = False,
        session: Optional[Session] = None, backend: str = "host",
        n_nodes: int = 2, threads_per_node: int = 2, mesh=None):
    """Lloyd iterations through the Table-1 facade; backend-agnostic.

    Returns ``(centers, session)``.
    """
    sess = session or Session(backend=backend, n_nodes=n_nodes,
                              threads_per_node=threads_per_node, mesh=mesh)
    rng = np.random.default_rng(seed)
    d = x.shape[1]
    centers = sess.def_global(
        "centers", jnp.asarray(x[rng.choice(x.shape[0], k, replace=False)]))
    partials = sess.new_array("partials", (k * (d + 1),))

    if use_kernel:
        from repro.kernels.kmeans_assign.ops import kmeans_assign as assign_fn
    else:
        assign_fn = _assign

    def thread_proc(ctx, pts):
        def step(_):                       # the shared centers carry the state
            with ctx.span("kmeans.round"):
                a, _dist = assign_fn(pts, centers.get())
                sums, counts = _partials(pts, a, k)
                flat = partials.accumulate(
                    jnp.concatenate([sums.reshape(-1), counts]), mode=mode)
                sums_g = flat[: k * d].reshape(k, d)
                counts_g = flat[k * d:]
                # §4.5 pattern: every thread re-derives the identical center update
                centers.set(sums_g / jnp.maximum(counts_g[:, None], 1.0))
            return _
        ctx.iterate(step, None, iters)
        return None

    sess.run(thread_proc, data=(jnp.asarray(x),))
    return np.asarray(centers.get()), sess


# ---------------------------------------------------------------------------
# Deprecated pre-Session entry points
# ---------------------------------------------------------------------------


def fit_threads(x, k: int, *, n_nodes: int = 2, threads_per_node: int = 2,
                iters: int = 10, seed: int = 0,
                mode: AccumMode | str = AccumMode.REDUCE_SCATTER,
                use_kernel: bool = False):
    """Deprecated shim: ``fit(backend="host")`` with the old return tuple."""
    deprecated_entry("kmeans.fit_threads", 'kmeans.fit(backend="host")')
    sess = Session(backend="host", n_nodes=n_nodes,
                   threads_per_node=threads_per_node, accum_mode=mode)
    centers, sess = fit(x, k, iters=iters, seed=seed, mode=mode,
                        use_kernel=use_kernel, session=sess)
    return centers, sess.store, sess.accumulator("partials")


def fit_spmd(x, k: int, mesh, *, iters: int = 10, seed: int = 0,
             mode: AccumMode | str = AccumMode.REDUCE_SCATTER):
    """Deprecated shim: ``fit(backend="spmd")``."""
    deprecated_entry("kmeans.fit_spmd", 'kmeans.fit(backend="spmd")')
    sess = Session(backend=SpmdBackend(mesh=mesh))
    centers, _ = fit(x, k, iters=iters, seed=seed, mode=mode, session=sess)
    return centers
