"""The paper's four applications (logreg / kmeans / nmf / pagerank), each in
three forms: fit_reference (single-thread oracle), fit_threads (the paper's
Pthreads-style DThread + DSM + accumulator programming model), and fit_spmd
(shard_map production path)."""

from repro.analytics import kmeans, logreg, nmf, pagerank

__all__ = ["kmeans", "logreg", "nmf", "pagerank"]
