"""The paper's four applications (logreg / kmeans / nmf / pagerank).

Each exposes ``fit_reference`` (single-thread oracle) and ``fit`` — one
backend-agnostic ``thread_proc`` over the `step.Session` facade that runs on
either substrate: ``backend="host"`` (the paper's Pthreads-style DThread +
DSM + accumulator programming model) or ``backend="spmd"`` (one STEP thread
per mesh position via shard_map, the production path).  The pre-Session
entry points ``fit_threads`` / ``fit_spmd`` remain as deprecation shims."""

from repro.analytics import kmeans, logreg, nmf, pagerank

__all__ = ["kmeans", "logreg", "nmf", "pagerank"]
