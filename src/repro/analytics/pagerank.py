"""PageRank (paper §6.7): edge-partitioned credit accumulation.

Each thread owns a slice of the edge list; per iteration it computes the
credit vector its sources send along their out-edges and accumulates it
(the paper: "communication cost is proportional to the number of vertices",
because the accumulator ships V-length vectors, not per-edge messages as
Husky does).  The accumulator's ``sparse``/``auto`` modes engage when the
per-thread credit vector is sparse — graphs with concentrated out-degrees.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AccumMode, DAddAccumulator, GlobalStore, accumulate
from repro.core.threads import DThreadPool

DAMPING = 0.85


def _credits(src, dst, ranks, out_deg, n_vertices):
    """Credit vector contributed by this thread's edges."""
    w = ranks[src] / out_deg[src]
    return jnp.zeros((n_vertices,), jnp.float32).at[dst].add(w)


def fit_reference(edges, n_vertices: int, iters: int = 10):
    src, dst = jnp.asarray(edges[:, 0]), jnp.asarray(edges[:, 1])
    out_deg = jnp.maximum(jnp.zeros(n_vertices).at[src].add(1.0), 1.0)
    ranks = jnp.full((n_vertices,), 1.0 / n_vertices)
    for _ in range(iters):
        credits = _credits(src, dst, ranks, out_deg, n_vertices)
        ranks = (1 - DAMPING) / n_vertices + DAMPING * credits
    return np.asarray(ranks)


def fit_threads(edges, n_vertices: int, *, n_nodes: int = 2, threads_per_node: int = 2,
                iters: int = 10, mode: AccumMode | str = AccumMode.AUTO):
    store = GlobalStore()
    src_all, dst_all = jnp.asarray(edges[:, 0]), jnp.asarray(edges[:, 1])
    out_deg = jnp.maximum(jnp.zeros(n_vertices).at[src_all].add(1.0), 1.0)
    store.def_global("ranks", jnp.full((n_vertices,), 1.0 / n_vertices))
    store.new_array("credits", (n_vertices,))
    pool = DThreadPool(n_nodes, threads_per_node)
    accu = DAddAccumulator(store, "credits", pool.n_threads, n_nodes, mode)
    n_edges = edges.shape[0]
    per = n_edges // pool.n_threads

    def slave_proc(tid, _param):
        lo = tid * per
        hi = n_edges if tid == pool.n_threads - 1 else lo + per
        src, dst = src_all[lo:hi], dst_all[lo:hi]
        for _ in range(iters):
            pool.checkpoint_guard(tid)
            ranks = store.get("ranks")
            accu.accumulate(_credits(src, dst, ranks, out_deg, n_vertices))
            if tid == 0:
                credits = store.get("credits")
                store.set("ranks", (1 - DAMPING) / n_vertices + DAMPING * credits)
            accu._barrier.wait()
        return True

    pool.create_threads(slave_proc)
    pool.start_all()
    pool.join_all()
    return np.asarray(store.get("ranks")), store, accu


def fit_spmd(edges, n_vertices: int, mesh, *, iters: int = 10,
             mode: AccumMode | str = AccumMode.REDUCE_SCATTER, k: int = 0):
    from jax.sharding import PartitionSpec as P

    n_threads = mesh.shape["data"]
    per = edges.shape[0] // n_threads
    e = jnp.asarray(edges[: per * n_threads])
    src_all, dst_all = e[:, 0], e[:, 1]
    out_deg = jnp.maximum(jnp.zeros(n_vertices).at[src_all].add(1.0), 1.0)

    def thread_proc(edges_loc, deg):
        src, dst = edges_loc[:, 0], edges_loc[:, 1]

        def body(ranks, _):
            credits = accumulate(_credits(src, dst, ranks, deg, n_vertices),
                                 "data", mode, k=k or None)
            return (1 - DAMPING) / n_vertices + DAMPING * credits, None

        ranks, _ = jax.lax.scan(body, jnp.full((n_vertices,), 1.0 / n_vertices),
                                None, length=iters)
        return ranks[None]

    f = jax.jit(jax.shard_map(
        thread_proc, mesh=mesh,
        in_specs=(P("data", None), P(None)),
        out_specs=P("data", None), check_vma=False))
    ranks = f(e, out_deg)
    return np.asarray(ranks[0])
