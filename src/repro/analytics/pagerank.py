"""PageRank (paper §6.7) on the Session facade: edge-partitioned credits.

Each thread owns a slice of the edge list; per iteration it computes the
credit vector its sources send along their out-edges and accumulates it
(the paper: "communication cost is proportional to the number of vertices",
because the accumulator ships V-length vectors, not per-edge messages as
Husky does).  The accumulator's ``sparse``/``auto`` modes engage when the
per-thread credit vector is sparse — graphs with concentrated out-degrees.
One ``thread_proc`` serves both the host and SPMD backends; the out-degree
vector rides along replicated (``broadcast=``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AccumMode, Session
from repro.core.session import SpmdBackend, deprecated_entry

DAMPING = 0.85


def _credits(src, dst, ranks, out_deg, n_vertices):
    """Credit vector contributed by this thread's edges."""
    w = ranks[src] / out_deg[src]
    return jnp.zeros((n_vertices,), jnp.float32).at[dst].add(w)


def fit_reference(edges, n_vertices: int, iters: int = 10):
    src, dst = jnp.asarray(edges[:, 0]), jnp.asarray(edges[:, 1])
    out_deg = jnp.maximum(jnp.zeros(n_vertices).at[src].add(1.0), 1.0)
    ranks = jnp.full((n_vertices,), 1.0 / n_vertices)
    for _ in range(iters):
        credits = _credits(src, dst, ranks, out_deg, n_vertices)
        ranks = (1 - DAMPING) / n_vertices + DAMPING * credits
    return np.asarray(ranks)


def fit(edges, n_vertices: int, *, iters: int = 10,
        mode: Optional[AccumMode | str] = AccumMode.AUTO, k: Optional[int] = None,
        session: Optional[Session] = None, backend: str = "host",
        n_nodes: int = 2, threads_per_node: int = 2, mesh=None):
    """Credit accumulation through the Table-1 facade; backend-agnostic.

    ``mode="auto"`` ships (index, value) pairs only on rounds where every
    thread's credit vector compresses losslessly under the budget ``k``
    (default ~V/4) — identical results either way, cheaper wire format when
    out-degrees concentrate.  ``k`` becomes the credits ref's declared budget.
    Returns ``(ranks, session)``.
    """
    sess = session or Session(backend=backend, n_nodes=n_nodes,
                              threads_per_node=threads_per_node, mesh=mesh)
    src_all, dst_all = jnp.asarray(edges[:, 0]), jnp.asarray(edges[:, 1])
    out_deg = jnp.maximum(jnp.zeros(n_vertices).at[src_all].add(1.0), 1.0)
    ranks = sess.def_global("ranks", jnp.full((n_vertices,), 1.0 / n_vertices))
    credits = sess.new_array("credits", (n_vertices,), sparse_k=k)

    def thread_proc(ctx, edges_loc, deg):
        src, dst = edges_loc[:, 0], edges_loc[:, 1]

        def step(_):                       # the shared ranks carry the state
            with ctx.span("pagerank.round"):
                total = credits.accumulate(
                    _credits(src, dst, ranks.get(), deg, n_vertices), mode=mode)
                ranks.set((1 - DAMPING) / n_vertices + DAMPING * total)
            return _
        ctx.iterate(step, None, iters)
        return None

    sess.run(thread_proc, data=(jnp.asarray(edges),), broadcast=(out_deg,))
    return np.asarray(ranks.get()), sess


# ---------------------------------------------------------------------------
# Deprecated pre-Session entry points
# ---------------------------------------------------------------------------


def fit_threads(edges, n_vertices: int, *, n_nodes: int = 2,
                threads_per_node: int = 2, iters: int = 10,
                mode: AccumMode | str = AccumMode.AUTO):
    """Deprecated shim: ``fit(backend="host")`` with the old return tuple."""
    deprecated_entry("pagerank.fit_threads", 'pagerank.fit(backend="host")')
    sess = Session(backend="host", n_nodes=n_nodes,
                   threads_per_node=threads_per_node, accum_mode=mode)
    ranks, sess = fit(edges, n_vertices, iters=iters, mode=mode, session=sess)
    return ranks, sess.store, sess.accumulator("credits")


def fit_spmd(edges, n_vertices: int, mesh, *, iters: int = 10,
             mode: AccumMode | str = AccumMode.REDUCE_SCATTER, k: int = 0):
    """Deprecated shim: ``fit(backend="spmd")``."""
    deprecated_entry("pagerank.fit_spmd", 'pagerank.fit(backend="spmd")')
    sess = Session(backend=SpmdBackend(mesh=mesh))
    ranks, _ = fit(edges, n_vertices, iters=iters, mode=mode, k=k or None,
                   session=sess)
    return ranks
