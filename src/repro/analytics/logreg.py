"""Logistic regression — the paper's worked example (§4.5), both execution modes.

``fit_threads`` is a line-by-line port of the paper's ``slave_proc``: every
working thread keeps a local ``theta``, computes the gradient over its
partition (``LoadTrainPoint``), pushes it through the shared
``DAddAccumulator`` (a synchronisation point), and applies the accumulated
global gradient from DSM.  ``fit_spmd`` is the same program as one STEP thread
per mesh position via ``shard_map`` — the production path.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AccumMode, DAddAccumulator, GlobalStore, accumulate
from repro.core.threads import DThreadPool
from repro.data.pipeline import partition_rows


def _sigmoid(z):
    return 1.0 / (1.0 + jnp.exp(-z))


@jax.jit
def _local_grad(theta, x, y):
    """δ = Σ_p (y_p − σ(θᵀx_p))·x_p over this thread's mini-batch."""
    pred = _sigmoid(x @ theta)
    return (y - pred) @ x


def loss(theta, x, y):
    p = np.clip(np.asarray(_sigmoid(jnp.asarray(x) @ theta)), 1e-7, 1 - 1e-7)
    return float(-np.mean(y * np.log(p) + (1 - y) * np.log(1 - p)))


def fit_reference(x, y, iters: int = 10, lr: float = 1e-3):
    """Single-thread oracle (same algorithm, no distribution)."""
    theta = jnp.zeros((x.shape[1],), jnp.float32)
    for _ in range(iters):
        theta = theta + lr * _local_grad(theta, jnp.asarray(x), jnp.asarray(y))
    return np.asarray(theta)


def fit_threads(x, y, *, n_nodes: int = 2, threads_per_node: int = 2,
                iters: int = 10, lr: float = 1e-3,
                mode: AccumMode | str = AccumMode.REDUCE_SCATTER,
                store: Optional[GlobalStore] = None):
    """Paper §4.5 programming model on the host thread pool."""
    store = store or GlobalStore()
    d = x.shape[1]
    store.def_global("param_len", d)
    store.new_array("grad", (d,))
    pool = DThreadPool(n_nodes, threads_per_node)
    accu = DAddAccumulator(store, "grad", pool.n_threads, n_nodes, mode)
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    def slave_proc(tid, _param):
        theta = jnp.zeros((d,), jnp.float32)          # local copy (paper line 10)
        lo, hi = partition_rows(x.shape[0], tid, pool.n_threads)  # LoadTrainPoint
        xs, ys = xj[lo:hi], yj[lo:hi]
        for _ in range(iters):
            pool.checkpoint_guard(tid)
            local_grad = _local_grad(theta, xs, ys)   # lines 14–21
            accu.accumulate(local_grad)               # line 22 (sync point)
            theta = theta + lr * store.get("grad")    # lines 23–24
        return theta

    pool.create_threads(slave_proc)
    pool.start_all()
    pool.join_all()
    thetas = [t.result for t in pool.threads]
    return np.asarray(thetas[0]), store, accu


def fit_spmd(x, y, mesh, *, iters: int = 10, lr: float = 1e-3,
             mode: AccumMode | str = AccumMode.REDUCE_SCATTER, k: int = 0):
    """One STEP thread per mesh position (shard_map) — the production path."""
    from jax.sharding import PartitionSpec as P

    n = x.shape[0]
    n_threads = mesh.shape["data"]
    per = n // n_threads
    x = jnp.asarray(x[: per * n_threads])
    y = jnp.asarray(y[: per * n_threads])
    d = x.shape[1]

    def thread_proc(xs, ys):
        theta = jnp.zeros((d,), jnp.float32)

        def body(theta, _):
            g = _local_grad(theta, xs, ys)
            g = accumulate(g, "data", mode, k=k or None)
            return theta + lr * g, None

        theta, _ = jax.lax.scan(body, theta, None, length=iters)
        return theta[None]

    f = jax.jit(jax.shard_map(
        thread_proc, mesh=mesh,
        in_specs=(P("data", None), P("data")),
        out_specs=P("data", None), check_vma=False))
    thetas = f(x, y)
    return np.asarray(thetas[0])


def fit_ssp(x, y, *, n_workers: int = 4, staleness: int = 1, iters: int = 10,
            lr: float = 1e-3):
    """Asynchronous SGD under Stale Synchronous Parallel (paper §7 / Petuum).

    Workers update the shared theta in DSM without a barrier; the SSP clock
    only blocks a worker that runs more than `staleness` iterations ahead of
    the slowest — the paper's straggler-mitigation mode.  With staleness=0
    this degenerates to fully synchronous (barrier-per-iteration) execution.
    """
    import threading

    from repro.core import GlobalStore, SSPClock

    store = GlobalStore()
    d = x.shape[1]
    store.def_global("theta", jnp.zeros((d,), jnp.float32))
    clock = SSPClock(n_workers, staleness=staleness)
    lock = threading.Lock()
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    def worker(tid):
        lo, hi = partition_rows(x.shape[0], tid, n_workers)
        xs, ys = xj[lo:hi], yj[lo:hi]
        for _ in range(iters):
            theta = store.get("theta")             # possibly stale replica
            g = _local_grad(theta, xs, ys)
            with lock:                             # atomic DSM update
                store.set("theta", store.get("theta") + lr * g, bump_epoch=True)
            clock.tick(tid)
            clock.wait(tid)                        # bounded staleness

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(n_workers)]
    [t.start() for t in ts]
    [t.join(60) for t in ts]
    return np.asarray(store.get("theta")), clock
