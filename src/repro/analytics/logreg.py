"""Logistic regression — the paper's worked example (§4.5) on the Session facade.

``fit`` is a line-by-line port of the paper's ``slave_proc``: every working
thread keeps a local ``theta``, computes the gradient over its partition
(``LoadTrainPoint``), pushes it through the shared accumulator (a
synchronisation point), and applies the accumulated global gradient from DSM.
The *same* ``thread_proc`` runs on either substrate — ``backend="host"``
(DThreadPool + DAddAccumulator) or ``backend="spmd"`` (one STEP thread per
mesh position via shard_map) — selected at ``Session`` construction.

``fit_threads`` / ``fit_spmd`` remain as deprecation shims over ``fit``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AccumMode, Session
from repro.core.dsm import GlobalStore
from repro.core.session import SpmdBackend, deprecated_entry


def _sigmoid(z):
    return 1.0 / (1.0 + jnp.exp(-z))


@jax.jit
def _local_grad(theta, x, y):
    """δ = Σ_p (y_p − σ(θᵀx_p))·x_p over this thread's mini-batch."""
    pred = _sigmoid(x @ theta)
    return (y - pred) @ x


def loss(theta, x, y):
    p = np.clip(np.asarray(_sigmoid(jnp.asarray(x) @ theta)), 1e-7, 1 - 1e-7)
    return float(-np.mean(y * np.log(p) + (1 - y) * np.log(1 - p)))


def fit_reference(x, y, iters: int = 10, lr: float = 1e-3):
    """Single-thread oracle (same algorithm, no distribution)."""
    theta = jnp.zeros((x.shape[1],), jnp.float32)
    for _ in range(iters):
        theta = theta + lr * _local_grad(theta, jnp.asarray(x), jnp.asarray(y))
    return np.asarray(theta)


def fit(x, y, *, iters: int = 10, lr: float = 1e-3,
        mode: Optional[AccumMode | str] = None, k: Optional[int] = None,
        session: Optional[Session] = None, backend: str = "host",
        n_nodes: int = 2, threads_per_node: int = 2, mesh=None):
    """Paper §4.5 through the Table-1 facade; backend-agnostic.

    ``mode="sparse"``/``"auto"`` compress the gradient to top-``k`` (index,
    value) pairs through the shared Pallas dispatch — ``k`` becomes the grad
    ref's declared budget (``new_array(..., sparse_k=k)``), so per-round calls
    need no explicit ``k``.  Returns ``(theta, session)`` — the session
    exposes the store, cache and accumulator traffic for inspection.
    """
    sess = session or Session(backend=backend, n_nodes=n_nodes,
                              threads_per_node=threads_per_node, mesh=mesh)
    d = x.shape[1]
    grad = sess.new_array("grad", (d,), sparse_k=k)

    def thread_proc(ctx, xs, ys):
        def step(theta):                              # one synchronous round
            with ctx.span("logreg.round"):            # app-round marker (host)
                local = _local_grad(theta, xs, ys)        # lines 14–21
                total = grad.accumulate(local, mode=mode)  # line 22 (sync point)
                return theta + lr * total             # lines 23–24
        # local theta (paper line 10) is the carry; host: guarded loop,
        # SPMD: one lax.scan — O(1) lowered program size in `iters`.
        return ctx.iterate(step, jnp.zeros((d,), jnp.float32), iters)

    thetas = sess.run(thread_proc, data=(jnp.asarray(x), jnp.asarray(y)))
    return np.asarray(thetas[0]), sess


def fit_ssp(x, y, *, n_workers: int = 4, staleness: int = 1, iters: int = 10,
            lr: float = 1e-3):
    """Asynchronous SGD under Stale Synchronous Parallel (paper §7 / Petuum).

    Workers update the shared theta in DSM without a barrier — ``theta.inc``
    is the atomic Table-1 increment — and the SSP clock only blocks a worker
    that runs more than ``staleness`` iterations ahead of the slowest.  With
    ``staleness=0`` this degenerates to fully synchronous execution.
    """
    sess = Session(backend="host", n_nodes=n_workers, threads_per_node=1)
    d = x.shape[1]
    theta = sess.def_global("theta", jnp.zeros((d,), jnp.float32))
    clock = sess.ssp_clock(staleness)

    def worker(ctx, xs, ys):
        def step(_):
            with ctx.span("logreg.ssp_round"):
                g = _local_grad(theta.get(), xs, ys)   # possibly stale replica
                theta.inc(lr * g)                      # atomic DSM update
                clock.tick(ctx.tid)
                clock.wait(ctx.tid)                    # bounded staleness
            return _
        ctx.iterate(step, None, iters)             # host-only: clock is a
                                                   # Python-side effect

    sess.run(worker, data=(jnp.asarray(x), jnp.asarray(y)), timeout=60)
    return np.asarray(theta.get()), clock


# ---------------------------------------------------------------------------
# Deprecated pre-Session entry points
# ---------------------------------------------------------------------------


def fit_threads(x, y, *, n_nodes: int = 2, threads_per_node: int = 2,
                iters: int = 10, lr: float = 1e-3,
                mode: AccumMode | str = AccumMode.REDUCE_SCATTER,
                store: Optional[GlobalStore] = None):
    """Deprecated shim: ``fit(backend="host")`` with the old return tuple."""
    deprecated_entry("logreg.fit_threads", 'logreg.fit(backend="host")')
    sess = Session(backend="host", n_nodes=n_nodes,
                   threads_per_node=threads_per_node, store=store,
                   accum_mode=mode)
    theta, sess = fit(x, y, iters=iters, lr=lr, mode=mode, session=sess)
    return theta, sess.store, sess.accumulator("grad")


def fit_spmd(x, y, mesh, *, iters: int = 10, lr: float = 1e-3,
             mode: AccumMode | str = AccumMode.REDUCE_SCATTER, k: int = 0):
    """Deprecated shim: ``fit(backend="spmd")``."""
    deprecated_entry("logreg.fit_spmd", 'logreg.fit(backend="spmd")')
    sess = Session(backend=SpmdBackend(mesh=mesh))
    theta, _ = fit(x, y, iters=iters, lr=lr, mode=mode, k=k or None, session=sess)
    return theta
