"""Gradient compression — the accumulator's sparse/auto modes for training.

STEP §5.2 transfers sparse vectors as (index, value) pairs when beneficial.
For gradients (dense but compressible) the production analogue is top-k
sparsification with **error feedback** (the residual is carried to the next
step so the update remains unbiased in the limit), wrapped around the
accumulator.  ``auto`` keeps the paper's rule — compress only when the wire
cost of pairs beats the dense vector.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.accumulator import AccumMode, accumulate
from repro.core.sparse import blocked_topk_sparsify, densify


class EFState(NamedTuple):
    """Error-feedback residual, same structure as the (packed) gradient."""

    residual: jax.Array


def ef_init(flat_len: int) -> EFState:
    return EFState(jnp.zeros((flat_len,), jnp.float32))


def compressed_accumulate(flat_grad: jax.Array, ef: EFState, axis, k: int,
                          mode: AccumMode | str = AccumMode.SPARSE):
    """Top-k + error feedback around the accumulator.

    Returns (global_sum_of_compressed, new_ef).  Inside shard_map.
    """
    mode = AccumMode(mode)
    corrected = flat_grad.astype(jnp.float32) + ef.residual
    idx, vals = blocked_topk_sparsify(corrected, k)
    sent = densify(idx, vals, corrected.shape[0])
    new_residual = corrected - sent
    if mode == AccumMode.SPARSE:
        total = accumulate(sent, axis, AccumMode.SPARSE, k=k)
    else:
        total = accumulate(sent, axis, mode, k=k)
    return total, EFState(new_residual)


def compression_ratio(flat_len: int, k: int) -> float:
    """Wire-bytes ratio of the pairs representation vs dense (paper's rule)."""
    return (2.0 * k) / float(flat_len)
