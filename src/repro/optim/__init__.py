from repro.optim.compression import EFState, compressed_accumulate, compression_ratio, ef_init
from repro.optim.optimizers import (
    AdamState,
    Optimizer,
    adam,
    adamw,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    sgd,
    warmup_cosine,
)
from repro.optim.zero import Zero1State, zero1_gather_params, zero1_init, zero1_update

__all__ = [
    "EFState", "compressed_accumulate", "compression_ratio", "ef_init",
    "AdamState", "Optimizer", "adam", "adamw", "apply_updates",
    "clip_by_global_norm", "global_norm", "sgd", "warmup_cosine",
    "Zero1State", "zero1_gather_params", "zero1_init", "zero1_update",
]
