"""ZeRO-1: the paper's accumulator as a sharded optimizer (DESIGN.md §3).

STEP §5.2: chunk *i* of every thread's gradient goes to node *i*, which reduces
locally and updates the output shared array.  Node *i* is therefore the *owner*
of chunk *i* — and if the optimizer state for chunk *i* also lives on node *i*,
the "update the shared array" step becomes a full optimizer step on 1/N of the
parameters: that is exactly ZeRO stage 1.

Implementation (inside shard_map over the data axis):

  1. pack grads into one coarse-grained package-aligned buffer (coarse DSM),
  2. ``psum_scatter``  → this device's owned grad chunk        ((N-1)/N·V in)
  3. owner updates its optimizer-state chunk + fp32 master chunk,
  4. ``all_gather``    → republished full updated params        ((N-1)/N·V out)

Total per-device traffic ≈ 2·V·(N-1)/N, the paper's (N+1)·V/N per node — vs
the gather-all strawman's N·V.  fp32 master weights + optimizer moments are
only ever materialised as 1/N-size chunks per device.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.accumulator import accumulate_scatter
from repro.core.addressing import align_up
from repro.core.compat import axis_size as compat_axis_size
from repro.core.dsm import PackSpec, pack_spec, pack_tree, unpack_tree
from repro.optim.optimizers import Optimizer


class Zero1State(NamedTuple):
    """Per-device chunk of the sharded optimizer/master state."""

    master_chunk: jax.Array   # fp32 master params, this device's chunk
    opt_state: object          # optimizer state over the chunk (fp32)
    step: jax.Array


def _chunk_len(total: int, n_shards: int) -> int:
    return align_up(total, n_shards) // n_shards


def zero1_init(params, opt: Optimizer, axis_size: int, axis_index,
               spec: Optional[PackSpec] = None) -> Zero1State:
    """Build this device's Zero1State chunk from (replicated) init params.

    Runs inside shard_map: `axis_index` is this device's index on the data axis.
    """
    spec = spec or pack_spec(params)
    flat = pack_tree(params, spec, dtype=jnp.float32)
    clen = _chunk_len(spec.total, axis_size)
    flat = jnp.pad(flat, (0, clen * axis_size - flat.size))
    chunk = jax.lax.dynamic_slice_in_dim(flat, axis_index * clen, clen)
    return Zero1State(chunk, opt.init(chunk), jnp.zeros((), jnp.int32))


def zero1_update(grads, state: Zero1State, opt: Optimizer, axis,
                 spec: PackSpec, compute_dtype=jnp.bfloat16):
    """One accumulator-sharded optimizer step; returns (new_params, new_state).

    Must run inside shard_map over `axis` (the data/"node" axis).  `grads` is
    this device's local gradient pytree (already averaged over its microbatch).
    """
    n = compat_axis_size(axis)

    # (1) coarse-grained packing: one fused package-aligned buffer
    flat_g = pack_tree(grads, spec, dtype=jnp.float32)
    clen = _chunk_len(spec.total, n)
    flat_g = jnp.pad(flat_g, (0, clen * n - flat_g.size))

    # (2) reduce-scatter: the paper's chunk-i-to-node-i
    grad_chunk = jax.lax.psum_scatter(flat_g, axis, scatter_dimension=0, tiled=True)
    grad_chunk = grad_chunk / n  # data-parallel mean

    # (3) owner updates its optimizer shard + master chunk
    updates, new_opt = opt.update(grad_chunk, state.opt_state, state.master_chunk, state.step)
    new_master = state.master_chunk + updates

    # (4) republish: all-gather the updated chunks, unpack, cast to compute dtype
    full = jax.lax.all_gather(new_master, axis, axis=0, tiled=True)[: spec.total]
    new_params = jax.tree.map(
        lambda a, ref: a.astype(ref.dtype),
        unpack_tree(full.astype(jnp.float32), spec),
        grads,
    )
    if compute_dtype is not None:
        new_params = jax.tree.map(lambda p: p.astype(compute_dtype), new_params)
    return new_params, Zero1State(new_master, new_opt, state.step + 1)


def zero1_gather_params(state: Zero1State, axis, spec: PackSpec, dtype=jnp.bfloat16):
    """Materialise full params from the sharded master chunks (for eval/ckpt)."""
    full = jax.lax.all_gather(state.master_chunk, axis, axis=0, tiled=True)[: spec.total]
    tree = unpack_tree(full, spec)
    return jax.tree.map(lambda p: p.astype(dtype), tree)
