"""Optimizers built in JAX (no external deps): SGD / momentum / Adam / AdamW.

Functional protocol:
    opt = adamw(lr=3e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)

All states are pytrees, so they shard/checkpoint/reshard like params — which
is what lets the ZeRO-1 layer (optim/zero.py) treat "optimizer state shard i
lives with chunk-owner i" exactly as the paper's accumulator assigns chunk i
to node i.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]
LR = Union[float, Schedule]


def _lr_at(lr: LR, step) -> jax.Array:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, step) -> (updates, state)
    name: str = "optimizer"


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)) if u is not None else p, params, updates)


# -- SGD / momentum -----------------------------------------------------------


def sgd(lr: LR = 1e-2, momentum: Optional[float] = None, nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum is None:
            return ()
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params=None, step=0):
        lr_t = _lr_at(lr, step)
        if momentum is None:
            return jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads), state
        new_m = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -lr_t * (momentum * m + g.astype(jnp.float32)), new_m, grads)
        else:
            upd = jax.tree.map(lambda m: -lr_t * m, new_m)
        return upd, new_m

    return Optimizer(init, update, "sgd")


# -- Adam / AdamW ---------------------------------------------------------------


class AdamState(NamedTuple):
    mu: object
    nu: object


def adam(lr: LR = 1e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, name: str = "adam") -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(jax.tree.map(zeros, params), jax.tree.map(zeros, params))

    def update(grads, state: AdamState, params=None, step=0):
        step = jnp.asarray(step, jnp.int32) + 1
        lr_t = _lr_at(lr, step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            u = -lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        if params is None:
            updates = jax.tree.map(lambda m, v: upd(m, v, None), mu, nu)
        else:
            updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(mu, nu)

    return Optimizer(init, update, name)


def adamw(lr: LR = 1e-3, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    return adam(lr, b1, b2, eps, weight_decay, name="adamw")


# -- schedules -------------------------------------------------------------------


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Schedule:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(1.0, warmup_steps)
        frac = jnp.clip((step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps), 0, 1)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm
