"""Parse collective traffic out of compiled HLO text.

The roofline's collective term is not exposed by ``compiled.cost_analysis()``,
so we parse ``compiled.as_text()`` (the post-SPMD-partitioning per-device
program) and sum the **operand sizes** of every collective op:

    all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
    (+ their async ``-start`` forms; ``-done`` ops only consume handles).

Post-optimization HLO prints operands *without* type annotations, so operand
sizes are derived from the printed **output** shape(s) via op semantics
(group size ``g`` parsed from ``replica_groups``):

    all-reduce          operand = output
    all-gather          operand = output / g
    reduce-scatter      operand = output × g
    all-to-all          operand = output
    collective-permute  operand = output

We also keep a ring-model *wire bytes* estimate per op (all-reduce moves
2·(g-1)/g·size per device; gather/scatter (g-1)/g of the full buffer), since
that is closer to what the ICI links actually carry.

Shapes appearing in annotations such as ``replica_groups=[8,8]<=[64]`` cannot
match the shape regex (no dtype prefix), so the LHS scan is safe.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_OPS = (
    "all-reduce-start",
    "all-gather-start",
    "reduce-scatter-start",
    "all-to-all-start",
    "collective-permute-start",
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_CANONICAL = {
    "all-reduce-start": "all-reduce",
    "all-gather-start": "all-gather",
    "reduce-scatter-start": "reduce-scatter",
    "all-to-all-start": "all-to-all",
    "collective-permute-start": "collective-permute",
}

_OP_RE = re.compile(
    r"=\s*[^=]*?\b(" + "|".join(re.escape(o) for o in _COLLECTIVE_OPS) + r")\("
)
_SHAPE_RE = re.compile(r"\b(pred|[sufc](?:8|16|32|64|128|4)[a-z0-9]*|bf16)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


@dataclass
class CollectiveStats:
    """Per-device collective traffic summed from an HLO module."""

    bytes_by_op: Dict[str, float] = field(default_factory=dict)     # operand bytes
    wire_bytes_by_op: Dict[str, float] = field(default_factory=dict)  # ring estimate
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_op.values()))

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.wire_bytes_by_op.values()))

    @property
    def total_count(self) -> int:
        return int(sum(self.count_by_op.values()))

    def scale(self, op_factor: float) -> "CollectiveStats":
        return CollectiveStats(
            {k: v * op_factor for k, v in self.bytes_by_op.items()},
            {k: v * op_factor for k, v in self.wire_bytes_by_op.items()},
            dict(self.count_by_op),
        )

    def summary(self) -> str:
        lines = [
            f"collective traffic (per device): operand {self.total_bytes/1e6:.2f} MB, "
            f"wire≈{self.total_wire_bytes/1e6:.2f} MB, {self.total_count} ops"
        ]
        for op in sorted(self.bytes_by_op, key=lambda o: -self.bytes_by_op[o]):
            lines.append(
                f"  {op:<20s} {self.count_by_op[op]:>4d} ops  "
                f"{self.bytes_by_op[op]/1e6:>12.2f} MB (wire≈{self.wire_bytes_by_op[op]/1e6:.2f})"
            )
        return "\n".join(lines)


def collective_bytes_from_hlo(hlo_text: str) -> CollectiveStats:
    """Sum per-device operand bytes of every collective op in an HLO dump."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = _CANONICAL.get(m.group(1), m.group(1))
        lhs = line[: m.start(1)]
        out_bytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs))
        if m.group(1).endswith("-start") and out_bytes:
            out_bytes /= 2.0  # async start prints (operand, output) tuples
        g = _group_size(line)
        if op == "all-gather":
            operand = out_bytes / g
            wire = out_bytes * (g - 1) / g
        elif op == "reduce-scatter":
            operand = out_bytes * g
            wire = operand * (g - 1) / g
        elif op == "all-reduce":
            operand = out_bytes
            wire = 2.0 * out_bytes * (g - 1) / g
        elif op == "all-to-all":
            operand = out_bytes
            wire = out_bytes * (g - 1) / g
        else:  # collective-permute
            operand = out_bytes
            wire = out_bytes
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0.0) + operand
        stats.wire_bytes_by_op[op] = stats.wire_bytes_by_op.get(op, 0.0) + wire
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats
