from repro.utils.tree import (
    tree_bytes,
    tree_count,
    tree_flatten_with_paths,
    tree_zeros_like,
    path_str,
)
from repro.utils.hlo import collective_bytes_from_hlo, CollectiveStats

__all__ = [
    "tree_bytes",
    "tree_count",
    "tree_flatten_with_paths",
    "tree_zeros_like",
    "path_str",
    "collective_bytes_from_hlo",
    "CollectiveStats",
]
