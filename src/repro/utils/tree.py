"""Pytree utilities shared across the framework."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def path_str(path) -> str:
    """Render a jax tree path as a dotted string, e.g. ``params.layers.wq``."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:  # pragma: no cover - defensive
            parts.append(str(p))
    return ".".join(parts)


def tree_flatten_with_paths(tree: Any):
    """Return ``[(path_str, leaf), ...]`` in deterministic order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(path_str(path), leaf) for path, leaf in flat]


def _leaf_size(x) -> int:
    if hasattr(x, "size"):
        return int(x.size)
    return 1


def _leaf_bytes(x) -> int:
    if hasattr(x, "size") and hasattr(x, "dtype"):
        return int(x.size) * jnp.dtype(x.dtype).itemsize
    return 0


def tree_count(tree: Any) -> int:
    """Total number of scalar elements across all leaves (param count)."""
    return sum(_leaf_size(l) for l in jax.tree.leaves(tree))


def tree_bytes(tree: Any) -> int:
    """Total bytes across all leaves (works on ShapeDtypeStruct too)."""
    return sum(_leaf_bytes(l) for l in jax.tree.leaves(tree))


def tree_zeros_like(tree: Any):
    return jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), tree)


def tree_allclose(a: Any, b: Any, rtol=1e-5, atol=1e-5) -> bool:
    oks = jax.tree.map(
        lambda x, y: bool(np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)),
        a,
        b,
    )
    return all(jax.tree.leaves(oks))


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} PiB"
