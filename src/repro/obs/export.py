"""OpenMetrics / Prometheus text exporter for ``Session.metrics()``.

One pure function: :func:`openmetrics` renders the unified metrics snapshot
(the :data:`~repro.core.telemetry.SESSION_METRIC_KEYS` shape) into the
OpenMetrics text exposition format — ``# TYPE``/``# HELP`` headers, counter
families with ``_total`` suffixes, latency histograms as quantile summaries,
per-shard families labelled ``{shard="N"}``, terminated by ``# EOF``.  No
HTTP server ships here: the text is what a scrape endpoint, a pushgateway
hook, or a test asserts on, and ``Session.openmetrics()`` is the one-call
wrapper.

The renderer is defensive by construction (``.get`` with zero defaults
everywhere): a metrics dict from an older/newer session, or one missing the
``tiers``/``trace`` sections entirely, still renders — dashboards get a
stable family set, not a KeyError.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional

from repro.core import telemetry

#: quantile keys of a Hist snapshot → OpenMetrics quantile label values
_QUANTILES = (("p50", "0.5"), ("p90", "0.9"), ("p99", "0.99"))


def _escape(value: Any) -> str:
    """Escape a label value per the exposition format."""
    return (str(value).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _labels(labels: Optional[Dict[str, Any]]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in labels.items())
    return "{" + inner + "}"


def _num(v: Any) -> str:
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


class _Renderer:
    """Accumulates families so TYPE/HELP headers emit once per family."""

    def __init__(self, prefix: str):
        self.prefix = prefix
        self.lines: List[str] = []
        self._declared: set = set()

    def _declare(self, family: str, mtype: str, help_text: str) -> None:
        if family not in self._declared:
            self._declared.add(family)
            self.lines.append(f"# TYPE {family} {mtype}")
            self.lines.append(f"# HELP {family} {help_text}")

    def sample(self, name: str, mtype: str, help_text: str, value: Any,
               labels: Optional[Dict[str, Any]] = None,
               suffix: str = "") -> None:
        family = f"{self.prefix}_{name}"
        self._declare(family, mtype, help_text)
        self.lines.append(f"{family}{suffix}{_labels(labels)} {_num(value)}")

    def counter(self, name: str, help_text: str, value: Any,
                labels: Optional[Dict[str, Any]] = None) -> None:
        # counter families use the _total sample suffix per OpenMetrics
        family = f"{self.prefix}_{name}"
        self._declare(family, "counter", help_text)
        self.lines.append(f"{family}_total{_labels(labels)} {_num(value)}")

    def gauge(self, name: str, help_text: str, value: Any,
              labels: Optional[Dict[str, Any]] = None) -> None:
        self.sample(name, "gauge", help_text, value, labels)

    def summary(self, name: str, help_text: str, snap: Dict[str, float],
                labels: Optional[Dict[str, Any]] = None) -> None:
        """A Hist snapshot (count/total/p50/p90/p99) as a summary family."""
        family = f"{self.prefix}_{name}"
        self._declare(family, "summary", help_text)
        base = dict(labels) if labels else {}
        for key, q in _QUANTILES:
            self.lines.append(
                f"{family}{_labels({**base, 'quantile': q})} "
                f"{_num(snap.get(key, 0.0))}")
        self.lines.append(f"{family}_count{_labels(base)} "
                          f"{_num(snap.get('count', 0))}")
        self.lines.append(f"{family}_sum{_labels(base)} "
                          f"{_num(snap.get('total', 0.0))}")

    def render(self) -> str:
        return "\n".join(self.lines + ["# EOF"]) + "\n"


def openmetrics(metrics: Dict[str, Any], *, prefix: str = "step",
                anomalies: Optional[Iterable[Any]] = None) -> str:
    """Render a ``Session.metrics()`` snapshot as OpenMetrics text.

    ``anomalies`` (an iterable of :class:`~repro.obs.watchdog.Anomaly` or
    plain dicts with a ``kind``) adds a ``<prefix>_anomalies`` counter
    family labelled by kind — pass ``watchdog.anomalies`` to expose watchdog
    state on the same scrape."""
    r = _Renderer(prefix)
    r.gauge("info", "session backend (labels carry the string facts)", 1,
            {"backend": metrics.get("backend", "unknown")})

    store = metrics.get("store", {})
    for key in telemetry.STORE_METRIC_KEYS:
        r.counter(f"store_{key}", f"store {key.replace('_', ' ')}",
                  store.get(key, 0))

    cache = metrics.get("cache", {})
    for key in telemetry.CACHE_METRIC_KEYS:
        if key == "hit_rate":
            r.gauge("cache_hit_ratio", "cache hit ratio", cache.get(key, 0.0))
        else:
            r.counter(f"cache_{key}", f"DSM cache {key.replace('_', ' ')}",
                      cache.get(key, 0))

    r.counter("wire_traffic_elements",
              "accumulator wire traffic in vector elements",
              metrics.get("wire_traffic", 0))

    for sid, row in sorted(metrics.get("shards", {}).items()):
        labels = {"shard": sid}
        srow = row.get("store", {})
        for key in telemetry.STORE_METRIC_KEYS:
            r.counter(f"shard_store_{key}",
                      f"per-shard store {key.replace('_', ' ')}",
                      srow.get(key, 0), labels)
        r.counter("shard_wire_traffic_elements",
                  "per-shard accumulator wire traffic (elements)",
                  row.get("wire_traffic", 0), labels)

    tiers = metrics.get("tiers", {})
    hot = tiers.get("hot", {})
    cold = tiers.get("cold", {})
    r.gauge("tier_hot_entries", "entries resident in the hot tier",
            hot.get("entries", 0))
    r.gauge("tier_hot_bytes", "bytes resident in the hot tier",
            hot.get("bytes", 0))
    r.gauge("tier_cold_entries", "entries demoted to the cold tier",
            tiers.get("cold_entries", 0))
    r.gauge("tier_cold_bytes", "bytes held by the cold backend",
            cold.get("bytes", 0))
    for key in ("hot_hits", "cold_hits", "promotions", "demotions"):
        r.counter(f"tier_{key}", f"tier {key.replace('_', ' ')}",
                  tiers.get(key, 0))

    mig = tiers.get("migration", {})
    for key in ("windows", "entries_moved", "bytes_moved", "pulled"):
        r.counter(f"migration_{key}", f"migration {key.replace('_', ' ')}",
                  mig.get(key, 0))
    r.counter("migration_window_seconds", "cumulative open-window time",
              mig.get("window_s", 0.0))
    r.gauge("migration_open", "1 while a migration window is open",
            1 if mig.get("open") else 0)
    r.gauge("migration_pending", "entries still pending in the open window",
            mig.get("pending", 0))

    trace = metrics.get("trace", {})
    r.gauge("trace_enabled", "1 when the session tracer is armed",
            1 if trace.get("enabled") else 0)
    r.gauge("trace_record_only", "1 when the tracer runs in record-only "
            "(flight recorder) mode", 1 if trace.get("record_only") else 0)
    ring = trace.get("ring")
    if ring:
        r.counter("recorder_events", "events ever appended to the flight "
                  "recorder ring", ring.get("total", 0))
        r.gauge("recorder_ring_held", "events currently held by the ring",
                ring.get("held", 0))
        r.gauge("recorder_ring_capacity", "flight recorder ring capacity",
                ring.get("capacity", 0))
    for op, snap in sorted(trace.get("ops", {}).items()):
        r.summary("op_latency_us", "per-op latency distribution "
                  "(microseconds; unit-free hists ride along)",
                  snap, {"op": op})
    for op, per in sorted(trace.get("ops_by_shard", {}).items()):
        for sid, snap in sorted(per.items()):
            r.summary("shard_op_latency_us",
                      "per-shard per-op latency distribution (microseconds)",
                      snap, {"op": op, "shard": sid})

    if anomalies is not None:
        by_kind: Dict[str, int] = {}
        for a in anomalies:
            kind = a.get("kind") if isinstance(a, dict) else getattr(a, "kind", "unknown")
            by_kind[kind] = by_kind.get(kind, 0) + 1
        for kind in sorted(by_kind):
            r.counter("anomalies", "watchdog anomalies by kind",
                      by_kind[kind], {"kind": kind})

    return r.render()
