"""Flight recorder — the always-on half of step.obs.

A :class:`FlightRecorder` keeps the last N trace events in a bounded
:class:`~repro.core.telemetry.RingSink` so that *when* something goes wrong
(a stalled migration window, a straggler barrier, a dead node) there is
evidence to dump — without paying full `step.trace` cost in the meantime.

Arming contract (``Session(record=True)``):

* If the session's tracer is **disabled** (the default), the recorder arms
  it in *record-only* mode: histograms and counters accumulate as usual,
  but span events are materialised only into the ring, and only when slow
  (``duration >= slow_us``) or in an always-record category
  (:data:`~repro.core.telemetry.ALWAYS_RECORD` — migration windows, SPMD
  phases, anomaly marks).  Fast ops allocate nothing, the unbounded
  ``_events`` list stays empty, and memory is O(capacity) forever.
* If the tracer is already **enabled** (``Session(trace=True, record=True)``),
  full tracing continues unchanged; the recorder just hangs its ring off the
  tracer so the *recent* window is dump-able without walking 200k events.

``dump()`` captures a JSON-safe snapshot (events + counters + hist
quantiles); ``export()`` writes it to disk.  ``close()`` disarms whatever
the recorder armed — tests (and tidy shutdown paths) call it so the
module-level ``TRACING`` flag drops back when the session is done.
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional

from repro.core import telemetry


class FlightRecorder:
    """Bounded always-on event recorder over a session's tracer."""

    def __init__(self, *, capacity: int = 4096, slow_us: float = 1000.0,
                 enabled: bool = True):
        self.capacity = int(capacity)
        self.slow_us = float(slow_us)
        self.enabled = bool(enabled)
        self.tracer: Optional[telemetry.Tracer] = None
        self._armed_tracer = False   # recorder enabled the tracer itself

    # -- arming ---------------------------------------------------------------

    def attach(self, tracer: telemetry.Tracer) -> "FlightRecorder":
        """Hang the ring off ``tracer`` and arm record-only mode when the
        tracer isn't already running full tracing.  Idempotent; a disabled
        recorder only remembers the tracer (so ``dump()`` stays callable,
        returning an eventless capture)."""
        self.tracer = tracer
        if not self.enabled:
            return self
        if tracer.ring is None:
            tracer.ring = telemetry.RingSink(self.capacity)
        if not tracer.enabled:
            tracer.record_only = True
            tracer.slow_us = self.slow_us
            tracer.enable()
            self._armed_tracer = True
        return self

    @property
    def armed(self) -> bool:
        """True when events are currently flowing into the ring."""
        t = self.tracer
        return bool(self.enabled and t is not None and t.enabled
                    and t.ring is not None)

    def close(self) -> "FlightRecorder":
        """Disarm whatever :meth:`attach` armed.  A tracer the *user* enabled
        (full tracing) is left running — the recorder only undoes itself."""
        t = self.tracer
        if t is not None and self._armed_tracer:
            t.disable()
            t.record_only = False
            self._armed_tracer = False
        return self

    detach = close

    # -- capture --------------------------------------------------------------

    def events(self) -> List[dict]:
        """Ring contents oldest→newest (empty when never attached/armed)."""
        return self.tracer.ring_events() if self.tracer is not None else []

    def dump(self, reason: str = "manual") -> Dict[str, Any]:
        """A JSON-safe capture of the ring plus the tracer's counters and
        latency quantiles — the artifact the watchdog attaches to an
        :class:`~repro.obs.watchdog.Anomaly` and recovery attaches to its
        :class:`~repro.ft.elastic.RecoveryPlan`."""
        t = self.tracer
        events = self.events()
        snap = t.snapshot() if t is not None else {}
        ring = snap.get("ring")
        return {
            "reason": reason,
            "captured_at_unix": time.time(),
            "record_only": bool(snap.get("record_only", False)),
            "ring": ring if ring is not None else
                    {"capacity": self.capacity, "held": 0, "total": 0},
            "events": events,
            "counters": snap.get("counters", {}),
            "ops": snap.get("ops", {}),
        }

    def export(self, path: str, reason: str = "manual") -> str:
        """Write :meth:`dump` to ``path`` as JSON; returns ``path``."""
        with open(path, "w") as f:
            json.dump(self.dump(reason), f)
        return path

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        held = len(self.tracer.ring) if (self.tracer is not None and
                                         self.tracer.ring is not None) else 0
        return (f"FlightRecorder(armed={self.armed}, held={held}, "
                f"capacity={self.capacity})")


def as_recorder(record) -> FlightRecorder:
    """Resolve ``Session(record=...)``, mirroring ``as_tracer``: a
    :class:`FlightRecorder` is adopted as-is (recovery re-attaches the dead
    session's recorder this way), ``True`` builds an enabled recorder,
    ``None``/``False`` a disabled one (attach is then a no-op beyond
    remembering the tracer)."""
    if isinstance(record, FlightRecorder):
        return record
    return FlightRecorder(enabled=bool(record))
