"""step.obs — always-on flight recorder, stall/SLO watchdog, and
OpenMetrics export.

The production observability surface over ``step.trace``'s measurement
substrate, in three parts:

* :class:`FlightRecorder` — a bounded ring of recent trace events, cheap
  enough to leave armed always (``Session(record=True)``): histograms and
  counters accumulate at full fidelity while only slow or lifecycle events
  materialise, so the last moments before an incident are always dumpable.
* :class:`Watchdog` — polls live session state (open migration windows,
  in-flight barrier/semaphore waits, tier churn, per-shard lock waits,
  heartbeats via :meth:`Watchdog.watch_heartbeats`) and fires typed
  :class:`Anomaly` findings with an automatic flight-recorder dump.
* :func:`openmetrics` — ``Session.metrics()`` rendered to the OpenMetrics /
  Prometheus text format (``Session.openmetrics()`` is the wrapper;
  ``scripts/step_top.py`` is the human-facing live view).

Import discipline: this package sits *between* ``core.telemetry`` (which it
imports) and ``core.session`` (which imports it) — nothing here may import
``repro.core`` package attributes or ``core.session``.
"""

from repro.obs.export import openmetrics
from repro.obs.recorder import FlightRecorder, as_recorder
from repro.obs.watchdog import ANOMALY_KINDS, Anomaly, SEVERITIES, Watchdog

__all__ = ["ANOMALY_KINDS", "Anomaly", "FlightRecorder", "SEVERITIES",
           "Watchdog", "as_recorder", "openmetrics"]
