"""Stall/SLO watchdog — the "is it stuck?" half of step.obs.

A :class:`Watchdog` polls a session's live state (open migration window,
in-flight barrier/semaphore waits, tier counters, per-shard lock-wait
histograms) and fires a typed :class:`Anomaly` the moment a deadline or SLO
is crossed — with a flight-recorder dump captured at detection time, so the
events *leading up to* the stall are preserved even if the process dies a
second later.

Detectors (kind → trigger):

``stalled-migration``
    An open :class:`~repro.core.shards.MigrationWindow` made no progress
    (``entries_moved + pulled`` unchanged, pending nonempty) for
    ``migration_deadline_s``.
``slow-barrier`` / ``slow-semaphore``
    Some thread has been waiting on a registered sync primitive longer than
    ``max(min_*_slo_us, slo_factor × p99)`` — the SLO is derived from the
    primitive's own latency histogram, so a workload with naturally long
    barriers doesn't false-positive.
``tier-thrash``
    Promotions ≈ demotions over the last poll window with at least
    ``thrash_min_moves`` total moves: the hot tier is churning entries in
    and out instead of holding a working set.
``lock-wait-outlier``
    One shard's lock-wait p99 exceeds ``lock_wait_factor ×`` the median
    shard's p99 (and an absolute floor) — a hot shard is serialising.
``dead-heartbeat``
    Chained from :class:`~repro.ft.heartbeat.HeartbeatMonitor` via
    :meth:`Watchdog.watch_heartbeats`; fires per dead node before the
    monitor's own ``on_failure`` proceeds to recovery.

The watchdog never blocks the session: every read is a lock-free attribute
peek, a counter snapshot, or a tracer-lock histogram read.  ``poll_once()``
is the deterministic unit (tests drive it directly); ``start()`` wraps it in
a daemon thread.
"""

from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core import telemetry
from repro.obs.recorder import FlightRecorder

#: anomaly kinds, stable slugs (the Anomaly catalogue in the README)
ANOMALY_KINDS = ("stalled-migration", "slow-barrier", "slow-semaphore",
                 "tier-thrash", "lock-wait-outlier", "dead-heartbeat")

#: severity levels, in increasing order of badness
SEVERITIES = ("warning", "error", "critical")


@dataclass(frozen=True)
class Anomaly:
    """One detected runtime anomaly, with its evidence attached."""

    kind: str                        # one of ANOMALY_KINDS
    severity: str                    # "warning" | "error" | "critical"
    message: str                     # human-readable, names the culprit
    detected_at: float               # unix time of detection
    details: Dict[str, Any] = field(default_factory=dict)
    dump: Optional[Dict[str, Any]] = None   # FlightRecorder.dump() capture

    def as_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "severity": self.severity,
                "message": self.message, "detected_at": self.detected_at,
                "details": dict(self.details), "dump": self.dump}


class Watchdog:
    """Deadline/SLO monitor over one session, firing :class:`Anomaly` rows.

    ``session`` is duck-typed (needs ``store``, ``tracer`` and optionally
    ``recorder`` / ``_watch_prims``) so this module never imports
    ``core.session``.  All thresholds are constructor knobs; the defaults
    are conservative enough for production polling at ``interval_s``.
    """

    def __init__(self, session, *,
                 interval_s: float = 0.25,
                 migration_deadline_s: float = 5.0,
                 barrier_slo_factor: float = 8.0,
                 min_barrier_slo_us: float = 50_000.0,
                 semaphore_slo_factor: float = 8.0,
                 min_semaphore_slo_us: float = 50_000.0,
                 lock_wait_factor: float = 8.0,
                 min_lock_wait_us: float = 20_000.0,
                 thrash_min_moves: int = 64,
                 thrash_balance: float = 0.25,
                 cooldown_s: float = 30.0,
                 dump_dir: Optional[str] = None,
                 on_anomaly: Optional[Callable[[Anomaly], None]] = None):
        self.session = session
        self.interval_s = float(interval_s)
        self.migration_deadline_s = float(migration_deadline_s)
        self.barrier_slo_factor = float(barrier_slo_factor)
        self.min_barrier_slo_us = float(min_barrier_slo_us)
        self.semaphore_slo_factor = float(semaphore_slo_factor)
        self.min_semaphore_slo_us = float(min_semaphore_slo_us)
        self.lock_wait_factor = float(lock_wait_factor)
        self.min_lock_wait_us = float(min_lock_wait_us)
        self.thrash_min_moves = int(thrash_min_moves)
        self.thrash_balance = float(thrash_balance)
        self.cooldown_s = float(cooldown_s)
        self.dump_dir = dump_dir
        self.on_anomaly = on_anomaly
        self.anomalies: List[Anomaly] = []
        self._lock = threading.Lock()
        self._seen: Dict[tuple, float] = {}      # incident key -> fired-at
        self._mig_state: Optional[tuple] = None  # (win id, progress, t_last)
        self._tier_prev: Optional[Dict[str, int]] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._dump_seq = 0

    # -- lifecycle ------------------------------------------------------------

    def start(self) -> "Watchdog":
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="step-watchdog")
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
            self._thread = None

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                self.poll_once()
            except Exception:  # pragma: no cover - a dying watchdog must not
                pass           # take the session down with it
            self._stop.wait(self.interval_s)

    def __enter__(self) -> "Watchdog":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- the poll -------------------------------------------------------------

    def poll_once(self) -> List[Anomaly]:
        """Run every detector once; returns the anomalies fired *this* poll
        (also appended to :attr:`anomalies`).  Deterministic — tests call
        this directly instead of racing the daemon thread."""
        fired: List[Anomaly] = []
        now = time.monotonic()
        fired += self._check_migration(now)
        fired += self._check_sync_waits(now)
        fired += self._check_tier_thrash()
        fired += self._check_lock_outliers()
        return fired

    # stalled migration window ------------------------------------------------

    def _check_migration(self, now: float) -> List[Anomaly]:
        win = getattr(self.session.store, "migration_window", None)
        if win is None:
            self._mig_state = None
            return []
        progress = (int(getattr(win, "entries_moved", 0))
                    + int(getattr(win, "pulled", 0)))
        remaining = int(getattr(win, "remaining", 0))
        state = self._mig_state
        if state is None or state[0] != id(win) or state[1] != progress:
            self._mig_state = (id(win), progress, now)
            return []
        if remaining <= 0 or now - state[2] < self.migration_deadline_s:
            return []
        return self._fire(
            "stalled-migration", "error",
            f"migration window open {now - state[2]:.1f}s with no progress "
            f"({remaining} entries still pending)",
            {"stalled_s": now - state[2], "remaining": remaining,
             "entries_moved": int(getattr(win, "entries_moved", 0)),
             "pulled": int(getattr(win, "pulled", 0))},
            incident=("mig", id(win), progress))

    # in-flight barrier / semaphore waits ------------------------------------

    def _slo_us(self, hist_names, factor: float, floor: float) -> float:
        trc = self.session.tracer
        p99 = 0.0
        for name in hist_names:
            snap = trc.hist(name)
            if snap is not None:
                p99 = max(p99, snap["p99"])
        return max(floor, factor * p99)

    def _check_sync_waits(self, now: float) -> List[Anomaly]:
        fired: List[Anomaly] = []
        prims = list(getattr(self.session, "_watch_prims", ()))
        wall = time.perf_counter()
        for prim in prims:
            kind = getattr(prim, "watch_kind", None)
            oldest = getattr(prim, "oldest_wait_start", None)
            if kind is None or oldest is None:
                continue
            t0 = oldest()
            if t0 is None:
                continue
            wait_us = (wall - t0) * 1e6
            if kind == "barrier":
                slo = self._slo_us(("barrier.wait", "accumulate.barrier"),
                                   self.barrier_slo_factor,
                                   self.min_barrier_slo_us)
                slug, sev = "slow-barrier", "warning"
            else:
                slo = self._slo_us(("semaphore.acquire",),
                                   self.semaphore_slo_factor,
                                   self.min_semaphore_slo_us)
                slug, sev = "slow-semaphore", "warning"
            if wait_us < slo:
                continue
            fired += self._fire(
                slug, sev,
                f"{kind} wait in flight for {wait_us / 1e3:.1f}ms "
                f"(SLO {slo / 1e3:.1f}ms, p99-derived)",
                {"wait_us": wait_us, "slo_us": slo,
                 "waiters": int(getattr(prim, "waiters", lambda: 0)())},
                incident=(slug, id(prim), round(t0, 6)))
        return fired

    # tier demotion thrash ----------------------------------------------------

    def _check_tier_thrash(self) -> List[Anomaly]:
        tier_stats = getattr(self.session.store, "tier_stats", None)
        if tier_stats is None:
            return []
        stats = tier_stats()
        cur = {"promotions": int(stats.get("promotions", 0)),
               "demotions": int(stats.get("demotions", 0))}
        prev, self._tier_prev = self._tier_prev, cur
        if prev is None:
            return []
        dp = cur["promotions"] - prev["promotions"]
        dd = cur["demotions"] - prev["demotions"]
        moves = dp + dd
        if moves < self.thrash_min_moves or min(dp, dd) == 0:
            return []
        balance = min(dp, dd) / max(dp, dd)
        if balance < 1.0 - self.thrash_balance:
            return []
        return self._fire(
            "tier-thrash", "warning",
            f"hot tier churning: {dp} promotions vs {dd} demotions in one "
            f"poll window (balance {balance:.2f})",
            {"promotions": dp, "demotions": dd, "balance": balance},
            incident=("thrash",))   # one ongoing churn = one incident; the
                                    # cooldown alone governs re-fires

    # per-shard lock-wait outliers -------------------------------------------

    def _check_lock_outliers(self) -> List[Anomaly]:
        per = self.session.tracer.shard_hist("store.lock_wait")
        if len(per) < 2:
            return []
        p99s = {sid: snap["p99"] for sid, snap in per.items()}
        ranked = sorted(p99s.values())
        median = ranked[len(ranked) // 2]
        fired: List[Anomaly] = []
        for sid, p99 in p99s.items():
            if p99 < self.min_lock_wait_us:
                continue
            if p99 < self.lock_wait_factor * max(median, 1.0):
                continue
            fired += self._fire(
                "lock-wait-outlier", "warning",
                f"shard {sid} lock-wait p99 {p99 / 1e3:.1f}ms vs median "
                f"{median / 1e3:.3f}ms across {len(p99s)} shards",
                {"shard": sid, "p99_us": p99, "median_us": median},
                incident=("lockwait", sid))
        return fired

    # heartbeat escalation ----------------------------------------------------

    def watch_heartbeats(self, monitor) -> Any:
        """Chain onto a :class:`~repro.ft.heartbeat.HeartbeatMonitor`: each
        newly dead node fires a ``dead-heartbeat`` anomaly (dump included)
        *before* the monitor's original ``on_failure`` runs recovery."""
        prev = monitor.on_failure

        def _on_failure(dead_nodes):
            for node_id in dead_nodes:
                payload = monitor.last_payload(node_id)
                self._fire("dead-heartbeat", "critical",
                           f"node {node_id} heartbeat lost",
                           {"node": node_id, "last_payload": payload},
                           incident=("dead", node_id))
            if prev is not None:
                prev(dead_nodes)

        monitor.on_failure = _on_failure
        return monitor

    # firing ------------------------------------------------------------------

    def _recorder(self) -> Optional[FlightRecorder]:
        rec = getattr(self.session, "recorder", None)
        return rec if isinstance(rec, FlightRecorder) else None

    def _fire(self, kind: str, severity: str, message: str,
              details: Dict[str, Any],
              incident: Optional[tuple] = None) -> List[Anomaly]:
        now = time.monotonic()
        key = (kind,) + (incident if incident is not None else ())
        with self._lock:
            last = self._seen.get(key)
            if last is not None and now - last < self.cooldown_s:
                return []
            self._seen[key] = now
        # breadcrumb first, so the mark is *inside* the dump we then capture
        trc = self.session.tracer
        if telemetry.TRACING and trc.enabled:
            trc.mark("anomaly", kind, severity=severity, message=message)
        dump = None
        rec = self._recorder()
        if rec is not None and rec.armed:
            dump = rec.dump(reason=kind)
        anomaly = Anomaly(kind=kind, severity=severity, message=message,
                          detected_at=time.time(), details=details, dump=dump)
        if self.dump_dir is not None and dump is not None:
            os.makedirs(self.dump_dir, exist_ok=True)
            with self._lock:
                self._dump_seq += 1
                seq = self._dump_seq
            path = os.path.join(self.dump_dir, f"anomaly-{seq:04d}-{kind}.json")
            with open(path, "w") as f:
                json.dump(anomaly.as_dict(), f)
            details["dump_path"] = path
        with self._lock:
            self.anomalies.append(anomaly)
        if self.on_anomaly is not None:
            self.on_anomaly(anomaly)
        return [anomaly]

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Watchdog(anomalies={len(self.anomalies)}, "
                f"interval_s={self.interval_s})")
