"""Paper §5.2 / Table: accumulator traffic — (2N+1)·V vs (N+1)·V, plus the
sparse wire format.

Validates the paper's claim three ways:
1. host accumulator: exact wire-traffic accounting per mode (sparse figures
   derived from the actual pair-array lengths);
2. SPMD lowering on an 8-device mesh: per-device collective bytes parsed from
   the compiled HLO — gather_all ≈ N·V vs reduce_scatter ≈ 2·V per device —
   plus wall time per accumulate call;
3. dense-vs-sparse-vs-auto sweep over nnz density: which wire format the auto
   rule picks, what it costs, and Pallas-vs-jnp sparsifier wall time.

The whole table is written to ``benchmarks/BENCH_accumulator.json`` so the
perf trajectory has data across PRs (``python -m benchmarks.run --only
accumulator``).
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit, timeit, write_bench
from repro.core import AccumMode, DAddAccumulator, GlobalStore, accumulate, shard_map
from repro.core.sparse import blocked_topk_sparsify, pair_capacity
from repro.launch.mesh import make_host_mesh
from repro.utils.hlo import collective_bytes_from_hlo

RESULTS = {}


def host_layer():
    V, N, iters, k = 4096, 8, 5, 256
    P_cap = pair_capacity(V, k)
    for mode in (AccumMode.GATHER_ALL, AccumMode.REDUCE_SCATTER,
                 AccumMode.SPARSE, AccumMode.AUTO):
        store = GlobalStore()
        store.new_array("out", (V,))
        acc = DAddAccumulator(store, "out", N, 4, mode, k=k)
        vec = jnp.ones((V,))

        def worker():
            for _ in range(iters):
                acc.accumulate(vec)

        ts = [threading.Thread(target=worker) for _ in range(N)]
        t0 = time.perf_counter()
        [t.start() for t in ts]
        [t.join() for t in ts]
        us = (time.perf_counter() - t0) * 1e6 / iters
        model = {"gather_all": (2 * N + 1) * V,
                 "reduce_scatter": (N + 1) * V,
                 "sparse": N * 2 * P_cap + V,   # pairs actually shipped (lossy here)
                 "auto": (N + 1) * V}[mode.value]  # dense input → dense branch
        assert acc.bytes_transferred == model * iters, (
            mode, acc.bytes_transferred, model * iters)
        emit(f"accum_host_{mode.value}", us,
             f"wire_elems={acc.bytes_transferred};model_per_round={model}")
        RESULTS[f"host_{mode.value}"] = {
            "us_per_round": us, "wire_elems": acc.bytes_transferred,
            "model_per_round": model}


def spmd_layer():
    mesh = make_host_mesh(data=8)
    V = 1 << 16
    x = jnp.arange(8 * V, dtype=jnp.float32).reshape(8, V)
    # sparse input (each shard has <= k nonzeros) for the sparse/auto rows
    xs = np.zeros((8, V), np.float32)
    for i in range(8):
        xs[i, (np.arange(5) * 1024 + i * 7) % V] = float(i + 1)  # ≤1 nnz per block
    xs = jnp.asarray(xs)

    for mode in ("gather_all", "reduce_scatter", "hierarchical", "sparse", "auto"):
        k = 256 if mode in ("sparse", "auto") else None
        inp = xs if mode == "sparse" else x
        expect = np.asarray(jnp.sum(inp, axis=0))
        f = jax.jit(shard_map(
            lambda v: accumulate(v[0], "data", mode, inner_axis="data", k=k)[None],
            mesh=mesh, in_specs=P("data", None), out_specs=P("data", None),
            check_vma=False))
        lowered = f.lower(inp)
        compiled = lowered.compile()
        coll = collective_bytes_from_hlo(compiled.as_text())
        out = np.asarray(f(inp))[0]
        exact = bool(np.allclose(out, expect))
        us = timeit(lambda: jax.block_until_ready(f(inp)), warmup=1, iters=5)
        emit(f"accum_spmd_{mode}", us,
             f"coll_bytes_per_dev={coll.total_bytes:.0f};"
             f"wire_bytes_per_dev={coll.total_wire_bytes:.0f};"
             f"ops={coll.total_count};exact={exact}")
        RESULTS[f"spmd_{mode}"] = {
            "us_per_call": us, "coll_bytes_per_dev": coll.total_bytes,
            "wire_bytes_per_dev": coll.total_wire_bytes, "exact": exact}


def sparsity_sweep():
    """Dense vs sparse vs auto over nnz density: wire cost + branch taken,
    and Pallas-vs-jnp sparsifier wall time at each density."""
    V, N, k = 1 << 14, 4, 512
    P_cap = pair_capacity(V, k)
    rng = np.random.default_rng(0)
    sweep = {}
    for density in (0.001, 0.01, 0.03, 0.25, 1.0):
        vecs = []
        for _ in range(N):
            v = np.zeros(V, np.float32)
            nnz = max(1, int(V * density))
            pos = rng.choice(V, size=nnz, replace=False)
            v[pos] = rng.normal(size=nnz)
            vecs.append(jnp.asarray(v))

        row = {"nnz": int(np.sum(np.asarray(vecs[0]) != 0)),
               "pair_capacity": P_cap}
        for mode in (AccumMode.REDUCE_SCATTER, AccumMode.SPARSE, AccumMode.AUTO):
            store = GlobalStore()
            store.new_array("out", (V,))
            acc = DAddAccumulator(store, "out", N, 4, mode, k=k)
            ts = [threading.Thread(target=acc.accumulate, args=(v,)) for v in vecs]
            t0 = time.perf_counter()
            [t.start() for t in ts]
            [t.join() for t in ts]
            us = (time.perf_counter() - t0) * 1e6
            row[mode.value] = {"us": us, "wire_elems": acc.bytes_transferred,
                               "branch": acc.last_mode.value}
            emit(f"accum_density{density}_{mode.value}", us,
                 f"wire_elems={acc.bytes_transferred};branch={acc.last_mode.value}")

        x = vecs[0]
        us_pl = timeit(lambda: jax.block_until_ready(
            tuple(blocked_topk_sparsify(x, k))), warmup=1, iters=5)
        us_jnp = timeit(lambda: jax.block_until_ready(
            tuple(blocked_topk_sparsify(x, k, impl="jnp"))), warmup=1, iters=5)
        row["sparsify_pallas_us"] = us_pl
        row["sparsify_jnp_us"] = us_jnp
        emit(f"sparsify_density{density}", us_pl, f"jnp_us={us_jnp:.1f}")
        sweep[str(density)] = row
    RESULTS["density_sweep"] = sweep


def main():
    host_layer()
    spmd_layer()
    sparsity_sweep()
    out = write_bench("BENCH_accumulator.json", RESULTS)
    print(f"# wrote {out}", flush=True)


if __name__ == "__main__":
    main()
