"""Paper §5.2 / Table: accumulator traffic — (2N+1)·V vs (N+1)·V.

Validates the paper's claim two ways:
1. host accumulator: exact wire-traffic accounting per mode;
2. SPMD lowering on an 8-device mesh: per-device collective bytes parsed from
   the compiled HLO — gather_all ≈ N·V vs reduce_scatter ≈ 2·V per device —
   plus wall time per accumulate call.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit, timeit
from repro.core import AccumMode, DAddAccumulator, GlobalStore, accumulate, shard_map
from repro.launch.mesh import make_host_mesh
from repro.utils.hlo import collective_bytes_from_hlo


def host_layer():
    V, N, iters = 4096, 8, 5
    for mode in (AccumMode.GATHER_ALL, AccumMode.REDUCE_SCATTER, AccumMode.SPARSE, AccumMode.AUTO):
        store = GlobalStore()
        store.new_array("out", (V,))
        acc = DAddAccumulator(store, "out", N, 4, mode)
        import threading
        vec = jnp.ones((V,))

        def worker():
            for _ in range(iters):
                acc.accumulate(vec)

        ts = [threading.Thread(target=worker) for _ in range(N)]
        t0 = __import__("time").perf_counter()
        [t.start() for t in ts]
        [t.join() for t in ts]
        us = (__import__("time").perf_counter() - t0) * 1e6 / iters
        model = {"gather_all": (2 * N + 1) * V, "reduce_scatter": (N + 1) * V,
                 "sparse": 2 * V + V, "auto": (N + 1) * V}[mode.value]
        emit(f"accum_host_{mode.value}", us,
             f"wire_elems={acc.bytes_transferred};model_per_round={model}")


def spmd_layer():
    mesh = make_host_mesh(data=8)
    V = 1 << 16
    x = jnp.arange(8 * V, dtype=jnp.float32).reshape(8, V)
    # sparse input (each shard has <= k nonzeros) for the sparse/auto rows
    xs = np.zeros((8, V), np.float32)
    for i in range(8):
        xs[i, (np.arange(5) * 1024 + i * 7) % V] = float(i + 1)  # ≤1 nnz per block
    xs = jnp.asarray(xs)

    for mode in ("gather_all", "reduce_scatter", "hierarchical", "sparse", "auto"):
        k = 256 if mode in ("sparse", "auto") else None
        inp = xs if mode == "sparse" else x
        expect = np.asarray(jnp.sum(inp, axis=0))
        f = jax.jit(shard_map(
            lambda v: accumulate(v[0], "data", mode, inner_axis="data", k=k)[None],
            mesh=mesh, in_specs=P("data", None), out_specs=P("data", None),
            check_vma=False))
        lowered = f.lower(inp)
        compiled = lowered.compile()
        coll = collective_bytes_from_hlo(compiled.as_text())
        out = np.asarray(f(inp))[0]
        exact = bool(np.allclose(out, expect))
        us = timeit(lambda: jax.block_until_ready(f(inp)), warmup=1, iters=5)
        emit(f"accum_spmd_{mode}", us,
             f"coll_bytes_per_dev={coll.total_bytes:.0f};"
             f"wire_bytes_per_dev={coll.total_wire_bytes:.0f};"
             f"ops={coll.total_count};exact={exact}")


def main():
    host_layer()
    spmd_layer()


if __name__ == "__main__":
    main()
