"""Paper Fig. 3: fine- vs coarse-grained DSM, plus the shard-count sweep.

Three structural measurements on real machinery:
1. transfer counts through the GlobalStore under each granularity (the paper's
   request-count argument: coarse-grained = 1 bulk transfer per object, fine =
   1 per 32-bit word), plus wall time of get/set round trips;
2. the TPU realisation — a 200-leaf parameter pytree moved leaf-by-leaf
   ("fine") vs packed into one 128-aligned buffer ("coarse", pack_tree) —
   which is the latency-vs-bandwidth trade the paper measures on memcached;
3. the ``step.shards`` sweep — S=1 vs S=8 consistent-hash shards under a
   concurrent multi-thread cached read/write mix (the workload the seed's
   single cache lock serialised), written to ``benchmarks/BENCH_shards.json``.
"""

import os
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, timeit, write_bench
from repro.core import DSMCache, GlobalStore, pack_spec, pack_tree, unpack_tree


def main():
    n_leaves, leaf = 200, 512
    tree = {f"w{i}": jnp.full((leaf,), float(i)) for i in range(n_leaves)}

    for gran in ("fine", "coarse"):
        store = GlobalStore(granularity=gran)
        for k, v in tree.items():
            store.new_array(k, (leaf,))

        def roundtrip():
            for k, v in tree.items():
                store.set(k, v, bump_epoch=False)
            for k in tree:
                store.get(k)

        us = timeit(roundtrip, warmup=1, iters=3)
        emit(f"dsm_{gran}_roundtrip", us, f"transfers={store.stats['transfers']}")

    # packed vs per-leaf device transfer
    spec = pack_spec(tree)

    def fine_put():
        out = [jax.device_put(v) for v in tree.values()]
        jax.block_until_ready(out)

    def coarse_put():
        buf = jax.device_put(pack_tree(tree, spec))
        jax.block_until_ready(buf)

    us_fine = timeit(fine_put, warmup=1, iters=5)
    us_coarse = timeit(coarse_put, warmup=1, iters=5)
    emit("dsm_fine_device_put", us_fine, f"n_transfers={n_leaves}")
    emit("dsm_coarse_device_put", us_coarse,
         f"n_transfers=1;speedup={us_fine / max(us_coarse, 1e-9):.2f}x;pad_waste={spec.padding_waste}")

    # roundtrip correctness of the coarse path
    buf = pack_tree(tree, spec)
    back = unpack_tree(buf, spec)
    ok = all(np.allclose(tree[k], back[k]) for k in tree)
    emit("dsm_coarse_roundtrip_exact", 0.0, f"ok={ok}")

    shard_sweep()


def _mixed_workload(store, cache, names, n_threads, ops_per_thread, write_every,
                    memoize_owners=False):
    """Concurrent cached read/write mix: each worker node loops over its
    name stream, writing a fresh host buffer every `write_every`-th op (the
    numpy→jax conversion happens under the owning shard's lock — exactly the
    hold the seed's single lock serialised across all names).

    With ``memoize_owners=True`` each op carries its pre-resolved
    :class:`OwnerHandle`, so the hot loop never re-hashes the ring — the
    memoization the ``SharedRef`` path uses."""
    payload = [np.full((262144,), float(t), np.float32) for t in range(n_threads)]
    handles = ({name: store.owner_handle(name) for name in names}
               if memoize_owners else {})
    errs = []

    def worker(node):
        try:
            for i in range(ops_per_thread):
                name = names[(node * 31 + i) % len(names)]
                owner = handles.get(name)
                if i % write_every == node % write_every:
                    cache.write(node, name, payload[node], owner=owner)
                else:
                    cache.read(node, name, owner=owner)
        except Exception as e:  # pragma: no cover - surfaced below
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(n_threads)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errs:
        raise errs[0]
    return time.perf_counter() - t0


def shard_sweep(n_threads: int = 8, n_names: int = 64,
                ops_per_thread: int = 240, write_every: int = 2):
    """S=1 vs S=8 × hashed vs memoized owners: the same mixed read/write
    workload over the same namespace.  Per-shard locks let ops on different
    shards overlap; pre-resolved :class:`OwnerHandle`\\ s additionally take
    the per-op ring hash out of the locked hot path (median of 5 runs)."""
    results = {"workload": {"threads": n_threads, "names": n_names,
                            "ops_per_thread": ops_per_thread,
                            "write_every": write_every, "vector_len": 262144}}
    total_ops = n_threads * ops_per_thread
    for shards in (1, 8):
        row = {}
        for label, memo in (("hashed", False), ("memoized", True)):
            # fresh store + cache per cell: identical cold-cache start, so the
            # hashed/memoized comparison is owner resolution and nothing else
            store = GlobalStore(shards=shards)
            cache = DSMCache(store, n_nodes=n_threads, capacity=n_names)
            names = [f"v{i}" for i in range(n_names)]
            for n in names:
                store.new_array(n, (262144,))
            _mixed_workload(store, cache, names, n_threads, 20, write_every,
                            memoize_owners=memo)  # warmup
            dt = sorted(_mixed_workload(store, cache, names, n_threads,
                                        ops_per_thread, write_every,
                                        memoize_owners=memo)
                        for _ in range(5))[2]
            row[f"{label}_seconds"] = dt
            row[f"{label}_ops_per_sec"] = total_ops / dt
            emit(f"dsm_sharded_rw_mix_s{shards}_{label}", dt / total_ops * 1e6,
                 f"ops_per_sec={total_ops / dt:.0f}")
        # headline ops_per_sec is the memoized path — what SharedRef users get
        row["seconds"] = row["memoized_seconds"]
        row["ops_per_sec"] = row["memoized_ops_per_sec"]
        row["owner_memo_speedup"] = (row["memoized_ops_per_sec"]
                                     / row["hashed_ops_per_sec"])
        row["cache_hit_rate"] = cache.stats.hit_rate
        row["shards_busy"] = sum(1 for r in store.shard_stats().values()
                                 if r["get"] + r["set"] > 0)
        results[f"s{shards}"] = row
    # the per-shard-locking story is measured on the hashed path (the PR 5
    # workload, where per-op resolution + lock hold is what sharding relieves)
    results["speedup_s8_over_s1"] = (results["s8"]["hashed_ops_per_sec"]
                                     / results["s1"]["hashed_ops_per_sec"])
    emit("dsm_sharded_speedup", 0.0,
         f"s8_over_s1={results['speedup_s8_over_s1']:.2f}x;"
         f"memo_s8={results['s8']['owner_memo_speedup']:.2f}x")
    write_bench("BENCH_shards.json", results)


if __name__ == "__main__":
    main()
