"""Paper Fig. 3: fine- vs coarse-grained DSM.

Two structural measurements on real machinery:
1. transfer counts through the GlobalStore under each granularity (the paper's
   request-count argument: coarse-grained = 1 bulk transfer per object, fine =
   1 per 32-bit word), plus wall time of get/set round trips;
2. the TPU realisation — a 200-leaf parameter pytree moved leaf-by-leaf
   ("fine") vs packed into one 128-aligned buffer ("coarse", pack_tree) —
   which is the latency-vs-bandwidth trade the paper measures on memcached.
"""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, timeit
from repro.core import GlobalStore, pack_spec, pack_tree, unpack_tree


def main():
    n_leaves, leaf = 200, 512
    tree = {f"w{i}": jnp.full((leaf,), float(i)) for i in range(n_leaves)}

    for gran in ("fine", "coarse"):
        store = GlobalStore(granularity=gran)
        for k, v in tree.items():
            store.new_array(k, (leaf,))

        def roundtrip():
            for k, v in tree.items():
                store.set(k, v, bump_epoch=False)
            for k in tree:
                store.get(k)

        us = timeit(roundtrip, warmup=1, iters=3)
        emit(f"dsm_{gran}_roundtrip", us, f"transfers={store.stats['transfers']}")

    # packed vs per-leaf device transfer
    spec = pack_spec(tree)

    def fine_put():
        out = [jax.device_put(v) for v in tree.values()]
        jax.block_until_ready(out)

    def coarse_put():
        buf = jax.device_put(pack_tree(tree, spec))
        jax.block_until_ready(buf)

    us_fine = timeit(fine_put, warmup=1, iters=5)
    us_coarse = timeit(coarse_put, warmup=1, iters=5)
    emit("dsm_fine_device_put", us_fine, f"n_transfers={n_leaves}")
    emit("dsm_coarse_device_put", us_coarse,
         f"n_transfers=1;speedup={us_fine / max(us_coarse, 1e-9):.2f}x;pad_waste={spec.padding_waste}")

    # roundtrip correctness of the coarse path
    buf = pack_tree(tree, spec)
    back = unpack_tree(buf, spec)
    ok = all(np.allclose(tree[k], back[k]) for k in tree)
    emit("dsm_coarse_roundtrip_exact", 0.0, f"ok={ok}")


if __name__ == "__main__":
    main()
