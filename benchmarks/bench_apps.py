"""Paper Figs. 4–10: the four applications vs iterations and thread counts.

GENE/LRS/KMS/FOREST/NMFS/LJ-scale datasets are shrunk to CPU-bench size but
keep the papers' sweep structure: running time vs #iterations and vs
#threads, per application.  All workloads iterate via ``ctx.iterate`` through
the `step.Session` facade — host backend for the paper sweeps, plus an SPMD
sweep where the loop lowers to one ``lax.scan`` (so rising iters should cost
runtime, not compile time).  The derived column records the sweep point + the
quality metric so regressions in either speed or convergence are visible.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from benchmarks.common import emit, timeit
from repro.analytics import kmeans, logreg, nmf, pagerank
from repro.data import kmeans_dataset, logreg_dataset, nmf_dataset, powerlaw_graph


def bench_logreg():
    x, y, _ = logreg_dataset(2000, 128, seed=0)   # GENE-shaped (n >> d)
    for iters in (6, 10, 14):
        us = timeit(lambda: logreg.fit(x, y, n_nodes=2, threads_per_node=2,
                                       iters=iters, lr=1e-3), iters=2)
        theta, _ = logreg.fit(x, y, n_nodes=2, threads_per_node=2,
                              iters=iters, lr=1e-3)
        emit(f"logreg_iters{iters}", us, f"loss={logreg.loss(theta, x, y):.4f}")
    for threads in (1, 2, 4):
        us = timeit(lambda: logreg.fit(x, y, n_nodes=1, threads_per_node=threads,
                                       iters=10, lr=1e-3), iters=2)
        emit(f"logreg_threads{threads}", us, "iters=10")


def bench_kmeans():
    x, _, _ = kmeans_dataset(20000, 32, 16, seed=0)   # KMS-shaped
    for k in (8, 16, 32):
        us = timeit(lambda: kmeans.fit(x, k, n_nodes=2, threads_per_node=2,
                                       iters=10, seed=0), iters=2)
        c, _ = kmeans.fit(x, k, n_nodes=2, threads_per_node=2, iters=10, seed=0)
        emit(f"kmeans_k{k}", us, f"inertia={kmeans.inertia(x, c):.0f}")
    for iters in (6, 10, 14):
        us = timeit(lambda: kmeans.fit(x, 16, n_nodes=2, threads_per_node=2,
                                       iters=iters, seed=0), iters=2)
        emit(f"kmeans_iters{iters}", us, "k=16")


def bench_nmf():
    r, _, _ = nmf_dataset(2000, 256, 16, seed=0)   # NMFS-shaped
    for rank in (8, 16, 32):
        us = timeit(lambda: nmf.fit(r, rank, n_nodes=2, threads_per_node=2,
                                    iters=10, seed=0), iters=2)
        p, q, _ = nmf.fit(r, rank, n_nodes=2, threads_per_node=2, iters=10, seed=0)
        emit(f"nmf_rank{rank}", us, f"frob={nmf.frob_loss(r, p, q):.4f}")
    for iters in (6, 10, 14):
        us = timeit(lambda: nmf.fit(r, 16, n_nodes=2, threads_per_node=2,
                                    iters=iters, seed=0), iters=2)
        emit(f"nmf_iters{iters}", us, "rank=16")


def bench_pagerank():
    n_v = 20000
    edges = powerlaw_graph(n_v, 8, seed=0)   # LJ-shaped
    for iters in (6, 10, 14):
        us = timeit(lambda: pagerank.fit(edges, n_v, n_nodes=2,
                                         threads_per_node=2, iters=iters), iters=2)
        emit(f"pagerank_iters{iters}", us, f"edges={edges.shape[0]}")
    for threads in (1, 2, 4):
        us = timeit(lambda: pagerank.fit(edges, n_v, n_nodes=1,
                                         threads_per_node=threads, iters=10), iters=2)
        emit(f"pagerank_threads{threads}", us, "iters=10")


def bench_spmd_scan():
    """The scan path end-to-end: wall time vs iters on the SPMD backend."""
    x, y, _ = logreg_dataset(2000, 128, seed=0)
    for iters in (8, 64):
        us = timeit(lambda: logreg.fit(x, y, backend="spmd", iters=iters,
                                       lr=1e-3), iters=2)
        theta, _ = logreg.fit(x, y, backend="spmd", iters=iters, lr=1e-3)
        emit(f"logreg_spmd_scan_iters{iters}", us,
             f"loss={logreg.loss(theta, x, y):.4f}")


def main():
    bench_logreg()
    bench_kmeans()
    bench_nmf()
    bench_pagerank()
    bench_spmd_scan()


if __name__ == "__main__":
    main()
