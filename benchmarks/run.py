"""Benchmark harness: one sub-benchmark per paper table/figure.

Each module runs in its own subprocess (so it can force its own device count
before importing jax) and prints ``name,us_per_call,derived`` CSV rows, which
this driver aggregates.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run --only accumulator
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys

BENCHES = [
    ("dsm_modes", "benchmarks.bench_dsm_modes"),            # Fig. 3 + shard sweep
    ("accumulator", "benchmarks.bench_accumulator"),        # §5.2 traffic claim
    ("apps", "benchmarks.bench_apps"),                      # Figs. 4–10
    ("fault_tolerance", "benchmarks.bench_fault_tolerance"),  # Fig. 11
    ("rebalance", "benchmarks.bench_rebalance"),            # step.tiers gate
    ("kernels", "benchmarks.bench_kernels"),                # Pallas μs/call
    ("compile", "benchmarks.bench_compile"),                # ctx.iterate O(1) claim
    ("trace", "benchmarks.bench_trace"),                    # step.trace overhead
    ("check", "benchmarks.bench_check"),                    # step.check overhead
    ("obs", "benchmarks.bench_obs"),                        # step.obs armed gate
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on bench name")
    args = ap.parse_args()

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root

    print("name,us_per_call,derived")
    failures = []
    for name, module in BENCHES:
        if args.only and args.only not in name:
            continue
        print(f"# --- {name} ---", flush=True)
        proc = subprocess.run([sys.executable, "-m", module], env=env, cwd=root,
                              capture_output=True, text=True, timeout=1800)
        out = proc.stdout.strip()
        if out:
            print(out, flush=True)
        if proc.returncode != 0:
            failures.append(name)
            print(f"# {name} FAILED (exit {proc.returncode}):", flush=True)
            print("\n".join("#   " + l for l in proc.stderr.strip().splitlines()[-12:]),
                  flush=True)
    if failures:
        print(f"# FAILURES: {failures}", flush=True)
        sys.exit(1)
    print("# all benchmarks OK", flush=True)


if __name__ == "__main__":
    main()
