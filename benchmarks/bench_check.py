"""step.check overhead: the ≤5%-when-disabled acceptance measurement.

Mirrors bench_trace.py on the same two workloads, three checker states each:

1. the S=8 sharded concurrent cached read/write mix (the lock-order
   sanitizer's densest hook path: every shard/node lock acquisition), and
2. a 2-thread host logreg fit (access hooks + sync edges + accumulator
   rounds together);

each timed under ``noop`` (no checker attached anywhere — the pre-step.check
baseline), ``disabled`` (checkers attached but off, the shipping default:
must cost ≤5% on the rw mix), and ``armed`` (full happens-before + lock
analysis, reported for scale, not gated).  Results land in
``benchmarks/BENCH_check.json``.
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.bench_dsm_modes import _mixed_workload
from benchmarks.common import emit, write_bench
from repro.check import NULL_CHECKER, Checker
from repro.check import checker as stepcheck
from repro.core import DSMCache, GlobalStore, Session


def _rw_mix_once(state: str, n_threads=8, n_names=64, ops_per_thread=120,
                 write_every=2):
    store = GlobalStore(shards=8)
    cache = DSMCache(store, n_nodes=n_threads, capacity=n_names)
    checker = None
    if state == "disabled":
        checker = Checker(enabled=False)
    elif state == "armed":
        checker = Checker(enabled=True)
    if checker is not None:
        store.checker = checker
        cache.checker = checker
    names = [f"v{i}" for i in range(n_names)]
    for n in names:
        store.new_array(n, (262144,))
    _mixed_workload(store, cache, names, n_threads, 20, write_every)  # warmup
    dt = _mixed_workload(store, cache, names, n_threads, ops_per_thread,
                         write_every)
    findings = 0
    if checker is not None:
        findings = len(checker.findings())
        checker.disable()
    return dt, n_threads * ops_per_thread, findings


def _rw_mix_all(states, repeats=7, **kw):
    """Interleave states round-robin and keep each state's best run (the mix
    is dominated by 1 MiB payload writes and thread scheduling — see the
    same rationale in bench_trace.py)."""
    best = {}
    for _ in range(repeats):
        for state in states:
            dt, ops, findings = _rw_mix_once(state, **kw)
            if state not in best or dt < best[state][0]:
                best[state] = (dt, ops, findings)
    return best


def _logreg_fit(state: str, repeats=5):
    import time

    from repro.analytics import logreg

    rng = np.random.default_rng(0)
    x = rng.normal(size=(256, 64)).astype(np.float32)
    y = (rng.random(256) > 0.5).astype(np.float32)

    # absorb jit compilation before any state is timed
    logreg.fit(x, y, iters=2, n_nodes=2, threads_per_node=1)
    best = None
    for _ in range(repeats):
        sess = Session(backend="host", n_nodes=2, threads_per_node=1,
                       check=(state == "armed"))
        if state == "noop":
            # strip even the disabled per-object checkers: the pre-step.check
            # baseline had no checker attribute lookups beyond the flag check
            sess.checker = NULL_CHECKER
            sess.store.checker = NULL_CHECKER
            sess.cache.checker = NULL_CHECKER
        t0 = time.perf_counter()
        theta, _ = logreg.fit(x, y, iters=20, session=sess)
        dt = time.perf_counter() - t0
        findings = len(sess.findings()) if state == "armed" else 0
        sess.checker.disable()
        if best is None or dt < best[0]:
            best = (dt, findings)
    return best


def main():
    assert stepcheck.armed_count() == 0
    results = {"workload_rw": {"threads": 8, "shards": 8, "names": 64,
                               "ops_per_thread": 120, "vector_len": 262144},
               "workload_logreg": {"n": 256, "d": 64, "iters": 20,
                                   "threads": 2}}

    rw = _rw_mix_all(("noop", "disabled", "armed"))
    for state, (dt, ops, findings) in rw.items():
        results[f"rw_{state}"] = {"seconds": dt, "ops_per_sec": ops / dt,
                                  "findings": findings}
        emit(f"check_rw_mix_{state}", dt / ops * 1e6,
             f"ops_per_sec={ops / dt:.0f};findings={findings}")

    for state in ("noop", "disabled", "armed"):
        dt, findings = _logreg_fit(state)
        results[f"logreg_{state}"] = {"seconds": dt, "findings": findings}
        emit(f"check_logreg_{state}", dt * 1e6, f"findings={findings}")

    rw_overhead = (results["rw_disabled"]["seconds"]
                   / results["rw_noop"]["seconds"] - 1.0) * 100
    armed_overhead = (results["rw_armed"]["seconds"]
                      / results["rw_noop"]["seconds"] - 1.0) * 100
    lr_overhead = (results["logreg_disabled"]["seconds"]
                   / results["logreg_noop"]["seconds"] - 1.0) * 100
    results["disabled_overhead_pct_rw"] = rw_overhead
    results["armed_overhead_pct_rw"] = armed_overhead
    results["disabled_overhead_pct_logreg"] = lr_overhead
    results["acceptance_limit_pct"] = 5.0
    results["disabled_within_limit"] = rw_overhead <= 5.0
    emit("check_disabled_overhead_rw", 0.0,
         f"pct={rw_overhead:.2f};limit=5;ok={rw_overhead <= 5.0}")
    emit("check_armed_overhead_rw", 0.0, f"pct={armed_overhead:.2f}")

    write_bench("BENCH_check.json", results)
    assert stepcheck.armed_count() == 0, "benchmark leaked an armed checker"


if __name__ == "__main__":
    main()
