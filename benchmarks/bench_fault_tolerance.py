"""Paper Fig. 11: recovery time, single-node vs multi-node recovery.

K-means over a 16-thread pool; node killed at iteration 6; recovery reloads
the dead node's partitions and redoes the iteration on 1 survivor (single) vs
all survivors (multi).  Reports per-phase times like the paper (data loading
vs recomputation).
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from benchmarks.common import emit
from repro.analytics import kmeans
from repro.data import kmeans_dataset, partition_rows
from repro.ft import plan_recovery


def main():
    x, _, _ = kmeans_dataset(40000, 32, 16, seed=0)
    n_nodes, tpn = 4, 4
    n_threads = n_nodes * tpn

    # normal per-iteration time
    t0 = time.perf_counter()
    centers, _, _ = kmeans.fit_threads(x, 16, n_nodes=n_nodes, threads_per_node=tpn,
                                       iters=5, seed=0)
    per_iter_us = (time.perf_counter() - t0) / 5 * 1e6
    emit("ft_normal_iter", per_iter_us, "iters=5")

    tids_by_node = {n: [n * tpn + i for i in range(tpn)] for n in range(n_nodes)}
    failed = [1]

    for mode in ("single", "multi"):
        plan = plan_recovery(failed, list(range(n_nodes)), tids_by_node, mode=mode)
        # data loading: survivors re-read the dead node's partitions
        t0 = time.perf_counter()
        lost = [t for t in range(n_threads) if t in plan.reassignment]
        _reloaded = [x[slice(*partition_rows(x.shape[0], t, n_threads))].copy()
                     for t in lost]
        if mode == "single":
            pass  # one node does all the copies serially (already serial here)
        t_load = (time.perf_counter() - t0) * 1e6
        # recomputation: redo iteration 6 on the surviving pool
        t0 = time.perf_counter()
        kmeans.fit_threads(x, 16, n_nodes=len(plan.new_world),
                           threads_per_node=tpn if mode == "multi" else tpn * 2,
                           iters=1, seed=0)
        t_recompute = (time.perf_counter() - t0) * 1e6
        emit(f"ft_{mode}_recovery", t_load + t_recompute,
             f"load_us={t_load:.0f};recompute_us={t_recompute:.0f};"
             f"survivors={len(plan.new_world)}")


if __name__ == "__main__":
    main()
