"""step.obs overhead: the ≤5%-when-armed acceptance measurement.

Same protocol as ``bench_trace``/``bench_check``: the S=8 sharded concurrent
cached read/write mix plus a 2-thread host logreg fit, each timed under

* ``noop``     — no tracer attached anywhere (pre-step.trace baseline),
* ``disabled`` — tracer attached but off (the shipping default), and
* ``armed``    — a :class:`FlightRecorder` armed on that tracer, i.e. the
  tracer running in **record-only** mode: hists/counters accumulate and
  slow/always-record events land in the bounded ring, but no unbounded span
  list grows and fast spans early-return without taking the tracer lock.

The gate is ``armed``: the flight recorder exists to be left on in
production, so its rw-mix overhead must stay ≤5% over ``noop`` (full tracing
costs ~29% on the same mix — see BENCH_trace.json — which is exactly why
record-only mode exists).  Results land in ``benchmarks/BENCH_obs.json``.
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.bench_dsm_modes import _mixed_workload
from benchmarks.common import emit, write_bench
from repro.core import DSMCache, GlobalStore, Session, telemetry
from repro.core.telemetry import NULL_TRACER, Tracer
from repro.obs import FlightRecorder

STATES = ("noop", "disabled", "armed")


def _rw_mix_once(state: str, n_threads=8, n_names=64, ops_per_thread=120,
                 write_every=2):
    store = GlobalStore(shards=8)
    cache = DSMCache(store, n_nodes=n_threads, capacity=n_names)
    tracer = None
    recorder = None
    if state in ("disabled", "armed"):
        tracer = Tracer(enabled=False)
        store.tracer = tracer
        cache.tracer = tracer
    if state == "armed":
        recorder = FlightRecorder()
        recorder.attach(tracer)
    names = [f"v{i}" for i in range(n_names)]
    for n in names:
        store.new_array(n, (262144,))
    _mixed_workload(store, cache, names, n_threads, 20, write_every)  # warmup
    dt = _mixed_workload(store, cache, names, n_threads, ops_per_thread,
                         write_every)
    ring_held = 0
    if recorder is not None:
        ring_held = len(recorder.events())
        recorder.close()
    return dt, n_threads * ops_per_thread, ring_held


def _rw_mix_all(states, repeats=7, **kw):
    """Interleave states round-robin and keep each state's best run (the mix
    is dominated by payload writes and scheduling drift — see bench_trace)."""
    best = {}
    for _ in range(repeats):
        for state in states:
            dt, ops, ring = _rw_mix_once(state, **kw)
            if state not in best or dt < best[state][0]:
                best[state] = (dt, ops, ring)
    return best


def _logreg_fit(state: str, repeats=5):
    from repro.analytics import logreg

    rng = np.random.default_rng(0)
    x = rng.normal(size=(256, 64)).astype(np.float32)
    y = (rng.random(256) > 0.5).astype(np.float32)
    import time

    # absorb jit compilation before any state is timed
    logreg.fit(x, y, iters=2, n_nodes=2, threads_per_node=1)
    best = None
    for _ in range(repeats):
        sess = Session(backend="host", n_nodes=2, threads_per_node=1,
                       record=(state == "armed"))
        if state == "noop":
            sess.tracer = NULL_TRACER
        t0 = time.perf_counter()
        logreg.fit(x, y, iters=20, session=sess)
        dt = time.perf_counter() - t0
        ring = len(sess.recorder.events()) if state == "armed" else 0
        sess.recorder.close()
        sess.tracer.disable()
        if best is None or dt < best[0]:
            best = (dt, ring)
    return best


def main():
    assert telemetry.armed_count() == 0
    results = {"workload_rw": {"threads": 8, "shards": 8, "names": 64,
                               "ops_per_thread": 120, "vector_len": 262144},
               "workload_logreg": {"n": 256, "d": 64, "iters": 20,
                                   "threads": 2}}

    rw = _rw_mix_all(STATES)
    for state, (dt, ops, ring) in rw.items():
        results[f"rw_{state}"] = {"seconds": dt, "ops_per_sec": ops / dt,
                                  "ring_events": ring}
        emit(f"obs_rw_mix_{state}", dt / ops * 1e6,
             f"ops_per_sec={ops / dt:.0f};ring={ring}")

    for state in STATES:
        dt, ring = _logreg_fit(state)
        results[f"logreg_{state}"] = {"seconds": dt, "ring_events": ring}
        emit(f"obs_logreg_{state}", dt * 1e6, f"ring={ring}")

    rw_armed = (results["rw_armed"]["seconds"]
                / results["rw_noop"]["seconds"] - 1.0) * 100
    rw_disabled = (results["rw_disabled"]["seconds"]
                   / results["rw_noop"]["seconds"] - 1.0) * 100
    lr_armed = (results["logreg_armed"]["seconds"]
                / results["logreg_noop"]["seconds"] - 1.0) * 100
    results["armed_overhead_pct_rw"] = rw_armed
    results["disabled_overhead_pct_rw"] = rw_disabled
    results["armed_overhead_pct_logreg"] = lr_armed
    results["acceptance_limit_pct"] = 5.0
    results["armed_within_limit"] = rw_armed <= 5.0
    emit("obs_armed_overhead_rw", 0.0,
         f"pct={rw_armed:.2f};limit=5;ok={rw_armed <= 5.0}")
    emit("obs_armed_overhead_logreg", 0.0, f"pct={lr_armed:.2f}")

    write_bench("BENCH_obs.json", results)
    assert telemetry.armed_count() == 0, "benchmark leaked an armed recorder"


if __name__ == "__main__":
    main()
