"""step.trace overhead: the ≤5%-when-disabled acceptance measurement.

Two workloads, three tracer states each:

1. the S=8 sharded concurrent cached read/write mix from the shard sweep
   (the DSM hot path the tracer instruments most densely), and
2. a 2-thread host logreg fit (store + cache + accumulator + barrier paths
   together);

each timed under ``noop`` (no tracer attached anywhere — the pre-step.trace
baseline), ``disabled`` (tracers attached but off, the shipping default:
must cost ≤5% on the rw mix), and ``enabled`` (full recording, reported for
scale, not gated).  Results land in ``benchmarks/BENCH_trace.json``.
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.bench_dsm_modes import _mixed_workload
from benchmarks.common import emit, write_bench
from repro.core import DSMCache, GlobalStore, Session, telemetry
from repro.core.telemetry import NULL_TRACER, Tracer


def _rw_mix_once(state: str, n_threads=8, n_names=64, ops_per_thread=120,
                 write_every=2):
    store = GlobalStore(shards=8)
    cache = DSMCache(store, n_nodes=n_threads, capacity=n_names)
    tracer = None
    if state == "disabled":
        tracer = Tracer(enabled=False)
    elif state == "enabled":
        tracer = Tracer(enabled=True)
    if tracer is not None:
        store.tracer = tracer
        cache.tracer = tracer
    names = [f"v{i}" for i in range(n_names)]
    for n in names:
        store.new_array(n, (262144,))
    _mixed_workload(store, cache, names, n_threads, 20, write_every)  # warmup
    dt = _mixed_workload(store, cache, names, n_threads, ops_per_thread,
                         write_every)
    events = 0
    if tracer is not None:
        events = tracer.snapshot()["events"]
        tracer.disable()
    return dt, n_threads * ops_per_thread, events


def _rw_mix_all(states, repeats=7, **kw):
    """Interleave states round-robin and keep each state's best run: the mix
    is dominated by 1 MiB payload writes and thread scheduling, so
    back-to-back blocks would mostly measure machine drift, not the tracer."""
    best = {}
    for _ in range(repeats):
        for state in states:
            dt, ops, events = _rw_mix_once(state, **kw)
            if state not in best or dt < best[state][0]:
                best[state] = (dt, ops, events)
    return best


def _logreg_fit(state: str, repeats=5):
    from repro.analytics import logreg

    rng = np.random.default_rng(0)
    x = rng.normal(size=(256, 64)).astype(np.float32)
    y = (rng.random(256) > 0.5).astype(np.float32)
    import time

    # absorb jit compilation before any state is timed
    logreg.fit(x, y, iters=2, n_nodes=2, threads_per_node=1)
    best = None
    for _ in range(repeats):
        sess = Session(backend="host", n_nodes=2, threads_per_node=1,
                       trace=(state == "enabled"))
        if state == "noop":
            # strip even the disabled per-object tracers: the pre-step.trace
            # baseline had no tracer attribute lookups beyond the flag check
            sess.tracer = NULL_TRACER
        t0 = time.perf_counter()
        theta, _ = logreg.fit(x, y, iters=20, session=sess)
        dt = time.perf_counter() - t0
        events = sess.tracer.snapshot()["events"] if state == "enabled" else 0
        sess.tracer.disable()
        if best is None or dt < best[0]:
            best = (dt, events)
    return best


def main():
    assert telemetry.armed_count() == 0
    results = {"workload_rw": {"threads": 8, "shards": 8, "names": 64,
                               "ops_per_thread": 120, "vector_len": 262144},
               "workload_logreg": {"n": 256, "d": 64, "iters": 20,
                                   "threads": 2}}

    rw = _rw_mix_all(("noop", "disabled", "enabled"))
    for state, (dt, ops, events) in rw.items():
        results[f"rw_{state}"] = {"seconds": dt, "ops_per_sec": ops / dt,
                                  "events": events}
        emit(f"trace_rw_mix_{state}", dt / ops * 1e6,
             f"ops_per_sec={ops / dt:.0f};events={events}")

    for state in ("noop", "disabled", "enabled"):
        dt, events = _logreg_fit(state)
        results[f"logreg_{state}"] = {"seconds": dt, "events": events}
        emit(f"trace_logreg_{state}", dt * 1e6, f"events={events}")

    rw_overhead = (results["rw_disabled"]["seconds"]
                   / results["rw_noop"]["seconds"] - 1.0) * 100
    en_overhead = (results["rw_enabled"]["seconds"]
                   / results["rw_noop"]["seconds"] - 1.0) * 100
    lr_overhead = (results["logreg_disabled"]["seconds"]
                   / results["logreg_noop"]["seconds"] - 1.0) * 100
    results["disabled_overhead_pct_rw"] = rw_overhead
    results["enabled_overhead_pct_rw"] = en_overhead
    results["disabled_overhead_pct_logreg"] = lr_overhead
    results["acceptance_limit_pct"] = 5.0
    results["disabled_within_limit"] = rw_overhead <= 5.0
    emit("trace_disabled_overhead_rw", 0.0,
         f"pct={rw_overhead:.2f};limit=5;ok={rw_overhead <= 5.0}")
    emit("trace_enabled_overhead_rw", 0.0, f"pct={en_overhead:.2f}")

    write_bench("BENCH_trace.json", results)
    assert telemetry.armed_count() == 0, "benchmark leaked an enabled tracer"


if __name__ == "__main__":
    main()
