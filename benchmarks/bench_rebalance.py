"""step.tiers acceptance benchmark: live incremental rebalancing vs
stop-the-world, plus the no-cold-tier default-path overhead gate.

Three measurements on the S=8 concurrent read/write mix (the
``bench_dsm_modes`` shard-sweep workload):

1. **default-path overhead gate** — the exact PR 8 ``s8`` cell re-measured
   on the refactored (two-tier-capable) store with ``cold_tier=None``.
   Compared against the committed ``BENCH_shards.json`` baseline; the gate
   passes when current throughput is >= 95% of baseline.
2. **rebalance under load, incremental** — an ``add_shard`` lands mid-run
   with readers/writers flowing: max single reader/writer pause and the
   throughput dip while the migration window is open.
3. **rebalance under load, stop-the-world** — the same join via the legacy
   ``incremental=False`` path (every involved shard lock held for the whole
   move) for the pause/dip comparison.

Results go to ``benchmarks/BENCH_rebalance.json``.
"""

import json
import os
import sys
import threading
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, write_bench
from repro.core import DSMCache, GlobalStore

HERE = os.path.dirname(os.path.abspath(__file__))


# -- 1. default-path overhead gate -------------------------------------------


def _mixed_workload(store, cache, names, n_threads, ops_per_thread, write_every):
    """The bench_dsm_modes memoized S=8 mix, byte for byte: pre-resolved
    owner handles, 1 MiB payloads, every ``write_every``-th op a write."""
    payload = [np.full((262144,), float(t), np.float32) for t in range(n_threads)]
    handles = {name: store.owner_handle(name) for name in names}
    errs = []

    def worker(node):
        try:
            for i in range(ops_per_thread):
                name = names[(node * 31 + i) % len(names)]
                owner = handles[name]
                if i % write_every == node % write_every:
                    cache.write(node, name, payload[node], owner=owner)
                else:
                    cache.read(node, name, owner=owner)
        except Exception as e:  # pragma: no cover - surfaced below
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(n_threads)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errs:
        raise errs[0]
    return time.perf_counter() - t0


def _gate_sample(n_threads, n_names, ops_per_thread, write_every):
    store = GlobalStore(shards=8)                    # cold_tier=None default
    cache = DSMCache(store, n_nodes=n_threads, capacity=n_names)
    names = [f"v{i}" for i in range(n_names)]
    for n in names:
        store.new_array(n, (262144,))
    _mixed_workload(store, cache, names, n_threads, 20, write_every)  # warmup
    dt = sorted(_mixed_workload(store, cache, names, n_threads,
                                ops_per_thread, write_every)
                for _ in range(5))[2]
    return n_threads * ops_per_thread / dt


def overhead_gate(n_threads=8, n_names=64, ops_per_thread=240, write_every=2):
    """The committed baseline comes from ``BENCH_shards.json`` — regenerated
    by ``bench_dsm_modes`` earlier in the same ``benchmarks.run`` session, so
    both sides are measured minutes apart on the same machine.  Two samples
    (fresh store each) with the max taken guard against one-sided load
    drift between the two module runs."""
    samples = [_gate_sample(n_threads, n_names, ops_per_thread, write_every)
               for _ in range(2)]
    current = max(samples)
    baseline = None
    baseline_path = os.path.join(HERE, "BENCH_shards.json")
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)["s8"]["memoized_ops_per_sec"]
    except (OSError, KeyError, ValueError):
        pass
    row = {"current_ops_per_sec": current, "samples_ops_per_sec": samples,
           "baseline_ops_per_sec": baseline, "threshold": 0.95}
    if baseline:
        row["ratio"] = current / baseline
        row["pass"] = row["ratio"] >= row["threshold"]
        emit("rebalance_default_path_gate", 1e6 / current,
             f"ratio={row['ratio']:.3f};pass={row['pass']}")
    else:
        emit("rebalance_default_path_gate", 1e6 / current,
             "baseline=missing")
    return row


# -- 2/3. rebalance under live load -------------------------------------------


def rebalance_under_load(incremental, n_threads=4, n_names=2048,
                         steady_s=0.4, join_id=17):
    """S=8 rw mix with an ``add_shard`` landing mid-run.  Every op records
    (start, duration); the window timestamps split steady-state ops from the
    ops that overlapped the migration.  Many small entries keep single ops
    fast (~tens of µs) while giving the join a real arc to move — the
    regime where stop-the-world visibly freezes every worker and the
    incremental window should not."""
    store = GlobalStore(shards=8)
    cache = DSMCache(store, n_nodes=n_threads, capacity=n_names)
    names = [f"r{i}" for i in range(n_names)]
    for n in names:
        store.new_array(n, (256,))
    handles = {n: store.owner_handle(n) for n in names}
    payload = [np.full((256,), float(t), np.float32)
               for t in range(n_threads)]
    stop = threading.Event()
    ops = [[] for _ in range(n_threads)]             # (t_start, dt) per thread
    errs = []

    def worker(node):
        lat = ops[node]
        i = 0
        try:
            while not stop.is_set():
                name = names[(node * 31 + i) % len(names)]
                t0 = time.perf_counter()
                if i % 2 == node % 2:
                    cache.write(node, name, payload[node], owner=handles[name])
                else:
                    cache.read(node, name, owner=handles[name])
                lat.append((t0, time.perf_counter() - t0))
                i += 1
        except Exception as e:  # pragma: no cover - surfaced below
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    time.sleep(steady_s)
    t_mig0 = time.perf_counter()
    mig = store.add_shard(join_id, incremental=incremental)  # drains inline
    t_mig1 = time.perf_counter()
    time.sleep(steady_s)
    stop.set()
    for t in threads:
        t.join()
    if errs:
        raise errs[0]
    flat = [x for lane in ops for x in lane]
    steady = sorted(dt for t0, dt in flat if t0 + dt < t_mig0 or t0 > t_mig1)
    during = sorted(dt for t0, dt in flat
                    if t0 <= t_mig1 and t0 + dt >= t_mig0)
    steady_span = 2 * steady_s
    mig_span = max(t_mig1 - t_mig0, 1e-9)
    steady_rate = len(steady) / steady_span
    during_rate = len(during) / mig_span

    def pct(lat, q):
        return lat[min(int(q * len(lat)), len(lat) - 1)] if lat else 0.0

    return {"mode": "incremental" if incremental else "stop_the_world",
            "entries_moved": len(mig.moved),
            "bytes_moved": mig.bytes_moved,
            "window_s": mig.window_s,
            "reader_pulls": mig.pulled,
            "max_op_pause_s": max(during, default=0.0),
            "p99_op_pause_s": pct(during, 0.99),
            "p50_op_pause_s": pct(during, 0.50),
            "steady_max_op_s": max(steady, default=0.0),
            "steady_p99_op_s": pct(steady, 0.99),
            "steady_ops_per_sec": steady_rate,
            "during_ops_per_sec": during_rate,
            "throughput_dip": 1.0 - min(during_rate / max(steady_rate, 1e-9),
                                        1.0)}


def main():
    # a 0.5ms GIL quantum keeps scheduler starvation out of the pause
    # measurement — what remains is actual lock blocking
    sys.setswitchinterval(0.0005)
    results = {"workload": {"gate_threads": 8, "gate_names": 64,
                            "rebalance_threads": 4, "rebalance_names": 2048,
                            "write_every": 2,
                            "gil_switch_interval_s": 0.0005}}
    results["overhead_gate"] = overhead_gate()
    inc = rebalance_under_load(True)
    stw = rebalance_under_load(False)
    results["incremental"] = inc
    results["stop_the_world"] = stw
    results["pause_ratio_stw_over_incremental"] = (
        stw["max_op_pause_s"] / max(inc["max_op_pause_s"], 1e-9))
    for row in (inc, stw):
        emit(f"rebalance_{row['mode']}", row["window_s"] * 1e6,
             f"moved={row['entries_moved']};"
             f"max_pause_ms={row['max_op_pause_s'] * 1e3:.2f};"
             f"dip={row['throughput_dip']:.2f}")
    emit("rebalance_pause_ratio", 0.0,
         f"stw_over_incremental={results['pause_ratio_stw_over_incremental']:.2f}x")
    write_bench("BENCH_rebalance.json", results)


if __name__ == "__main__":
    main()
