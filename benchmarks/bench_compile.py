"""Compile-cost of the SPMD `ctx.iterate` scan path vs trip count.

The point of lowering `ctx.iterate` to one ``lax.scan`` is that the traced
program — and therefore trace+lower and XLA compile wall-time — is O(1) in
``iters`` instead of O(iters) unrolled HLO.  This benchmark measures the
paper's §4.5 logreg step at iters ∈ {2, 32, 256}: per point it reports
trace+lower time, compile time and the lowered line count (which must be
constant), and writes the whole table to ``benchmarks/BENCH_compile.json``
so the perf trajectory has data across PRs.

    PYTHONPATH=src python -m benchmarks.bench_compile
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from benchmarks.common import emit, write_bench
from repro.core import Session
from repro.core.compat import cost_analysis

ITERS_SWEEP = (2, 32, 256)
N_ROWS, N_FEATURES = 256, 64


def _program(sess, grad, iters: int):
    """The §4.5 logreg round as a `ctx.iterate` step function."""

    def thread_proc(ctx, xs, ys):
        def step(theta):
            total = grad.accumulate((ys - 1.0 / (1.0 + jnp.exp(-(xs @ theta)))) @ xs)
            return theta + 1e-3 * total

        return ctx.iterate(step, jnp.zeros((N_FEATURES,), jnp.float32), iters)

    return thread_proc


def main():
    xs = jnp.ones((N_ROWS, N_FEATURES), jnp.float32)
    ys = jnp.ones((N_ROWS,), jnp.float32)
    rows = {}
    for iters in ITERS_SWEEP:
        sess = Session(backend="spmd")
        grad = sess.new_array("grad", (N_FEATURES,))
        proc = _program(sess, grad, iters)
        t0 = time.perf_counter()
        lowered = sess.lower(proc, data=(xs, ys))
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        rows[str(iters)] = {
            "trace_lower_s": t1 - t0,
            "compile_s": t2 - t1,
            "lowered_lines": len(lowered.as_text().splitlines()),
            "flops": cost_analysis(compiled).get("flops"),
        }
        emit(f"compile_iters{iters}", (t2 - t0) * 1e6,
             f"lines={rows[str(iters)]['lowered_lines']}")

    lines = {r["lowered_lines"] for r in rows.values()}
    rows["constant_program_size"] = len(lines) == 1
    out = write_bench("BENCH_compile.json", rows)
    print(f"# wrote {out} (constant_program_size={rows['constant_program_size']})",
          flush=True)


if __name__ == "__main__":
    main()
