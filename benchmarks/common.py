"""Benchmark helpers: timing, CSV emission (``name,us_per_call,derived``) and
provenance-stamped ``BENCH_*.json`` writing.

Every BENCH file written through :func:`write_bench` carries a ``provenance``
record with ``{host, commit, config}`` so a committed number can always be
traced back to the machine, revision and toolchain that produced it
(``tests/test_bench_schema.py`` pins this for every BENCH_*.json in the repo).
"""

from __future__ import annotations

import json
import os
import platform
import subprocess
import time
from typing import Any, Callable, Dict

HERE = os.path.dirname(os.path.abspath(__file__))


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def provenance(**config: Any) -> Dict[str, Any]:
    """``{host, commit, config}`` for a BENCH file.

    ``config`` always records the python and jax versions; callers extend it
    with workload knobs via keyword arguments.  Never raises — a missing git
    binary or a non-repo checkout degrades to ``commit: "unknown"``.
    """
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=HERE, capture_output=True,
            text=True, timeout=10).stdout.strip() or "unknown"
    except Exception:
        commit = "unknown"
    cfg: Dict[str, Any] = {"python": platform.python_version()}
    try:
        import jax
        cfg["jax"] = jax.__version__
    except Exception:
        pass
    cfg.update(config)
    return {"host": platform.node() or "unknown", "commit": commit,
            "config": cfg}


def write_bench(filename: str, results: Dict[str, Any], **config: Any) -> str:
    """Write ``results`` + a :func:`provenance` record to
    ``benchmarks/<filename>`` and return the path."""
    payload = dict(results)
    payload["provenance"] = provenance(**config)
    path = filename if os.path.isabs(filename) else os.path.join(HERE, filename)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path
