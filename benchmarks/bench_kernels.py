"""Pallas kernel micro-benchmarks (interpret mode on CPU) vs jnp oracles.

On CPU, interpret-mode kernels are expected to be SLOWER than the fused jnp
oracle — the numbers here are correctness/overhead tracking, not TPU perf;
the TPU target engages via Mosaic on real hardware.  Derived column carries
the oracle time for the ratio.

Three hot-path sweeps additionally land in ``benchmarks/BENCH_kernels.json``:

* ``fused_density_sweep`` — the accumulator round at the bench shape
  (N=4, V=16384, k=512): one fused sparsify→scatter-add launch vs the
  historical compress→densify→add chain vs the jnp reference, across the
  same nnz densities as BENCH_accumulator.json;
* ``topk_methods`` — bitonic partial sort vs the k×(argmax→mask) loop in
  ``topk_compress`` over k_per_block ∈ {16, 64, 256};
* ``owner_memo`` — ``store.get`` with a pre-resolved :class:`OwnerHandle`
  vs re-hashing the ring on every call (S=8).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit, write_bench

RESULTS = {}


def fused_density_sweep():
    """Fused one-launch accumulate vs compress→densify→add, per density."""
    from repro.core.sparse import DEFAULT_BLOCK, blocked_topk_accumulate
    N, V, k = 4, 1 << 14, 512
    rng = np.random.default_rng(0)
    sweep = {"shape": {"n": N, "v": V, "k": k, "block": DEFAULT_BLOCK}}
    for density in (0.001, 0.01, 0.03, 0.25, 1.0):
        mat = rng.normal(size=(N, V)).astype(np.float32)
        mat[rng.random((N, V)) >= density] = 0.0
        mat = jnp.asarray(mat)
        us_fused = timeit(lambda: jax.block_until_ready(
            blocked_topk_accumulate(mat, k, fused=True, impl="pallas")),
            warmup=2, iters=5)
        us_unfused = timeit(lambda: jax.block_until_ready(
            blocked_topk_accumulate(mat, k, fused=False)),
            warmup=2, iters=5)
        us_jnp = timeit(lambda: jax.block_until_ready(
            blocked_topk_accumulate(mat, k, fused=True, impl="jnp")),
            warmup=2, iters=5)
        speedup = us_unfused / max(us_fused, 1e-9)
        sweep[str(density)] = {"fused_us": us_fused, "unfused_us": us_unfused,
                               "jnp_us": us_jnp,
                               "speedup_fused_over_unfused": speedup}
        emit(f"fused_accum_density{density}", us_fused,
             f"unfused_us={us_unfused:.0f};jnp_us={us_jnp:.0f};"
             f"speedup={speedup:.2f}x")
    speeds = [row["speedup_fused_over_unfused"]
              for key, row in sweep.items() if key != "shape"]
    sweep["min_speedup"] = min(speeds)
    emit("fused_accum_min_speedup", 0.0, f"{sweep['min_speedup']:.2f}x")
    RESULTS["fused_density_sweep"] = sweep


def topk_methods_sweep():
    """Bitonic partial sort vs the argmax loop, k_per_block ∈ {16, 64, 256}."""
    from repro.kernels.topk_compress.ops import topk_compress
    V, block_v = 1 << 14, 1024
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(V,)), jnp.float32)
    sweep = {"shape": {"v": V, "block_v": block_v}}
    for k in (16, 64, 256):
        row = {}
        for method in ("argmax", "bitonic"):
            us = timeit(lambda: jax.block_until_ready(tuple(
                topk_compress(x, k_per_block=k, block_v=block_v,
                              method=method))), warmup=2, iters=5)
            row[f"{method}_us"] = us
        row["speedup_bitonic_over_argmax"] = (row["argmax_us"]
                                              / max(row["bitonic_us"], 1e-9))
        sweep[f"k{k}"] = row
        emit(f"topk_k{k}_bitonic", row["bitonic_us"],
             f"argmax_us={row['argmax_us']:.0f};"
             f"speedup={row['speedup_bitonic_over_argmax']:.2f}x")
    RESULTS["topk_methods"] = sweep


def owner_memo_bench():
    """store.get with a pre-resolved OwnerHandle vs re-hashing every call."""
    from repro.core import GlobalStore
    n_names, iters = 64, 50
    store = GlobalStore(shards=8)
    names = [f"v{i}" for i in range(n_names)]
    for n in names:
        store.def_global(n, float(len(n)))
    handles = {n: store.owner_handle(n) for n in names}

    def hashed():
        for n in names:
            store.get(n)

    def memoized():
        for n in names:
            store.get(n, owner=handles[n])

    us_hash = timeit(hashed, warmup=2, iters=iters)
    us_memo = timeit(memoized, warmup=2, iters=iters)
    speedup = us_hash / max(us_memo, 1e-9)
    RESULTS["owner_memo"] = {"shards": 8, "names": n_names,
                             "hashed_us": us_hash, "memoized_us": us_memo,
                             "speedup_memo_over_hash": speedup}
    emit("owner_memo_get", us_memo,
         f"hashed_us={us_hash:.1f};speedup={speedup:.2f}x")


def main():
    rng = np.random.default_rng(0)

    from repro.kernels.flash_attention.ops import flash_attention as fa
    from repro.models.attention import naive_attention
    q = jnp.asarray(rng.normal(size=(1, 256, 2, 2, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    us_k = timeit(lambda: jax.block_until_ready(fa(q, k, v, causal=True)), iters=3)
    ref = jax.jit(lambda a, b, c: naive_attention(a, b, c, causal=True))
    us_r = timeit(lambda: jax.block_until_ready(ref(q, k, v)), iters=3)
    emit("kernel_flash_attention", us_k, f"oracle_us={us_r:.0f}")

    from repro.kernels.accumulate.ops import accumulate as acc
    from repro.kernels.accumulate.ref import accumulate_ref
    x = jnp.asarray(rng.normal(size=(16, 65536)), jnp.float32)
    us_k = timeit(lambda: jax.block_until_ready(acc(x)), iters=3)
    refj = jax.jit(accumulate_ref)
    us_r = timeit(lambda: jax.block_until_ready(refj(x)), iters=3)
    emit("kernel_accumulate", us_k, f"oracle_us={us_r:.0f}")

    from repro.kernels.topk_compress.ops import topk_compress
    v1 = jnp.asarray(rng.normal(size=(65536,)), jnp.float32)
    us_k = timeit(lambda: jax.block_until_ready(topk_compress(v1, k_per_block=16)), iters=3)
    emit("kernel_topk_compress", us_k, "k_per_block=16")

    from repro.kernels.sparse_update.ops import scatter_add
    idx = jnp.asarray(rng.integers(0, 65536, size=(1024,)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(1024,)), jnp.float32)
    us_k = timeit(lambda: jax.block_until_ready(scatter_add(idx, vals, out_len=65536)), iters=3)
    emit("kernel_sparse_update", us_k, "M=1024,V=65536")

    from repro.kernels.kmeans_assign.ops import kmeans_assign
    pts = jnp.asarray(rng.normal(size=(8192, 64)), jnp.float32)
    ctr = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    us_k = timeit(lambda: jax.block_until_ready(kmeans_assign(pts, ctr)), iters=3)
    emit("kernel_kmeans_assign", us_k, "N=8192,K=32,D=64")

    from repro.kernels.ssd_scan.ops import ssd
    from repro.models.mamba import ssd_chunked
    b, T, H, P, G, N = 1, 512, 4, 32, 1, 32
    xs = jnp.asarray(rng.normal(size=(b, T, H, P)), jnp.float32) * 0.3
    dt = jnp.asarray(np.abs(rng.normal(size=(b, T, H))) * 0.3 + 0.1, jnp.float32)
    A_log = jnp.asarray(np.log(np.linspace(1.0, 4.0, H)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, T, G, N)), jnp.float32) * 0.3
    C = jnp.asarray(rng.normal(size=(b, T, G, N)), jnp.float32) * 0.3
    us_k = timeit(lambda: jax.block_until_ready(ssd(xs, dt, A_log, B, C, chunk=64)[0]), iters=3)
    refj = jax.jit(lambda *a: ssd_chunked(*a, chunk=64)[0])
    us_r = timeit(lambda: jax.block_until_ready(refj(xs, dt, A_log, B, C)), iters=3)
    emit("kernel_ssd_scan", us_k, f"oracle_us={us_r:.0f}")

    fused_density_sweep()
    topk_methods_sweep()
    owner_memo_bench()
    out = write_bench("BENCH_kernels.json", RESULTS)
    print(f"# wrote {out}", flush=True)


if __name__ == "__main__":
    main()
