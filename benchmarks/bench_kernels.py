"""Pallas kernel micro-benchmarks (interpret mode on CPU) vs jnp oracles.

On CPU, interpret-mode kernels are expected to be SLOWER than the fused jnp
oracle — the numbers here are correctness/overhead tracking, not TPU perf;
the TPU target engages via Mosaic on real hardware.  Derived column carries
the oracle time for the ratio.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit


def main():
    rng = np.random.default_rng(0)

    from repro.kernels.flash_attention.ops import flash_attention as fa
    from repro.models.attention import naive_attention
    q = jnp.asarray(rng.normal(size=(1, 256, 2, 2, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    us_k = timeit(lambda: jax.block_until_ready(fa(q, k, v, causal=True)), iters=3)
    ref = jax.jit(lambda a, b, c: naive_attention(a, b, c, causal=True))
    us_r = timeit(lambda: jax.block_until_ready(ref(q, k, v)), iters=3)
    emit("kernel_flash_attention", us_k, f"oracle_us={us_r:.0f}")

    from repro.kernels.accumulate.ops import accumulate as acc
    from repro.kernels.accumulate.ref import accumulate_ref
    x = jnp.asarray(rng.normal(size=(16, 65536)), jnp.float32)
    us_k = timeit(lambda: jax.block_until_ready(acc(x)), iters=3)
    refj = jax.jit(accumulate_ref)
    us_r = timeit(lambda: jax.block_until_ready(refj(x)), iters=3)
    emit("kernel_accumulate", us_k, f"oracle_us={us_r:.0f}")

    from repro.kernels.topk_compress.ops import topk_compress
    v1 = jnp.asarray(rng.normal(size=(65536,)), jnp.float32)
    us_k = timeit(lambda: jax.block_until_ready(topk_compress(v1, k_per_block=16)), iters=3)
    emit("kernel_topk_compress", us_k, "k_per_block=16")

    from repro.kernels.sparse_update.ops import scatter_add
    idx = jnp.asarray(rng.integers(0, 65536, size=(1024,)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(1024,)), jnp.float32)
    us_k = timeit(lambda: jax.block_until_ready(scatter_add(idx, vals, out_len=65536)), iters=3)
    emit("kernel_sparse_update", us_k, "M=1024,V=65536")

    from repro.kernels.kmeans_assign.ops import kmeans_assign
    pts = jnp.asarray(rng.normal(size=(8192, 64)), jnp.float32)
    ctr = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    us_k = timeit(lambda: jax.block_until_ready(kmeans_assign(pts, ctr)), iters=3)
    emit("kernel_kmeans_assign", us_k, "N=8192,K=32,D=64")

    from repro.kernels.ssd_scan.ops import ssd
    from repro.models.mamba import ssd_chunked
    b, T, H, P, G, N = 1, 512, 4, 32, 1, 32
    xs = jnp.asarray(rng.normal(size=(b, T, H, P)), jnp.float32) * 0.3
    dt = jnp.asarray(np.abs(rng.normal(size=(b, T, H))) * 0.3 + 0.1, jnp.float32)
    A_log = jnp.asarray(np.log(np.linspace(1.0, 4.0, H)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, T, G, N)), jnp.float32) * 0.3
    C = jnp.asarray(rng.normal(size=(b, T, G, N)), jnp.float32) * 0.3
    us_k = timeit(lambda: jax.block_until_ready(ssd(xs, dt, A_log, B, C, chunk=64)[0]), iters=3)
    refj = jax.jit(lambda *a: ssd_chunked(*a, chunk=64)[0])
    us_r = timeit(lambda: jax.block_until_ready(refj(xs, dt, A_log, B, C)), iters=3)
    emit("kernel_ssd_scan", us_k, f"oracle_us={us_r:.0f}")


if __name__ == "__main__":
    main()
