"""Fault-tolerance drill (paper §5.4 + Fig. 11), on the `step.Session` facade.

Runs distributed K-means sessions, kills a node via the heartbeat monitor,
and recovers twice — single-node vs multi-node recovery — through
``ft.session_recovery``, which replans thread placement over the survivors
and rolls a fresh Session onto the surviving DSM.  With a sharded store
(``shards=n_nodes``), recovery also removes the dead node's shard from the
consistent-hash ring: only its ~1/S of keys migrate to survivors, epochs
intact.  Then demonstrates checkpoint/rollback exactness for the shared
state.

    PYTHONPATH=src python examples/fault_tolerance_drill.py
"""

import tempfile
import time

import numpy as np

from repro.analytics import kmeans
from repro.core import Session
from repro.data import kmeans_dataset
from repro.ft import HeartbeatMonitor, save_checkpoint, restore_checkpoint, session_recovery


def main():
    x, _, _ = kmeans_dataset(4000, 16, 8, seed=0)
    n_nodes, tpn = 4, 2

    # -- failure detection ---------------------------------------------------
    failures = []
    mon = HeartbeatMonitor(list(range(n_nodes)), timeout=0.2,
                           on_failure=lambda dead: failures.append(dead))
    mon.start()
    for node in range(n_nodes):
        mon.beat(node)
    mon.declare_dead(2)   # drill: node 2 dies
    time.sleep(0.1)
    mon.stop()
    print(f"heartbeat detected failures: {failures}")

    # -- recovery planning: single vs multi (Fig. 11) --------------------------
    for mode in ("single", "multi"):
        failed_session = Session(backend="host", n_nodes=n_nodes,
                                 threads_per_node=tpn, shards=n_nodes)
        kmeans.fit(x, 8, iters=1, seed=0, session=failed_session)
        plan, recovered = session_recovery(
            failed_session, failures[0] if failures else [2], mode=mode,
            threads_per_node=tpn if mode == "multi" else tpn * 2)
        t0 = time.time()
        # recovery = reload the dead node's partitions + recompute one iteration
        centers, _ = kmeans.fit(x, 8, iters=1, seed=0, session=recovered)
        dt = (time.time() - t0) * 1e3
        mig = plan.migration
        moved = (f"ring: moved {len(mig.moved)}/{mig.total_names} keys off "
                 f"shard {mig.removed}" if mig else "ring: unchanged")
        print(f"{mode:>6s}-node recovery: reassign {plan.reassignment} "
              f"redo-iteration {dt:.0f}ms  {moved}")

    # -- checkpoint/rollback exactness ------------------------------------------
    with tempfile.TemporaryDirectory() as d:
        centers1, _ = kmeans.fit(x, 8, n_nodes=2, threads_per_node=2,
                                 iters=6, seed=0)
        save_checkpoint(d, 6, {"centers": centers1})
        restored, _, step = restore_checkpoint(d, {"centers": centers1})
        assert np.allclose(restored["centers"], centers1)
        print(f"checkpoint at iter {step} restores bit-exact: True")


if __name__ == "__main__":
    main()
