"""PageRank over a power-law graph (paper §6.7) with the auto accumulator.

Shows the paper's sparse/auto accumulator decision in action: threads owning
edges with concentrated destinations produce sparse credit vectors, and the
``auto`` mode ships (index, value) pairs only when cheaper.  Everything runs
through the Session facade with the iteration written via ``ctx.iterate`` —
swap ``backend="spmd"`` to put the same workload on a device mesh, where the
loop lowers to one ``lax.scan`` instead of unrolling.

    PYTHONPATH=src python examples/pagerank_graph.py
"""

import numpy as np

from repro.analytics import pagerank
from repro.core import AccumMode
from repro.data import powerlaw_graph


def main():
    n_vertices = 2000
    edges = powerlaw_graph(n_vertices, avg_degree=8, seed=0)
    print(f"graph: {n_vertices} vertices, {edges.shape[0]} edges")

    ref = pagerank.fit_reference(edges, n_vertices, iters=15)
    for mode in (AccumMode.GATHER_ALL, AccumMode.REDUCE_SCATTER, AccumMode.AUTO):
        ranks, sess = pagerank.fit(edges, n_vertices, backend="host", n_nodes=2,
                                   threads_per_node=2, iters=15, mode=mode)
        drift = float(np.max(np.abs(ranks - ref)))
        print(f"[{mode.value:>14s}] top vertex {int(np.argmax(ranks))} "
              f"drift {drift:.2e} wire {sess.wire_traffic():>9d} elems")
    print("top-5 ranked vertices:", np.argsort(-ref)[:5].tolist())


if __name__ == "__main__":
    main()
