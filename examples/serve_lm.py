"""Serve a small LM with batched requests: prefill + decode with KV caches.

The DSM-cache analogy in action (DESIGN.md §2): the KV cache is the
device-local replica the paper's DSM cache kept per node — written through at
every decode step, never invalidated because the owner is the only writer.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-4b --batch 8
"""

import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    toks = serve(args.arch, smoke=True, batch=args.batch,
                 prompt_len=args.prompt_len, gen=args.gen)
    print(f"[serve_lm] generated {toks.shape[0]}×{toks.shape[1]} tokens; "
          f"first request: {toks[0][:10].tolist()}")


if __name__ == "__main__":
    main()
