"""Quickstart: the STEP-JAX stack in ~40 lines.

Declares shared state in a GlobalStore (the DSM), runs the paper's worked
example — distributed-multi-threaded logistic regression with the
DAddAccumulator — then trains a tiny LM end-to-end through the production
step builder.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.analytics import logreg
from repro.core import AccumMode, GlobalStore
from repro.data import logreg_dataset


def main():
    # 1. DSM + shared data (paper §4.1)
    store = GlobalStore(granularity="coarse")
    store.def_global("step_size", 1e-3)
    store.new_array("grad", (32,))
    print(f"DSM declared: {store.names()}, grad addr=0x{store.address('grad'):x}")

    # 2. the paper's §4.5 example: distributed multi-threaded logistic regression
    x, y, _ = logreg_dataset(n_rows=800, n_features=32, seed=0)
    theta, store2, accu = logreg.fit_threads(
        x, y, n_nodes=2, threads_per_node=2, iters=15, lr=1e-3,
        mode=AccumMode.REDUCE_SCATTER)
    print(f"logreg loss: {logreg.loss(theta, x, y):.4f} "
          f"(accumulator wire traffic: {accu.bytes_transferred} elements, "
          f"(N+1)·V·iters = {(4 + 1) * 32 * 15})")

    # 3. a tiny LM through the production trainer
    from repro.launch.train import train
    losses = train("qwen3-1.7b", smoke=True, steps=10, batch=4, seq=64)
    print(f"LM train: loss {losses[0]:.3f} → {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
