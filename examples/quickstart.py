"""Quickstart: the STEP-JAX stack in ~40 lines, through the `step.Session` facade.

One `Session` object is the whole Table-1 API: shared state is declared with
``def_global``/``new_array`` and handled via typed `SharedRef` handles
(``.get()/.set()/.inc()/.accumulate()``), threads are spawned with
``session.run``, and the *same* workload code executes on the host backend
(paper-faithful DThreads + blocking accumulator) or the SPMD backend
(shard_map over a device mesh) — pick one at ``Session(backend=...)``.
Per-thread loops are written with ``ctx.iterate(step, carry, iters)``: a
guarded Python loop on the host backend, a single ``lax.scan`` under SPMD
(compile time O(1) in ``iters``).  The script declares shared state, runs a
tiny ``ctx.iterate`` program and the paper's worked example (distributed
multi-threaded logistic regression) on both backends, then trains a tiny LM
end-to-end through the production step builder.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.analytics import logreg
from repro.core import AccumMode, Session
from repro.data import logreg_dataset


def main():
    # 1. the Table-1 facade: DSM declaration through handles (paper §4.1)
    sess = Session(backend="host", n_nodes=2, threads_per_node=2)
    step_size = sess.def_global("step_size", 1e-3)
    grad = sess.new_array("grad", (32,))
    print(f"DSM declared: {sess.names()}, grad addr=0x{grad.address:x}, "
          f"step_size={float(step_size.get()):g}")

    # 1b. the iteration engine: one logical loop, two lowerings — a guarded
    # Python loop here on the host backend, one lax.scan under SPMD.
    total = sess.new_array("total", ())

    def count_rounds(ctx):
        return ctx.iterate(lambda c: c + total.accumulate(jnp.float32(1.0)),
                           jnp.float32(0.0), 5)

    per_thread = sess.run(count_rounds)
    print(f"ctx.iterate: 5 rounds x {sess.backend.n_threads} threads -> "
          f"carry {float(per_thread[0]):g} per thread")

    # 2. the paper's §4.5 example on BOTH backends — same thread_proc
    x, y, _ = logreg_dataset(n_rows=800, n_features=32, seed=0)
    theta, hsess = logreg.fit(x, y, backend="host", n_nodes=2, threads_per_node=2,
                              iters=15, lr=1e-3, mode=AccumMode.REDUCE_SCATTER)
    print(f"logreg[host] loss: {logreg.loss(theta, x, y):.4f} "
          f"(accumulator wire traffic: {hsess.wire_traffic()} elements, "
          f"(N+1)·V·iters = {(4 + 1) * 32 * 15})")
    theta_s, ssess = logreg.fit(x, y, backend="spmd", iters=15, lr=1e-3)
    print(f"logreg[spmd] loss: {logreg.loss(theta_s, x, y):.4f} "
          f"drift vs host {float(np.max(np.abs(theta_s - theta))):.2e}")

    # 3. a tiny LM through the production trainer
    from repro.launch.train import train
    losses = train("qwen3-1.7b", smoke=True, steps=10, batch=4, seq=64)
    print(f"LM train: loss {losses[0]:.3f} → {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
