"""Race demo: step.check catching an unsynchronized read-modify-write.

Two host threads both run the classic racy counter update

    v = counter.get()          # read
    counter.set(v + tid + 1)   # write computed from a stale read

with no barrier between them, so the two RMWs are unordered in the
happens-before order the checker tracks (only spawn/join edges exist) and the
written values differ per thread — a textbook lost-update race.  Armed via
``Session(check=True)``, the vector-clock detector flags the unordered
read/write and write/write pairs and reports *both* stack sites.

The second half runs the fixed program — same update, but each thread owns a
disjoint round via a DBarrier hand-off — and shows the checker stays silent.

    PYTHONPATH=src python examples/race_demo.py
"""

import jax.numpy as jnp

from repro.core import Session


def racy():
    sess = Session(backend="host", n_nodes=1, threads_per_node=2, check=True)
    counter = sess.def_global("counter", jnp.float32(0))

    def proc(ctx):
        for _ in range(4):
            v = counter.get()                       # site A: racy read
            counter.set(v + jnp.float32(ctx.tid + 1))   # site B: racy write
        return None

    sess.run(proc)
    findings = sess.findings()
    print(f"racy program: {len(findings)} finding(s)")
    for f in findings:
        print(f"  [{f.kind}] {f.message}")
        for site in f.sites:
            print(f"      site: {site}")
    sess.checker.disable()
    return findings


def synchronized():
    sess = Session(backend="host", n_nodes=1, threads_per_node=2, check=True)
    counter = sess.def_global("counter", jnp.float32(0))
    bar = sess.barrier()

    def proc(ctx):
        # alternate turns: tid 0 updates on even rounds, tid 1 on odd ones,
        # with a barrier between rounds ordering every access pair
        for r in range(4):
            if r % 2 == ctx.tid:
                v = counter.get()
                counter.set(v + jnp.float32(ctx.tid + 1))
            bar.enter()
        return None

    sess.run(proc)
    findings = sess.findings()
    print(f"synchronized program: {len(findings)} finding(s)")
    sess.checker.disable()
    return findings


def main():
    racy_findings = racy()
    clean_findings = synchronized()
    assert racy_findings, "the seeded race must be detected"
    assert any({s.split(":")[0] for s in f.sites} and len(f.sites) >= 2
               for f in racy_findings), "both access sites must be reported"
    assert not clean_findings, "the barrier-ordered program must be clean"
    print("ok: race flagged with both sites; synchronized variant clean")


if __name__ == "__main__":
    main()
