"""Paper §4.5 end-to-end: logistic regression three ways, one workload.

1. fit_reference        — single-thread oracle
2. fit(backend="host")  — the paper's DThread + DSM + accumulator program
3. fit(backend="spmd")  — the same thread_proc as shard_map over a mesh

All three produce identical parameters (the accumulator is exact), which is
the point: the STEP programming model is a *semantics-preserving* distribution
of the sequential program, and the Session facade makes the substrate a
constructor argument instead of a rewrite.  The workload's loop is written
once with ``ctx.iterate``; on the SPMD backend it lowers to one ``lax.scan``,
so the printed iteration count is free at compile time (O(1) program size).

    PYTHONPATH=src python examples/logistic_regression.py
"""

import numpy as np

from repro.analytics import logreg
from repro.core import AccumMode
from repro.data import logreg_dataset


def main():
    x, y, theta_true = logreg_dataset(n_rows=2000, n_features=64, seed=0)

    ref = logreg.fit_reference(x, y, iters=20, lr=1e-3)
    print(f"reference loss: {logreg.loss(ref, x, y):.4f}")

    for mode in (AccumMode.GATHER_ALL, AccumMode.REDUCE_SCATTER, AccumMode.AUTO):
        theta, sess = logreg.fit(x, y, backend="host", n_nodes=2,
                                 threads_per_node=2, iters=20, lr=1e-3, mode=mode)
        drift = float(np.max(np.abs(theta - ref)))
        print(f"host[{mode.value:>14s}] loss {logreg.loss(theta, x, y):.4f} "
              f"drift {drift:.2e} wire {sess.wire_traffic():>8d} elems")

    spmd, sess = logreg.fit(x, y, backend="spmd", iters=20, lr=1e-3)
    print(f"spmd[{sess.backend.n_threads} threads] loss: "
          f"{logreg.loss(spmd, x, y):.4f} "
          f"drift {float(np.max(np.abs(spmd - ref))):.2e} "
          f"wire {sess.wire_traffic():>8d} elems")


if __name__ == "__main__":
    main()
