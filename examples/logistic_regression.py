"""Paper §4.5 end-to-end: logistic regression three ways.

1. fit_reference — single-thread oracle
2. fit_threads   — the paper's DThread + DSM + DAddAccumulator program
3. fit_spmd      — the same STEP program as shard_map over a device mesh

All three produce identical parameters (the accumulator is exact), which is
the point: the STEP programming model is a *semantics-preserving* distribution
of the sequential program.

    PYTHONPATH=src python examples/logistic_regression.py
"""

import numpy as np

from repro.analytics import logreg
from repro.core import AccumMode
from repro.data import logreg_dataset
from repro.launch.mesh import make_host_mesh


def main():
    x, y, theta_true = logreg_dataset(n_rows=2000, n_features=64, seed=0)

    ref = logreg.fit_reference(x, y, iters=20, lr=1e-3)
    print(f"reference loss: {logreg.loss(ref, x, y):.4f}")

    for mode in (AccumMode.GATHER_ALL, AccumMode.REDUCE_SCATTER, AccumMode.AUTO):
        theta, _store, accu = logreg.fit_threads(
            x, y, n_nodes=2, threads_per_node=2, iters=20, lr=1e-3, mode=mode)
        drift = float(np.max(np.abs(theta - ref)))
        print(f"threads[{mode.value:>14s}] loss {logreg.loss(theta, x, y):.4f} "
              f"drift {drift:.2e} wire {accu.bytes_transferred:>8d} elems")

    mesh = make_host_mesh(data=1)  # grows with available devices
    spmd = logreg.fit_spmd(x, y, mesh, iters=20, lr=1e-3)
    print(f"spmd loss: {logreg.loss(spmd, x, y):.4f} "
          f"drift {float(np.max(np.abs(spmd - ref))):.2e}")


if __name__ == "__main__":
    main()
