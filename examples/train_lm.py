"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the production trainer (sharded step, prefetching pipeline, async
checkpoints, restart-exact resume).  The default config is a ~100M-parameter
dense transformer (qwen3-family blocks); on this CPU container the default
invocation trims steps — pass ``--steps 300`` on real hardware.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse

from repro.configs import get_arch
from repro.launch.train import train as _train
import repro.launch.train as train_mod
from repro.configs.base import ArchConfig

# ~100M params: 12 × (d512 swiglu-2048 blocks, 8 heads) + 32k vocab embed/head
LM100M = ArchConfig(
    name="lm-100m", family="dense",
    n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
    d_ff=2048, vocab=32000, head_dim=64, qk_norm=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/step_jax_lm100m")
    args = ap.parse_args()

    # register the 100M config under the trainer's lookup
    import repro.configs as C
    C.ARCHS[LM100M.name] = LM100M

    losses = _train(LM100M.name, smoke=False, steps=args.steps, batch=args.batch,
                    seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=25)
    print(f"[train_lm] {LM100M.name}: loss {losses[0]:.3f} → {losses[-1]:.3f} "
          f"over {len(losses)} steps (resume-capable via {args.ckpt_dir})")


if __name__ == "__main__":
    main()
